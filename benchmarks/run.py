"""Benchmark driver — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark (plus each module's own
human-readable tables above its CSV line).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep sizes (CI mode)")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (ablation, kernels_micro, needle, pattern_pareto,
                            pg19_stream, roofline, throughput, wikitext_ppl)
    from benchmarks import common

    suites = {
        "wikitext_ppl": wikitext_ppl.main,      # paper Tab. 1 + Tab. 2
        "pg19_stream": pg19_stream.main,        # paper Fig. 5 / Fig. 6
        "pattern_pareto": pattern_pareto.main,  # paper Fig. 3
        "needle": needle.main,                  # paper Fig. 8 / Fig. 9
        "ablation": ablation.main,              # paper Fig. 10 + Tab. 6
        "throughput": throughput.main,          # paper Fig. 7
        "kernels_micro": kernels_micro.main,    # TPU-kernel substrate
        "roofline": roofline.main,              # EXPERIMENTS.md §Roofline
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    # ensure the shared eval model exists (trains once, ~minutes on CPU)
    common.bench_model(steps=120 if args.quick else 300)

    failures = 0
    for name, fn in suites.items():
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        try:
            fn(quick=args.quick)
        except Exception:
            failures += 1
            print(f"[FAIL] {name}")
            traceback.print_exc()
        print(f"----- {name} done in {time.perf_counter()-t0:.1f}s -----",
              flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
