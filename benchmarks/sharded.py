"""Sharded paged serving on a forced-multi-device host mesh.

The tentpole claim behind ``Engine(mesh=...)``: sharding the physical
pool's kv-head axis over the ``model`` mesh axis divides the per-chip
cached-KV footprint by the model-axis extent while the emitted tokens
stay identical to single-device paged serving (the kv-head split is
bitwise clean — each shard computes its own query-head group end to end,
no collective inside attention). This benchmark runs both engines over
the same shared-prefix request mix on an 8-way forced host-device CPU
"mesh" (4 data x 2 model), asserts token parity and the ~1/model per-chip
plane footprint, and emits ``results/BENCH_sharded.json`` through the
shared ``write_bench`` envelope.

Run directly (the XLA device-count flag must be set before jax imports,
which this module does for itself):

  PYTHONPATH=src python benchmarks/sharded.py
"""
from __future__ import annotations

import os
import sys
import time

# must precede any jax import: the forced host device count is read once
# at backend initialization
_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + _FLAG).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

if __package__ in (None, ""):     # `python benchmarks/sharded.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
from benchmarks import common  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serving.engine import Engine  # noqa: E402

DATA_AXIS, MODEL_AXIS = 4, 2


def sharded_vs_single(cfg, params, budget=96, n_requests=6, prefix_len=192,
                      tail_len=16, max_new=8):
    """Serve one shared-prefix mix on a single-device paged engine and on
    a mesh-sharded one; return parity + footprint + throughput numbers."""
    c = common.with_policy(cfg, "lacache", budget)
    co = common.corpus()
    shared = co.stream(prefix_len, seed=910)

    def wave(seed0):
        return [np.concatenate([shared, co.stream(tail_len, seed=seed0 + i)])
                for i in range(n_requests)]

    def serve(mesh):
        eng = Engine(c, params, budget=budget, max_batch=4,
                     kv_backend="paged", mesh=mesh)
        for p in wave(911):
            eng.submit(p, max_new, cache_prefix=True)
        eng.run()
        for p in wave(931):
            eng.submit(p, 4 * max_new, cache_prefix=True)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.output_tokens) for r in done)
        toks = [r.tokens.tolist() for r in done]
        per_dev = eng.kv_pool_bytes_per_device
        eng.close()
        return toks, n_tok / dt, per_dev

    single_toks, single_tps, single_bytes = serve(None)
    mesh = jax.make_mesh((DATA_AXIS, MODEL_AXIS), ("data", "model"))
    shard_toks, shard_tps, shard_bytes = serve(mesh)

    assert shard_toks == single_toks, \
        "sharded paged decode must match single-device token-for-token"
    ratio = shard_bytes / max(single_bytes, 1)
    # kv-head-sharded planes (bench_cfg has n_kv_heads=4, model axis 2):
    # per-chip plane bytes must scale as ~1/model
    assert abs(ratio - 1.0 / MODEL_AXIS) < 1e-6, \
        f"per-device plane bytes ratio {ratio} != 1/{MODEL_AXIS}"
    return {
        "scenario": "sharded_vs_single_device",
        "mesh": {"data": DATA_AXIS, "model": MODEL_AXIS},
        "devices": len(jax.devices()),
        "tokens_match": True,
        "kv_pool_bytes_per_device": {"single": single_bytes,
                                     "sharded": shard_bytes},
        "per_device_bytes_ratio": ratio,
        "expected_ratio": 1.0 / MODEL_AXIS,
        # CPU host-"devices" share one socket, so tok/s is a smoke signal
        # (collective + partitioning overhead), not a speedup claim
        "tok_per_s": {"single": single_tps, "sharded": shard_tps},
    }


def main():
    n = len(jax.devices())
    if n < DATA_AXIS * MODEL_AXIS:
        print(f"need {DATA_AXIS * MODEL_AXIS} devices, have {n}; "
              "set XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return 1
    cfg, params = common.bench_model()
    budget = 96
    out = sharded_vs_single(cfg, params, budget=budget)
    print(f"sharded ({DATA_AXIS}x{MODEL_AXIS} mesh): tokens match; "
          f"pool bytes/device "
          f"{out['kv_pool_bytes_per_device']['single']/1e6:.2f} MB -> "
          f"{out['kv_pool_bytes_per_device']['sharded']/1e6:.2f} MB "
          f"(ratio {out['per_device_bytes_ratio']:.3f}, expected "
          f"{out['expected_ratio']:.3f}); "
          f"{out['tok_per_s']['single']:.1f} -> "
          f"{out['tok_per_s']['sharded']:.1f} tok/s steady-state "
          "(CPU smoke, not a speedup claim)")
    common.write_bench("sharded", out, config={
        "mesh": f"{DATA_AXIS}x{MODEL_AXIS}", "budget": budget,
        "n_kv_heads": cfg.n_kv_heads, "page_size": 16})
    return 0


if __name__ == "__main__":
    sys.exit(main())
