"""Paper Fig. 10 + Tab. 6: span S and overlap O hyperparameter ablations.

Fig. 10: PPL over (S, O) on language modeling — the paper finds S ~= L/4,
O ~= S/2 best. Tab. 6: larger O helps global/synthetic tasks, hurts local QA
— probed here via the retention proxy (global coverage vs local concentration).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.core import ladder
from repro.serving.engine import Engine


def ppl_for(cfg, params, span, overlap, budget=96, T=512):
    c = common.with_policy(cfg, "lacache", budget, span=span, overlap=overlap)
    eng = Engine(c, params, budget=budget)
    co = common.corpus()
    toks = np.stack([co.stream(T, seed=8000 + i) for i in range(3)])
    return float(np.exp(eng.score_stream(toks).mean()))


def main(quick: bool = False):
    cfg, params = common.bench_model()
    L = cfg.n_layers
    t0 = time.perf_counter()
    grid = {}
    spans = [max(1, L // 8), L // 4, L // 2, L]
    for S in spans:
        for O in sorted({0, S // 4, S // 2}):
            if O >= S and S > 1:
                continue
            grid[f"S={S},O={O}"] = ppl_for(cfg, params, S, O,
                                           T=256 if quick else 512)
    print("span/overlap PPL grid:")
    for k, v in sorted(grid.items(), key=lambda kv: kv[1]):
        print(f"  {k:12s} ppl={v:.3f}")

    # Tab. 6 proxy: overlap widens union coverage (global) at the cost of
    # per-layer span concentration (local)
    cov = {}
    for O in (0, L // 8, L // 4):
        spec = ladder.LadderSpec(n_layers=L, span=L // 2, overlap=O, chunk=4,
                                 n_sink=4, n_recent=16, budget=96)
        sim = ladder.simulate_stream(spec, 800)
        cov[f"O={O}"] = {
            "union_span": sim.union_span(),
            "mean_per_layer": float(np.mean(sim.coverage())),
        }
    print("overlap coverage proxy:", cov)
    dt = time.perf_counter() - t0
    with open(os.path.join(common.RESULTS, "ablation.json"), "w") as f:
        json.dump({"ppl_grid": grid, "coverage": cov}, f, indent=1)

    best = min(grid, key=grid.get)
    common.emit("ablation_span_overlap", dt * 1e6 / max(1, len(grid)),
                f"best={best};ppl={grid[best]:.3f}")
    return grid


if __name__ == "__main__":
    main()
