"""Paper Fig. 5/6 structure: continuous generation over a very long stream.

Full cache with original rope explodes in PPL past the trained context and
its memory grows linearly (the OOM axis); LaCache sustains the stream at
O(1) memory with flat PPL, and stays below StreamingLLM throughout.
Stream length here is ~20x the trained context (CPU-scaled from the paper's
10M tokens)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.serving.engine import Engine


def run_stream(cfg, params, policy, budget, T, rope_mode="cache",
               chunk=256):
    c = common.with_policy(cfg, policy, budget, rope_mode=rope_mode)
    eng = Engine(c, params, budget=budget)
    co = common.corpus()
    toks = np.stack([co.stream(T, seed=31415)])
    # chunked streaming scoring (decode_chunk): the paper's PG19 sliding
    # window (256) — each chunk sees [compacted cache || chunk prefix]
    nll = eng.score_stream_chunked(toks, chunk=min(chunk, 256))
    # windowed PPL trace
    xs, ys = [], []
    for s in range(0, nll.shape[1] - chunk + 1, chunk):
        xs.append(s + chunk)
        ys.append(float(np.exp(nll[:, s:s + chunk].mean())))
    state = eng.new_state(1)
    return xs, ys, eng.cache_bytes(state)


def main(quick: bool = False):
    cfg, params = common.bench_model()
    T = 1024 if quick else 4096               # trained context = 192
    t0 = time.perf_counter()
    out = {}
    for name, (pol, bud, rm) in {
        "full(orig-rope)": ("full", T, "original"),
        "streaming(96)": ("streaming", 96, "cache"),
        "lacache(96)": ("lacache", 96, "cache"),
    }.items():
        xs, ys, cb = run_stream(cfg, params, pol, bud, T, rm)
        out[name] = {"pos": xs, "ppl": ys, "cache_bytes": cb}
        print(f"{name:18s} cache={cb/1e6:7.2f}MB  ppl@{xs[0]}={ys[0]:.2f} "
              f"ppl@{xs[-1]}={ys[-1]:.2f}")
    dt = time.perf_counter() - t0
    with open(os.path.join(common.RESULTS, "pg19_stream.json"), "w") as f:
        json.dump(out, f, indent=1)

    # derived claims
    full_exploded = out["full(orig-rope)"]["ppl"][-1] \
        / max(out["full(orig-rope)"]["ppl"][0], 1e-9)
    lc, st = out["lacache(96)"], out["streaming(96)"]
    common.emit("pg19_stream", dt * 1e6 / T,
                f"full_ppl_growth_x={full_exploded:.1f};"
                f"lacache_final={lc['ppl'][-1]:.2f};"
                f"streaming_final={st['ppl'][-1]:.2f};"
                f"lacache_cache_mb={lc['cache_bytes']/1e6:.1f};"
                f"full_cache_mb={out['full(orig-rope)']['cache_bytes']/1e6:.1f}")
    return out


if __name__ == "__main__":
    main()
