"""Shared benchmark infrastructure: the evaluation model (trained once and
checkpointed), timing and CSV helpers, and the ``write_bench`` envelope
writer every committed ``results/BENCH_*.json`` goes through.

All paper-table benchmarks run on ``bench_model()`` — a llama-family miniature
(paper models are Llama2/3; absolute PPLs differ by construction, the claims
validated are orderings/scalings — DESIGN.md §8)."""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs.base import LaCacheConfig, ModelConfig
from repro.data.pipeline import CorpusConfig, SyntheticCorpus, lm_batches
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train import trainer

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
VOCAB = 512
SEQ = 256          # training context length; PPL explosion expected beyond
BENCH_LAYERS = 8

# Bump when the envelope layout (not a benchmark's payload) changes.
SCHEMA_VERSION = 1


def git_sha() -> Optional[str]:
    """Short SHA of the repo HEAD, or None outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def write_bench(name: str, payload: Dict, config: Optional[Dict] = None,
                ) -> str:
    """Write ``results/BENCH_<name>.json`` in the shared envelope.

    Every committed benchmark artifact carries the same provenance header
    — schema version, the git SHA it was produced at, and the benchmark's
    configuration — with the benchmark-specific numbers under ``data``.
    Cross-PR diffs then always answer "what ran, at which commit, with
    which knobs" without per-benchmark archaeology. Returns the path.
    """
    path = os.path.join(RESULTS, f"BENCH_{name}.json")
    env = {
        "schema_version": SCHEMA_VERSION,
        "bench": name,
        "git_sha": git_sha(),
        "config": config or {},
        "data": payload,
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(path, "w") as f:
        json.dump(env, f, indent=1)
        f.write("\n")
    return path


def bench_cfg(**kw) -> ModelConfig:
    d = dict(
        name="bench-llama-mini", arch_type="dense", n_layers=BENCH_LAYERS,
        d_model=128, n_heads=8, n_kv_heads=4, head_dim=16, d_ff=384,
        vocab_size=VOCAB, dtype="float32", rope_theta=1e4,
        lacache=LaCacheConfig(budget=96, n_sink=4, n_recent=16, chunk=4))
    d.update(kw)
    return ModelConfig(**d)


def corpus() -> SyntheticCorpus:
    # long-range-heavy mixture: frequent copy events reaching far beyond the
    # LaCache budget give the eviction policies something real to disagree on
    return SyntheticCorpus(CorpusConfig(
        vocab_size=VOCAB, seed=7, p_copy=0.08, copy_len=(24, 96),
        copy_back=(96, 1536), p_motif=0.3))


def bench_model(steps: int = 500, force: bool = False
                ) -> Tuple[ModelConfig, Dict]:
    """Train (or load) the shared evaluation model."""
    cfg = bench_cfg()
    path = os.path.join(RESULTS, "bench_model.npz")
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    if os.path.exists(path) and not force:
        return cfg, ckpt.load(path, params)
    co = corpus()
    params, hist = trainer.train(
        cfg, params, lm_batches(co, 16, SEQ, steps),
        AdamWConfig(lr=2e-3, warmup_steps=30, total_steps=steps),
        log_every=50)
    ckpt.save(path, params)
    print(f"[bench_model] trained {steps} steps, "
          f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")
    return cfg, params


def with_policy(cfg: ModelConfig, policy: str, budget: int, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, lacache=dataclasses.replace(
        cfg.lacache, policy=policy, budget=budget, **kw))


def timer(fn, *args, reps: int = 3, warmup: int = 1):
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps, r


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
