"""Paper Fig. 3: the ladder pattern vs randomly sampled (layer x token) keep
patterns at matched per-layer budgets — PPL/cache-size Pareto.

Patterns are applied as static per-layer retention masks on a fixed context
(``kv_keep_masks`` in forward_train): each layer attends only to its kept
positions, exactly the 'compact the full KV cache by pattern' evaluation."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import ladder
from repro.models import model as M


def masked_ppl(cfg, params, toks, masks) -> float:
    logits, _, _ = M.forward_train(params, cfg, toks, remat=False,
                                   kv_keep_masks=jnp.asarray(masks))
    nll = M.lm_loss(logits[:, :-1], toks[:, 1:])
    return float(np.exp(nll))


def ladder_masks(cfg, T, budget, span, overlap, chunk=4, sink=4, recent=16):
    spec = ladder.LadderSpec(n_layers=cfg.n_layers, span=span, overlap=overlap,
                             chunk=chunk, n_sink=sink, n_recent=recent,
                             budget=budget)
    return np.stack([ladder.ladder_keep_mask_np(spec, T, l)
                     for l in range(cfg.n_layers)])


def main(quick: bool = False):
    cfg, params = common.bench_model()
    T = 160
    co = common.corpus()
    toks = jnp.asarray(np.stack([co.stream(T, seed=2000 + i)
                                 for i in range(8)]), jnp.int32)
    rng = np.random.default_rng(0)
    n_random = 40 if quick else 150
    t0 = time.perf_counter()

    f = jax.jit(lambda m: M.forward_train(params, cfg, toks, remat=False,
                                          kv_keep_masks=m)[0])

    def ppl_of(masks):
        logits = f(jnp.asarray(masks))
        return float(np.exp(M.lm_loss(logits[:, :-1], toks[:, 1:])))

    results = {"random": [], "ladder": [], "streaming": []}
    for i in range(n_random):
        keep = int(rng.integers(24, 120))
        masks = ladder.random_pattern_keep_mask_np(
            rng, cfg.n_layers, T, keep, n_sink=4, n_recent=16)
        results["random"].append((float(masks.sum(1).mean()), ppl_of(masks)))

    L = cfg.n_layers
    for span, ov in [(L, 0), (L // 2, 0), (L // 2, L // 4), (L // 4, 0),
                     (L // 4, L // 8), (2, 1), (2, 0)]:
        if span < 1:
            continue
        for chunk in (2, 4, 8):
            masks = ladder_masks(cfg, T, 9999, span, ov, chunk=chunk)
            results["ladder"].append(
                (float(masks.sum(1).mean()), ppl_of(masks)))
    for w in (24, 48, 96, 128):
        masks = np.zeros((cfg.n_layers, T), bool)
        masks[:, :4] = True
        masks[:, T - w:] = True
        results["streaming"].append((float(masks.sum(1).mean()), ppl_of(masks)))

    dt = time.perf_counter() - t0
    with open(os.path.join(common.RESULTS, "pattern_pareto.json"), "w") as fo:
        json.dump(results, fo, indent=1)

    # Pareto check: a ladder point is dominated only if a random pattern
    # beats it by more than the eval-noise margin at <= cache size.
    eps = 0.02
    dominated = 0
    for lc, lp in results["ladder"]:
        if any(rc <= lc and rp < lp - eps for rc, rp in results["random"]):
            dominated += 1
    frac = dominated / max(1, len(results["ladder"]))
    print(f"random patterns evaluated: {len(results['random'])}; "
          f"ladder points dominated (>{eps} PPL margin): {frac:.0%}")
    common.emit("pattern_pareto", dt * 1e6 / max(1, n_random),
                f"ladder_points_dominated_frac={frac:.3f}")
    return results


if __name__ == "__main__":
    main()
