"""Paper Fig. 7: accuracy-throughput trade-off across eviction policies.

LaCache/StreamingLLM are attention-score-free and run the fused decode path;
H2O and TOVA must materialize attention probabilities
(FlashAttention-incompatible) and pay the probability materialization plus
score bookkeeping — the throughput axis of Fig. 7. Quality axis: PPL on the
shared eval stream.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.speculative import SpecConfig


def decode_throughput(cfg, params, policy, budget, batch=8, steps=40):
    c = common.with_policy(cfg, policy, budget)
    eng = Engine(c, params, budget=budget)
    state = eng.new_state(batch)
    tok = jnp.zeros((batch, 1), jnp.int32)
    # fill the cache first so compaction costs are included
    for _ in range(budget + 8):
        _, state = eng._decode(eng.params, state=state, tokens=tok)
    jax.block_until_ready(state.pos)
    t0 = time.perf_counter()
    for _ in range(steps):
        logits, state = eng._decode(eng.params, state=state, tokens=tok)
    jax.block_until_ready(logits)
    dt = (time.perf_counter() - t0) / steps
    return dt * 1e6, batch / dt  # us/step, tok/s


def prefix_reuse(cfg, params, budget=96, n_requests=6, prefix_len=192,
                 tail_len=16, max_new=8):
    """Serving scenario: ``n_requests`` prompts share one long prefix (the
    million-user system-prompt shape). Serve the mix cold and through the
    shared-prefix cache; report prefill tokens computed and wall time."""
    c = common.with_policy(cfg, "lacache", budget)
    co = common.corpus()
    shared = co.stream(prefix_len, seed=900)
    prompts = [np.concatenate([shared, co.stream(tail_len, seed=901 + i)])
               for i in range(n_requests)]

    def serve(cache_prefix: bool):
        eng = Engine(c, params, budget=budget, max_batch=4)
        for p in prompts:
            eng.submit(p, max_new, cache_prefix=cache_prefix)
        t0 = time.perf_counter()
        eng.run()
        return eng, time.perf_counter() - t0

    cold, t_cold = serve(False)
    warm, t_warm = serve(True)
    return {
        "n_requests": n_requests, "prefix_len": prefix_len,
        "prefill_tokens_cold": cold.prefill_tokens,
        "prefill_tokens_warm": warm.prefill_tokens,
        "prefix_hit_rate": warm.prefix_hit_rate,
        "tokens_reused": warm.prefix_tokens_reused,
        "s_cold": t_cold, "s_warm": t_warm,
    }


def paged_vs_dense(cfg, params, budget=96, n_requests=6, prefix_len=192,
                   tail_len=13, max_new=8):
    """Shared-prefix traffic served by the dense vs the paged KV backend.

    Same requests, same prompt cache semantics; the paged backend decodes
    *in-model* through block tables in one physical pool — prefix hits
    splice shared blocks into the live state, snapshots are refcount forks
    — so the peak cached-KV footprint collapses while tokens stay
    identical. Each backend serves the mix twice with a fresh engine: the
    first pass is the cold start, the second measures the steady-state
    serving rate (the regression-tracked number — PR 3's paged backend
    lost 3x wall-clock to eager per-snapshot pool scatters that in-model
    decode eliminates). The paged engine is built with ``prewarm=True``
    and ``bucket_prefill=True``: the batched decode/chunk executables AND
    the bucketed prefill ladder compile at construction, so the cold
    start splits into an explicit ``prewarm_s`` compile phase plus a
    compile-free first wave. A third paged serve with
    ``prewarm_prefill=False`` isolates the prefill ladder's share of the
    compile-inclusive number (the former cold-start soft spot: prefill
    compiles used to land inside wave 1). ``tok_per_s_first_wave`` is the
    compile-free cold number; ``tok_per_s_*_incl_compile`` charges
    construction + wave 1 together. Read the reported
    ``prefill_prewarm_delta_tok_per_s`` with the scenario in mind: this
    workload's prompts all land in ONE bucket, so wave 1 cold pays a
    single prefill compile while the ladder warms every bucket up front —
    the delta prices that insurance (it can go negative here; the ladder
    pays off on mixed-length traffic, where each distinct bucket would
    otherwise spike a later request's TTFT). The default ``tail_len`` is
    deliberately ragged (192 + 13 = 205 = 6*32 + 13): the greedy chunk
    splitter then emits 8/4/1-wide tail dispatches inside wave 1, the
    widths the prewarm chunk ladder used to skip — so the first-wave and
    compile-inclusive numbers now exercise the full warmed ladder
    (``prewarmed_chunk_widths`` in the bench artifact records it).
    Machine-readable trajectory in ``results/BENCH_paged.json``.
    """
    c = common.with_policy(cfg, "lacache", budget)
    co = common.corpus()
    shared = co.stream(prefix_len, seed=910)

    def wave(seed0):
        return [np.concatenate([shared, co.stream(tail_len,
                                                  seed=seed0 + i)])
                for i in range(n_requests)]

    def serve(kv_backend, prewarm_prefill=True):
        t0 = time.perf_counter()
        eng = Engine(c, params, budget=budget, max_batch=4,
                     kv_backend=kv_backend, prewarm=True,
                     bucket_prefill=True, prewarm_prefill=prewarm_prefill)
        build_s = time.perf_counter() - t0   # prewarm compile (paged only)
        # wave 1 (cold): builds the shared-prefix cache and pays whatever
        # compilation prewarm could not move to construction
        for p in wave(911):
            eng.submit(p, max_new, cache_prefix=True)
        t0 = time.perf_counter()
        done = eng.run()
        t1 = time.perf_counter() - t0
        n1 = sum(len(r.output_tokens) for r in done)
        first, cold = n1 / t1, n1 / (build_s + t1)
        # wave 2 (steady state): fresh requests over the warm engine — the
        # continuous-serving regime the fixed-budget cache targets (prefix
        # hits splice the cached system prompt, tails prefill, decode runs
        # through the per-backend hot path). Generation runs 4x longer
        # than wave 1 so the decode loop dominates the window — a few
        # dozen tokens is pure scheduler noise on a shared CPU.
        for p in wave(931):
            eng.submit(p, 4 * max_new, cache_prefix=True)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.output_tokens) for r in done)
        return (eng, [r.tokens.tolist() for r in done], build_s, first,
                cold, n_tok / dt)

    (dense_eng, dense_toks, dense_build, dense_first, dense_cold,
     dense_tps) = serve("dense")
    # each paged serve starts from an empty compilation cache — the three
    # serves share one process, and a warm jit cache would hand the later
    # serves the earlier ones' compiles, turning the prewarm-scope
    # comparison into a no-op
    jax.clear_caches()
    (paged_eng, paged_toks, paged_build, paged_first, paged_cold,
     paged_tps) = serve("paged")
    # prefill ladder left cold: wave 1 re-pays the prefill compiles, so
    # the gap to the full-prewarm numbers is the prefill-prewarm delta
    jax.clear_caches()
    (_, nopre_toks, nopre_build, nopre_first, nopre_cold,
     _) = serve("paged", prewarm_prefill=False)
    assert dense_toks == paged_toks, "backends must agree token-for-token"
    assert nopre_toks == paged_toks, \
        "prewarm scope must not change tokens"
    return {
        "n_requests": n_requests, "prefix_len": prefix_len,
        "tok_per_s_dense": dense_tps, "tok_per_s_paged": paged_tps,
        "prewarm_s_dense": dense_build, "prewarm_s_paged": paged_build,
        "tok_per_s_dense_first_wave": dense_first,
        "tok_per_s_paged_first_wave": paged_first,
        "tok_per_s_dense_incl_compile": dense_cold,
        "tok_per_s_paged_incl_compile": paged_cold,
        "prewarm_s_paged_noprefill": nopre_build,
        "tok_per_s_paged_first_wave_noprefill": nopre_first,
        "tok_per_s_paged_incl_compile_noprefill": nopre_cold,
        "prefill_prewarm_delta_tok_per_s": paged_cold - nopre_cold,
        "prewarmed_chunk_widths": paged_eng.prewarmed_chunk_widths,
        "prewarmed_prefill_buckets": paged_eng.prewarmed_prefill_buckets,
        "peak_kv_bytes_dense": dense_eng.prefix_cache.peak_bytes,
        "peak_kv_bytes_paged": paged_eng.prefix_cache.peak_bytes,
        "bytes_shared": paged_eng.bytes_shared,
        "kv_bytes_in_use": paged_eng.kv_bytes_in_use,
        "paged_in_model": paged_eng._paged_in_model,
    }


def hybrid_paged_vs_dense(budget=64, n_requests=6, prefix_len=96,
                          tail_len=12, max_new=8):
    """The paged-vs-dense scenario on a *hybrid* (mamba + ring + global)
    stack — the architectures the in-model paged path newly covers.

    Same shared-prefix wave protocol as :func:`paged_vs_dense`; the model
    is a freshly-initialized hybrid miniature (token agreement between the
    backends plus throughput/byte telemetry are the signal here — sample
    quality is irrelevant to the serving-path contract). Emits the
    machine-readable trajectory to ``results/BENCH_hybrid_paged.json``.
    """
    from repro.configs.base import LaCacheConfig, ModelConfig
    cfg = ModelConfig(
        name="bench-hybrid-mini", arch_type="hybrid", n_layers=8,
        d_model=128, n_heads=8, n_kv_heads=4, head_dim=16, d_ff=384,
        vocab_size=common.VOCAB, dtype="float32", rope_theta=1e4,
        attn_every=2, local_global_pattern=3, sliding_window=32,
        d_state=16, d_conv=4,
        lacache=LaCacheConfig(budget=budget, n_sink=4, n_recent=16, chunk=4))
    params, _ = M.init(cfg, jax.random.PRNGKey(3))
    co = common.corpus()
    shared = co.stream(prefix_len, seed=950)

    def wave(seed0):
        return [np.concatenate([shared, co.stream(tail_len, seed=seed0 + i)])
                for i in range(n_requests)]

    def serve(kv_backend):
        eng = Engine(cfg, params, budget=budget, max_batch=4,
                     kv_backend=kv_backend)
        for p in wave(951):
            eng.submit(p, max_new, cache_prefix=True)
        t0 = time.perf_counter()
        done = eng.run()
        cold = sum(len(r.output_tokens) for r in done) \
            / (time.perf_counter() - t0)
        for p in wave(971):
            eng.submit(p, 4 * max_new, cache_prefix=True)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.output_tokens) for r in done)
        return eng, [r.tokens.tolist() for r in done], cold, n_tok / dt

    dense_eng, dense_toks, dense_cold, dense_tps = serve("dense")
    paged_eng, paged_toks, paged_cold, paged_tps = serve("paged")
    assert paged_eng._paged_in_model, "hybrid must take the in-model path"
    assert dense_toks == paged_toks, "backends must agree token-for-token"
    out = {
        "scenario": "hybrid_paged_vs_dense",
        "paged_in_model": paged_eng._paged_in_model,
        "tok_per_s": {"dense": dense_tps, "paged": paged_tps},
        "tok_per_s_incl_compile": {"dense": dense_cold, "paged": paged_cold},
        "peak_kv_bytes": {"dense": dense_eng.prefix_cache.peak_bytes,
                          "paged": paged_eng.prefix_cache.peak_bytes},
        "paged_over_dense_tok_per_s": paged_tps / max(dense_tps, 1e-9),
        "paged_over_dense_peak_kv":
            paged_eng.prefix_cache.peak_bytes
            / max(dense_eng.prefix_cache.peak_bytes, 1),
        "bytes_shared": paged_eng.bytes_shared,
        "kv_bytes_in_use": paged_eng.kv_bytes_in_use,
        "lane_owned_bytes": paged_eng.lane_owned_bytes,
    }
    common.write_bench("hybrid_paged", out, config={
        "arch": {"attn_every": cfg.attn_every,
                 "local_global_pattern": cfg.local_global_pattern,
                 "sliding_window": cfg.sliding_window,
                 "n_layers": cfg.n_layers},
        "budget": budget, "n_requests": n_requests,
        "prefix_len": prefix_len, "tail_len": tail_len,
        "max_new": max_new})
    return out


def spec_vs_greedy(cfg, params, budget=384, headroom=96, n_requests=4,
                   prefix_len=1024, tail_len=12, max_new=96, k=8,
                   draft_budget=96):
    """Self-speculative decoding vs plain greedy on the paged backend.

    Long-context serving shape: a ``prefix_len``-token prompt is ladder-
    compacted to ``budget`` live slots and each request decodes a long
    greedy continuation. The engine gets ``headroom`` decode slots above
    the ladder budget so the chunk-verify gate stays open in steady state
    (compaction still fires at the ladder budget; with zero headroom
    every tick would fall back to stepwise decode). Speculation pays off
    where decode is attention-bound: the draft steps through slot buffers
    trimmed to ``draft_budget + k`` slots while the target amortizes its
    full-width attention over ``k + 1`` positions per chunk — so the win
    grows with the live budget (the defaults sit in that regime; at small
    budgets the wave bookkeeping roughly cancels the savings). Both engines serve an
    identical two-wave mix (wave 1 cold, wave 2 steady-state, both
    prewarmed) and must agree token-for-token — speculation changes the
    schedule of the computation, never its result. Emits the trajectory
    (steady-state speedup + acceptance telemetry) to
    ``results/BENCH_spec.json``.
    """
    c = common.with_policy(cfg, "lacache", budget)
    co = common.corpus()
    shared = co.stream(prefix_len, seed=990)

    def wave(seed0):
        return [np.concatenate([shared, co.stream(tail_len, seed=seed0 + i)])
                for i in range(n_requests)]

    def serve(spec_config):
        eng = Engine(c, params, budget=budget + headroom, max_batch=4,
                     kv_backend="paged", spec_config=spec_config,
                     prewarm=True)
        for p in wave(991):
            eng.submit(p, max_new // 2, cache_prefix=True)
        eng.run()
        for p in wave(997):
            eng.submit(p, max_new, cache_prefix=True)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.output_tokens) for r in done)
        toks = [r.tokens.tolist() for r in done]
        acc = [r.spec_acceptance_rate for r in done]
        return eng, toks, n_tok / dt, acc

    base_eng, base_toks, base_tps, _ = serve(None)
    spec_eng, spec_toks, spec_tps, acc = serve(
        SpecConfig(k=k, draft_budget=draft_budget))
    assert spec_toks == base_toks, \
        "speculative decode must match greedy token-for-token"
    stats = spec_eng.spec_stats
    out = {
        "scenario": "spec_vs_greedy",
        "tok_per_s": {"greedy": base_tps, "spec": spec_tps},
        "spec_over_greedy_tok_per_s": spec_tps / max(base_tps, 1e-9),
        "acceptance_rate": stats["acceptance_rate"],
        "acceptance_rate_per_request": acc,
        "waves": stats["waves"], "forks": stats["forks"],
        "fallback_steps": stats["fallback_steps"],
        "catchup_steps": stats["catchup_steps"],
        "proposed": stats["proposed"], "accepted": stats["accepted"],
        "draft_owned_bytes": spec_eng.draft_owned_bytes,
    }
    common.write_bench("spec", out, config={
        "k": k, "draft_budget": spec_eng._spec.draft_budget,
        "budget": budget, "n_slots": budget + headroom,
        "prefix_len": prefix_len, "tail_len": tail_len,
        "max_new": max_new, "n_requests": n_requests})
    return out


def main(quick: bool = False):
    cfg, params = common.bench_model()
    budget = 96
    T = 256 if quick else 512
    co = common.corpus()
    toks = np.stack([co.stream(T, seed=600 + i) for i in range(2)])
    out = {}
    t0 = time.perf_counter()
    for policy in ("lacache", "streaming", "h2o", "tova", "full"):
        b = T if policy == "full" else budget
        us, tps = decode_throughput(cfg, params, policy, b,
                                    steps=20 if quick else 40)
        c = common.with_policy(cfg, policy, b)
        eng = Engine(c, params, budget=b)
        ppl = float(np.exp(eng.score_stream(toks).mean()))
        out[policy] = {"us_per_step": us, "tok_per_s": tps, "ppl": ppl,
                       "budget": b}
        print(f"{policy:10s} budget={b:4d} {us:9.1f} us/step "
              f"{tps:9.1f} tok/s  ppl={ppl:.3f}")
    pr = prefix_reuse(cfg, params, budget=budget,
                      n_requests=4 if quick else 6,
                      prefix_len=128 if quick else 192)
    out["prefix_reuse"] = pr
    pd = paged_vs_dense(cfg, params, budget=budget,
                        n_requests=4 if quick else 6,
                        prefix_len=128 if quick else 192)
    out["paged_vs_dense"] = pd
    hp = hybrid_paged_vs_dense(n_requests=4 if quick else 6,
                               prefix_len=64 if quick else 96)
    out["hybrid_paged_vs_dense"] = hp
    sp = spec_vs_greedy(cfg, params, budget=192 if quick else 384,
                        n_requests=4,
                        prefix_len=512 if quick else 1024,
                        max_new=48 if quick else 96)
    out["spec_vs_greedy"] = sp
    print(f"{'spec-decode':10s} {sp['tok_per_s']['greedy']:.1f} -> "
          f"{sp['tok_per_s']['spec']:.1f} tok/s steady-state "
          f"({sp['spec_over_greedy_tok_per_s']:.2f}x, "
          f"acceptance {sp['acceptance_rate']:.2f}, "
          f"{sp['waves']} waves / {sp['fallback_steps']} fallbacks)")
    print(f"{'hybrid-paged':10s} {hp['tok_per_s']['dense']:.1f} -> "
          f"{hp['tok_per_s']['paged']:.1f} tok/s steady-state; "
          f"peak KV {hp['peak_kv_bytes']['dense']/1e6:.2f} -> "
          f"{hp['peak_kv_bytes']['paged']/1e6:.2f} MB "
          f"({hp['bytes_shared']/1e6:.2f} MB shared)")
    print(f"{'paged-vs-dense':10s} peak KV bytes "
          f"{pd['peak_kv_bytes_dense']/1e6:.2f} MB -> "
          f"{pd['peak_kv_bytes_paged']/1e6:.2f} MB "
          f"({pd['bytes_shared']/1e6:.2f} MB shared); "
          f"{pd['tok_per_s_dense']:.1f} -> {pd['tok_per_s_paged']:.1f} tok/s "
          f"steady-state ({pd['tok_per_s_dense_incl_compile']:.1f} -> "
          f"{pd['tok_per_s_paged_incl_compile']:.1f} incl. compile; "
          f"paged prewarm {pd['prewarm_s_paged']:.1f}s then "
          f"{pd['tok_per_s_paged_first_wave']:.1f} tok/s first wave; "
          f"prefill ladder cold: "
          f"{pd['tok_per_s_paged_incl_compile_noprefill']:.1f} incl. "
          f"compile, delta "
          f"{pd['prefill_prewarm_delta_tok_per_s']:+.1f}; "
          f"warmed chunk widths {pd['prewarmed_chunk_widths']})")
    # machine-readable perf trajectory: tok/s + peak KV bytes per backend,
    # so paged regressions are tracked across PRs instead of rediscovered
    common.write_bench("paged", {
        "scenario": "paged_vs_dense",
        "paged_in_model": pd["paged_in_model"],
        "tok_per_s": {"dense": pd["tok_per_s_dense"],
                      "paged": pd["tok_per_s_paged"]},
        "prewarm_s": {"dense": pd["prewarm_s_dense"],
                      "paged": pd["prewarm_s_paged"]},
        "tok_per_s_first_wave": {
            "dense": pd["tok_per_s_dense_first_wave"],
            "paged": pd["tok_per_s_paged_first_wave"]},
        "tok_per_s_incl_compile": {
            "dense": pd["tok_per_s_dense_incl_compile"],
            "paged": pd["tok_per_s_paged_incl_compile"],
            "paged_noprefill_prewarm":
                pd["tok_per_s_paged_incl_compile_noprefill"]},
        "prefill_prewarm_delta_tok_per_s":
            pd["prefill_prewarm_delta_tok_per_s"],
        "prewarmed_chunk_widths": pd["prewarmed_chunk_widths"],
        "prewarmed_prefill_buckets": pd["prewarmed_prefill_buckets"],
        "peak_kv_bytes": {"dense": pd["peak_kv_bytes_dense"],
                          "paged": pd["peak_kv_bytes_paged"]},
        "paged_over_dense_tok_per_s":
            pd["tok_per_s_paged"] / max(pd["tok_per_s_dense"], 1e-9),
        "paged_over_dense_peak_kv":
            pd["peak_kv_bytes_paged"]
            / max(pd["peak_kv_bytes_dense"], 1),
        "bytes_shared": pd["bytes_shared"],
    }, config={"budget": budget, "n_requests": pd["n_requests"],
               "prefix_len": pd["prefix_len"]})
    print(f"{'prefix-reuse':10s} {pr['prefill_tokens_cold']:5d} -> "
          f"{pr['prefill_tokens_warm']:5d} prefill tokens "
          f"(hit rate {pr['prefix_hit_rate']:.2f}, "
          f"{pr['tokens_reused']} reused; "
          f"{pr['s_cold']:.2f}s -> {pr['s_warm']:.2f}s incl. compile — "
          f"the token counters are the compile-free signal)")
    out["wall_s"] = time.perf_counter() - t0
    # was results/throughput.json (untracked, schema-less) before the
    # write_bench envelope unified benchmark artifacts
    common.write_bench("throughput", out,
                       config={"quick": quick, "budget": budget, "T": T})
    speedup = out["h2o"]["us_per_step"] / out["lacache"]["us_per_step"]
    common.emit("throughput", out["lacache"]["us_per_step"],
                f"lacache_vs_h2o_speedup={speedup:.2f};"
                f"ppl_lacache={out['lacache']['ppl']:.3f};"
                f"ppl_h2o={out['h2o']['ppl']:.3f}")
    return out


if __name__ == "__main__":
    main()
