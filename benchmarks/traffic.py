"""Trace-driven SLO load harness: serve synthetic production traffic
through the Engine against a simulated clock and report latency/goodput.

The harness generates request traces (Poisson or bursty arrivals, Zipf-
shared prompt prefixes, mixed prompt/output lengths, tenant classes with
priorities and completion deadlines), submits them to a real Engine — the
paged backend, shared-prefix cache, bucketed prefill and the admission
registry are all live — and drives :meth:`Engine.step` under a *virtual*
clock advanced by a simple cost model (fixed dispatch cost per tick plus
per-token prefill/decode costs). Injecting the clock into the engine
means every engine-side timestamp (submit/admit/first-token/finish) and
deadline comparison lives in simulated seconds: results are deterministic
across machines and independent of host compile/dispatch jitter, which on
the miniature eval models would otherwise drown the scheduling signal.

Reported per scenario: TTFT and TPOT p50/p99, goodput under deadline
(generated tokens belonging to requests that finished within their
deadline, per simulated second), preemption/resume counts and queue-wait
percentiles (straight from the engine's metrics registry). Scenarios are
the cross product {steady Poisson, bursty} x {fifo, deadline} admission —
the headline claim is that deadline (EDF) admission converts the same
traffic into more deadline-met tokens than FIFO under burst.

  PYTHONPATH=src python benchmarks/traffic.py            # full matrix
  PYTHONPATH=src python benchmarks/traffic.py --smoke    # CI smoke

Full runs emit ``results/BENCH_traffic.json`` through
:func:`benchmarks.common.write_bench`; ``--smoke`` prints only.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
from typing import Dict, List, Optional

import jax
import numpy as np

if __package__ in (None, ""):     # `python benchmarks/traffic.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
from benchmarks import common
from repro.configs.base import LaCacheConfig, ModelConfig
from repro.models import model as M
from repro.obs.metrics import MetricsRegistry
from repro.serving.admission import deadline_slack
from repro.serving.engine import Engine, FINISHED


# --------------------------------------------------------------------------- #
# Simulated time
# --------------------------------------------------------------------------- #
class SimClock:
    """Virtual clock injected into the engine (``Engine(clock=clock.now)``).

    The harness owns time: it advances by the cost model after each tick
    and jumps to the next arrival when the engine idles. Timestamps the
    engine records therefore have one-tick granularity — a token sampled
    during tick *n* is stamped with the clock value at the start of that
    tick."""

    def __init__(self, t0: float = 0.0):
        self.t = t0

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt

    def advance_to(self, t: float) -> None:
        self.t = max(self.t, t)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Simulated cost of one engine tick (seconds of virtual time).

    Calibrated to a small-model serving shape: fixed per-tick dispatch
    overhead, plus linear costs per prompt token actually computed in
    prefill (prefix-cache hits are free — that is the point of the cache)
    and per token decoded."""

    tick_s: float = 0.004
    prefill_tok_s: float = 0.0004
    decode_tok_s: float = 0.001


# --------------------------------------------------------------------------- #
# Workload generation
# --------------------------------------------------------------------------- #
TENANTS = (
    # share of traffic, admission priority, completion SLO (virtual s),
    # output-length range. "interactive" is chat-shaped (short outputs,
    # tight deadline); "batch" is summarization-shaped (long outputs,
    # loose deadline).
    {"name": "interactive", "share": 0.7, "priority": 2, "slo_s": 0.6,
     "out": (6, 14)},
    {"name": "batch", "share": 0.3, "priority": 0, "slo_s": 3.0,
     "out": (20, 40)},
)


def _arrival_times(n: int, rng: np.random.Generator, pattern: str,
                   rate: float) -> np.ndarray:
    """Arrival offsets for ``n`` requests (virtual seconds, sorted).

    ``steady``: Poisson process at ``rate`` req/s (exponential gaps).
    ``bursty``: alternating phases — 0.5 s at 4x ``rate`` then 1.0 s at
    rate/4 — same Poisson machinery per phase, so bursts queue hard and
    the troughs let the backlog drain (the regime admission policies
    disagree in)."""
    if pattern == "steady":
        return np.cumsum(rng.exponential(1.0 / rate, size=n))
    if pattern != "bursty":
        raise ValueError(f"unknown arrival pattern {pattern!r}")
    out: List[float] = []
    t = 0.0
    hi = True
    while len(out) < n:
        dur, r = (0.5, 4.0 * rate) if hi else (1.0, rate / 4.0)
        end = t + dur
        while len(out) < n:
            t += rng.exponential(1.0 / r)
            if t >= end:
                t = end
                break
            out.append(t)
        hi = not hi
    return np.asarray(out[:n])


def gen_workload(n: int, seed: int, pattern: str, rate: float,
                 vocab: int, n_prefixes: int = 4, prefix_len: int = 32,
                 zipf_s: float = 1.2) -> List[Dict]:
    """One request trace: list of dicts sorted by arrival time.

    Prompts share ``n_prefixes`` system-prompt-shaped prefixes with Zipf
    popularity (rank-``zipf_s`` weights), each extended by a per-request
    tail of 4-16 tokens — the shape the shared-prefix cache exists for.
    Tenant class, output length, priority and deadline ride along."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, size=prefix_len, dtype=np.int32)
                for _ in range(n_prefixes)]
    w = 1.0 / np.arange(1, n_prefixes + 1) ** zipf_s
    w /= w.sum()
    shares = np.asarray([t["share"] for t in TENANTS])
    arrivals = _arrival_times(n, rng, pattern, rate)
    work = []
    for arrival in arrivals:
        tenant = TENANTS[int(rng.choice(len(TENANTS), p=shares))]
        tail = rng.integers(0, vocab, size=int(rng.integers(4, 17)),
                            dtype=np.int32)
        prefix = prefixes[int(rng.choice(n_prefixes, p=w))]
        lo, hi = tenant["out"]
        work.append({
            "arrival": float(arrival),
            "prompt": np.concatenate([prefix, tail]),
            "max_new": int(rng.integers(lo, hi + 1)),
            "priority": tenant["priority"],
            "slo_s": tenant["slo_s"],
            "tenant": tenant["name"],
        })
    return work


# --------------------------------------------------------------------------- #
# Scenario driver
# --------------------------------------------------------------------------- #
def _pct(xs, q) -> Optional[float]:
    return float(np.percentile(xs, q)) if len(xs) else None


def _latency_block(xs) -> Dict:
    return {"p50": _pct(xs, 50), "p99": _pct(xs, 99),
            "mean": float(np.mean(xs)) if len(xs) else None, "n": len(xs)}


def run_scenario(cfg, params, work: List[Dict], admission: str,
                 cost: CostModel = CostModel(), max_batch: int = 4,
                 budget: int = 48) -> Dict:
    """Serve one trace through a fresh engine; return the SLO report."""
    clock = SimClock()
    metrics = MetricsRegistry()
    eng = Engine(cfg, params, budget=budget, max_batch=max_batch,
                 kv_backend="paged", admission=admission,
                 bucket_prefill=True, metrics=metrics, clock=clock.now)
    arrival_of: Dict[int, float] = {}
    tenant_of: Dict[int, str] = {}
    done = []
    i = 0
    prev_prefill = 0
    prev_tokens = 0.0
    n_ticks = 0
    while i < len(work) or eng.scheduler.has_work:
        if not eng.scheduler.has_work:
            # engine idle: jump straight to the next arrival
            clock.advance_to(work[i]["arrival"])
        while i < len(work) and work[i]["arrival"] <= clock.now() + 1e-9:
            w = work[i]
            i += 1
            req = eng.submit(w["prompt"], w["max_new"],
                             priority=w["priority"],
                             deadline=w["arrival"] + w["slo_s"],
                             cache_prefix=True)
            arrival_of[req.request_id] = w["arrival"]
            tenant_of[req.request_id] = w["tenant"]
        done.extend(eng.step())
        n_ticks += 1
        # bill this tick's simulated cost: fixed dispatch overhead plus
        # the prompt tokens actually prefilled and the tokens decoded
        d_pre = eng.prefill_tokens - prev_prefill
        prev_prefill = eng.prefill_tokens
        tok = metrics.value("engine_tokens_total")
        d_tok = tok - prev_tokens
        prev_tokens = tok
        clock.advance(cost.tick_s + cost.prefill_tok_s * d_pre
                      + cost.decode_tok_s * d_tok)

    t_first_arrival = work[0]["arrival"]
    makespan = clock.now() - t_first_arrival
    ttft, tpot, met_tokens, total_tokens = [], [], 0, 0
    per_tenant: Dict[str, Dict] = {
        t["name"]: {"ttft": [], "met": 0, "n": 0} for t in TENANTS}
    n_met = n_missed = 0
    for r in done:
        if r.status != FINISHED:
            continue
        n = len(r.output_tokens)
        total_tokens += n
        tt = r.t_first - arrival_of[r.request_id]
        ttft.append(tt)
        if n >= 2:
            tpot.append((r.t_finish - r.t_first) / (n - 1))
        pt = per_tenant[tenant_of[r.request_id]]
        pt["ttft"].append(tt)
        pt["n"] += 1
        if deadline_slack(r, r.t_finish) >= 0.0:
            n_met += 1
            met_tokens += n
            pt["met"] += 1
        else:
            n_missed += 1
    qwait = metrics.get("engine_queue_wait_seconds")
    report = {
        "admission": admission,
        "n_requests": len(work),
        "n_finished": sum(r.status == FINISHED for r in done),
        "n_failed": sum(r.status != FINISHED for r in done),
        "sim_makespan_s": makespan,
        "ticks": n_ticks,
        "ttft_s": _latency_block(ttft),
        "tpot_s": _latency_block(tpot),
        "deadline": {"met": n_met, "missed": n_missed,
                     "met_rate": n_met / max(n_met + n_missed, 1)},
        "throughput_tok_per_s": total_tokens / max(makespan, 1e-9),
        "goodput_tok_per_s": met_tokens / max(makespan, 1e-9),
        "preemptions": metrics.value("engine_preemptions_total"),
        "resumes": metrics.value("engine_resumes_total"),
        "queue_wait_s": {"p50": qwait.percentile(50.0),
                         "p99": qwait.percentile(99.0)},
        "prefill_tokens": {
            "computed": metrics.value("engine_prefill_tokens_total",
                                      "computed"),
            "reused": metrics.value("engine_prefill_tokens_total",
                                    "reused")},
        "per_tenant": {
            name: {"n": pt["n"], "deadline_met": pt["met"],
                   "ttft_s": _latency_block(pt["ttft"])}
            for name, pt in per_tenant.items()},
    }
    return report


def traffic_model(budget: int = 48):
    """Freshly-initialized serving miniature (scheduling is the signal
    here, not sample quality — no training needed)."""
    cfg = ModelConfig(
        name="traffic-mini", arch_type="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
        dtype="float32",
        lacache=LaCacheConfig(budget=budget, n_sink=2, n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny single-scenario run, print only (CI)")
    ap.add_argument("--n", type=int, default=48,
                    help="requests per scenario (full mode)")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="steady arrival rate, requests per virtual second")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    budget = 48
    cfg, params = traffic_model(budget)
    cost = CostModel()

    if args.smoke:
        work = gen_workload(6, args.seed, "steady", args.rate,
                            cfg.vocab_size)
        for w in work:   # keep the smoke decode loop short
            w["max_new"] = min(w["max_new"], 8)
        rep = run_scenario(cfg, params, work, "deadline", cost,
                           budget=budget)
        print(f"[smoke] steady x deadline: {rep['n_finished']}/"
              f"{rep['n_requests']} finished, "
              f"ttft p50 {rep['ttft_s']['p50']:.3f}s, "
              f"goodput {rep['goodput_tok_per_s']:.1f} tok/s, "
              f"deadline met {rep['deadline']['met']}"
              f"/{rep['n_requests']}")
        return None

    scenarios = {}
    for pattern in ("steady", "bursty"):
        work = gen_workload(args.n, args.seed, pattern, args.rate,
                            cfg.vocab_size)
        for admission in ("fifo", "deadline"):
            key = f"{pattern}_{admission}"
            rep = run_scenario(cfg, params, work, admission, cost,
                               budget=budget)
            scenarios[key] = rep
            print(f"{key:18s} ttft p50/p99 "
                  f"{rep['ttft_s']['p50']:.3f}/{rep['ttft_s']['p99']:.3f}s  "
                  f"tpot p50 {rep['tpot_s']['p50']*1e3:.1f}ms  "
                  f"goodput {rep['goodput_tok_per_s']:6.1f} tok/s "
                  f"(thruput {rep['throughput_tok_per_s']:6.1f})  "
                  f"met {rep['deadline']['met']:2d}/{rep['n_requests']}  "
                  f"preempt {rep['preemptions']:.0f}")
    path = common.write_bench("traffic", {"scenarios": scenarios}, config={
        "n": args.n, "rate": args.rate, "seed": args.seed,
        "budget": budget, "max_batch": 4,
        "cost_model": dataclasses.asdict(cost),
        "tenants": [dict(t) for t in TENANTS],
    })
    print(f"wrote {path}")
    return scenarios


if __name__ == "__main__":
    main()
