"""Roofline analysis (EXPERIMENTS.md §Roofline): three terms per
(arch x shape x mesh) from the dry-run artifacts in results/dryrun/.

  compute    = FLOPs / (chips x 197 TF/s bf16)
  memory     = HBM bytes / (chips x 819 GB/s)
  collective = per-device collective bytes / (2 links x 50 GB/s)

FLOPs/bytes use the analytic models (launch/analytic.py) because XLA's
HloCostAnalysis counts while bodies once (documented in §Dry-run); collective
bytes come from the trip-count-weighted post-SPMD HLO parse
(launch/hlo_analysis.py) and are already per-device quantities.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
LINKS = 2.0  # usable ICI links per chip for the dominant collective dim (v5e 2D torus per axis)


def load_records(pattern: str = "*.json") -> List[Dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def roofline_terms(rec: Dict) -> Dict:
    n = rec["n_devices"]
    fl = rec["analytic_flops"]["total"] / n
    hb = rec["analytic_hbm_bytes"]["total"] / n
    coll = rec["collectives"]["total_bytes"]  # already per-device program
    t_c = fl / PEAK_FLOPS_BF16
    t_m = hb / HBM_BW
    t_x = coll / (LINKS * ICI_BW)
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    useful = rec["model_flops_global"] / max(rec["analytic_flops"]["total"], 1)
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom, "useful_flops_frac": useful,
            "bound_s": max(t_c, t_m, t_x)}


MOVES = {
    ("compute", "train"): "more TP on d_ff / larger per-chip batch won't help — already MXU-bound; next lever is remat policy (drop recompute)",
    ("compute", "prefill"): "attention is the quadratic term: block-sparse or sliding-window prefill, or LaCache streaming-prefill to cut ctx",
    ("compute", "decode"): "decode should not be compute-bound — check per-chip batch; shrink TP degree",
    ("memory", "decode"): "weights+cache streaming bound: LaCache budget directly cuts cache bytes; weight-quantization or larger batch amortizes weights",
    ("memory", "train"): "activation traffic: tighter remat policy / fused attention keeps working set in VMEM",
    ("memory", "prefill"): "KV write + activation traffic: fuse attention, bf16 cache",
    ("collective", "train"): "grad all-reduce dominates: reduce-scatter+bf16 grads, overlap with backprop",
    ("collective", "decode"): "per-step activation all-reduces: shrink TP for small models, or batch more tokens per step",
    ("collective", "prefill"): "all-gather of FSDP weights: prefetch/overlap or switch FSDP->pure TP for prefill",
}


def mode_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


def main(quick: bool = False):
    recs = [r for r in load_records() if r["mesh"] == "16x16"]
    if not recs:
        print("no dry-run records found; run repro.launch.dryrun --all first")
        return {}
    rows = []
    for r in recs:
        t = roofline_terms(r)
        rows.append((r, t))
    rows.sort(key=lambda rt: (rt[0]["shape"], -rt[1]["bound_s"]))
    print(f"{'arch':24s}{'shape':13s}{'compute_s':>11s}{'memory_s':>11s}"
          f"{'collect_s':>11s} {'dominant':>10s} {'useful':>7s}")
    for r, t in rows:
        print(f"{r['arch']:24s}{r['shape']:13s}{t['compute_s']:>11.4g}"
              f"{t['memory_s']:>11.4g}{t['collective_s']:>11.4g}"
              f" {t['dominant']:>10s} {t['useful_flops_frac']:>7.2f}")
    out = {f"{r['arch']}|{r['shape']}|{r['policy']}": t for r, t in rows}
    with open(os.path.join(DRYRUN_DIR, "..", "roofline.json"), "w") as f:
        json.dump(out, f, indent=1)

    worst = min(rows, key=lambda rt: rt[1]["useful_flops_frac"])
    most_coll = max(rows, key=lambda rt: rt[1]["collective_s"]
                    / max(rt[1]["bound_s"], 1e-12))
    from benchmarks.common import emit
    emit("roofline", 0.0,
         f"n_pairs={len(rows)};worst_useful={worst[0]['arch']}/"
         f"{worst[0]['shape']};most_collective={most_coll[0]['arch']}/"
         f"{most_coll[0]['shape']}")
    return out


def markdown_table() -> str:
    recs = [r for r in load_records() if r["mesh"] == "16x16"]
    lines = ["| arch | shape | policy | compute (s) | memory (s) | collective (s) "
             "| dominant | MODEL/HLO useful | next lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["shape"], x["arch"])):
        t = roofline_terms(r)
        lever = MOVES[(t["dominant"], mode_of(r["shape"]))]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['policy']} "
            f"| {t['compute_s']:.4g} | {t['memory_s']:.4g} "
            f"| {t['collective_s']:.4g} | **{t['dominant']}** "
            f"| {t['useful_flops_frac']:.2f} | {lever} |")
    return "\n".join(lines)


if __name__ == "__main__":
    main()
