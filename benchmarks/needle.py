"""Paper Fig. 8/9 (Needle-In-A-Haystack) as (a) an exact retention heatmap —
the fraction of the needle span's KV still cached at query time, per (context
length x needle depth) — and (b) answer NLL on the trained model.

Retention is the mechanism the paper's heatmaps read out: StreamingLLM's
window drops any needle older than the window; the ladder keeps older spans
alive in some layers."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.core import ladder
from repro.data.pipeline import needle_episode
from repro.serving.engine import Engine


def retention_grid(cfg, policy, budget, ctx_lens, depths):
    spec = ladder.LadderSpec(
        n_layers=cfg.n_cache_layers, span=max(1, cfg.n_cache_layers // 4),
        overlap=max(0, cfg.n_cache_layers // 8), chunk=4, n_sink=4,
        n_recent=16, budget=budget)
    grid = np.zeros((len(ctx_lens), len(depths)))
    for i, T in enumerate(ctx_lens):
        sim = ladder.simulate_stream(spec, T, policy=policy)
        for j, d in enumerate(depths):
            span = range(int(d * T * 0.9), min(int(d * T * 0.9) + 12, T))
            kept = np.mean([[p in set(k) for p in span] for k in sim.kept])
            grid[i, j] = kept
    return grid


def answer_nll(cfg, params, policy, budget, T, depth, n=3):
    c = common.with_policy(cfg, policy, budget)
    eng = Engine(c, params, budget=budget)
    co = common.corpus()
    tot = []
    for s in range(n):
        ep = needle_episode(co, T, depth, seed=s)
        toks = np.concatenate([ep["tokens"], ep["answer"]])[None]
        nll = eng.score_stream(toks)
        tot.append(float(nll[:, -len(ep["answer"]):].mean()))
    return float(np.mean(tot))


def main(quick: bool = False):
    cfg, params = common.bench_model()
    # contexts must exceed the budget several-fold, else nothing has been
    # evicted yet and the comparison is vacuous (paper Fig. 8 uses 128k
    # contexts vs small budgets)
    ctx_lens = [384, 768] if quick else [384, 768, 1536, 3072]
    depths = [0.1, 0.3, 0.5, 0.7, 0.9]
    budget = 96
    t0 = time.perf_counter()
    out = {}
    for policy in ("lacache", "streaming"):
        g = retention_grid(cfg, policy, budget, ctx_lens, depths)
        out[f"retention_{policy}"] = g.tolist()
        print(f"{policy} retention grid (rows=ctx {ctx_lens}, "
              f"cols=depth {depths}):")
        for row, T in zip(g, ctx_lens):
            print(f"  T={T:5d}: " + " ".join(f"{v:.2f}" for v in row))
    # trained-model answer NLL at one long context, early needle
    for policy in ("lacache", "streaming"):
        out[f"nll_{policy}"] = answer_nll(cfg, params, policy, budget,
                                          384, 0.2, n=2 if quick else 3)
    print(f"answer NLL (needle at 20% of 384): lacache={out['nll_lacache']:.3f}"
          f" streaming={out['nll_streaming']:.3f}")
    dt = time.perf_counter() - t0
    with open(os.path.join(common.RESULTS, "needle.json"), "w") as f:
        json.dump(out, f, indent=1)

    rl = np.array(out["retention_lacache"]).mean()
    rs = np.array(out["retention_streaming"]).mean()
    common.emit("needle", dt * 1e6, f"mean_retention_lacache={rl:.3f};"
                f"streaming={rs:.3f};nll_gain="
                f"{out['nll_streaming']-out['nll_lacache']:.3f}")
    return out


if __name__ == "__main__":
    main()
