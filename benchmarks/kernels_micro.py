"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness-path
timing only) vs XLA reference implementations; documents the compaction cost
amortization that makes iterative compaction cheap (1/(budget-keep) steps)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops


def main(quick: bool = False):
    rng = np.random.default_rng(0)
    b, s, kv, hd = 4, 1024, 8, 64
    h = 32
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    length = jnp.asarray(s, jnp.int32)

    f_dec = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l,
                                                            impl="xla"))
    us, _ = common.timer(f_dec, q, k, v, length, reps=10)
    common.emit("decode_attention_xla_1k", us[0] * 1e6 if isinstance(us, tuple)
                else us * 1e6, f"batch={b};slots={s}")

    perm = jnp.asarray(rng.permutation(s), jnp.int32)
    f_cmp = jax.jit(lambda x, p: ops.gather_compact(x, p, jnp.asarray(s // 2),
                                                    impl="xla"))
    us2, _ = common.timer(f_cmp, k, perm, reps=10)
    # amortization: one compaction frees ~half the budget -> cost/step is
    # compact_us / (s/2)
    common.emit("ladder_compact_xla_1k", us2 * 1e6,
                f"amortized_us_per_decode_step={us2*1e6/(s/2):.3f}")
    return {"decode_us": us * 1e6, "compact_us": us2 * 1e6}


if __name__ == "__main__":
    main()
