"""Paper Tab. 1 + Tab. 2 structure: token-by-token language-modeling PPL vs
decode length, per cache budget, for {full, StreamingLLM, LaCache} (and H2O).

Claims validated (orderings; absolute values are synthetic-corpus scale):
  * LaCache < StreamingLLM at equal budget across decode lengths,
  * both >= full cache within the trained context,
  * full cache explodes past the trained context (rope extrapolation),
    while budgeted cache-relative policies stay stable,
  * tiny-budget regime (Tab. 2, ~1% of trained context) preserves the gap.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.serving.engine import Engine


def eval_ppl(cfg, params, policy: str, budget: int, lengths: List[int],
             n_seqs: int = 4, rope_mode: str = "cache") -> Dict[int, float]:
    c = common.with_policy(cfg, policy, budget, rope_mode=rope_mode)
    eng = Engine(c, params, budget=budget)
    co = common.corpus()
    T = max(lengths)
    toks = np.stack([co.stream(T, seed=5000 + i) for i in range(n_seqs)])
    nll = eng.score_stream(toks)                       # [n, T-1]
    out = {}
    for L in lengths:
        out[L] = float(np.exp(nll[:, :L - 1].mean()))
    return out


def main(quick: bool = False):
    cfg, params = common.bench_model()
    lengths = [96, 192, 384, 768] if not quick else [96, 192]
    budgets = [96, 48] if not quick else [96]
    rows = {}
    t0 = time.perf_counter()
    # full cache with ORIGINAL positions: shows the >trained-context explosion
    rows["full(orig-rope)"] = eval_ppl(cfg, params, "full", max(lengths),
                                       lengths, rope_mode="original")
    for b in budgets:
        rows[f"streaming({b})"] = eval_ppl(cfg, params, "streaming", b, lengths)
        rows[f"lacache({b})"] = eval_ppl(cfg, params, "lacache", b, lengths)
    if not quick:
        rows["h2o(96)"] = eval_ppl(cfg, params, "h2o", 96, lengths)
        # Tab. 2: tiny budget ~= 1% regime
        rows["streaming(24)"] = eval_ppl(cfg, params, "streaming", 24, lengths)
        rows["lacache(24)"] = eval_ppl(cfg, params, "lacache", 24, lengths)
    dt = time.perf_counter() - t0

    hdr = "policy(budget)".ljust(20) + "".join(f"{L:>10d}" for L in lengths)
    print(hdr)
    for k, v in rows.items():
        print(k.ljust(20) + "".join(f"{v[L]:>10.3f}" for L in lengths))
    os.makedirs(common.RESULTS, exist_ok=True)
    with open(os.path.join(common.RESULTS, "wikitext_ppl.json"), "w") as f:
        json.dump({k: {str(kk): vv for kk, vv in v.items()}
                   for k, v in rows.items()}, f, indent=1)

    Lmax = lengths[-1]
    b0 = budgets[0]
    gain = rows[f"streaming({b0})"][Lmax] - rows[f"lacache({b0})"][Lmax]
    common.emit("wikitext_ppl", dt * 1e6 / max(1, len(rows) * len(lengths)),
                f"lacache_vs_streaming_ppl_gain_at_{Lmax}={gain:.3f}")
    if not quick:
        long_context(cfg, params)
    return rows


def long_context(cfg, params, T: int = 3072, n_seqs: int = 3):
    """Far-beyond-budget regime (16-32x budget; chunked streaming protocol):
    where the ladder's extended span is supposed to earn its keep."""
    import numpy as np
    from repro.serving.engine import Engine
    co = common.corpus()
    toks = np.stack([co.stream(T, seed=7000 + i) for i in range(n_seqs)])
    print(f"\nlong-context regime (T={T}, chunked window 48):")
    out = {}
    for policy, budget in (("streaming", 96), ("lacache", 96),
                           ("streaming", 48), ("lacache", 48)):
        c = common.with_policy(cfg, policy, budget)
        eng = Engine(c, params, budget=budget)
        nll = eng.score_stream_chunked(toks, chunk=48)
        for L in (768, 1536, T):
            out[f"{policy}({budget})@{L}"] = float(np.exp(nll[:, :L - 1].mean()))
        print(f"  {policy}({budget}):  " + "  ".join(
            f"@{L}={out[f'{policy}({budget})@{L}']:.3f}" for L in (768, 1536, T)))
    import json, os
    with open(os.path.join(common.RESULTS, "wikitext_long.json"), "w") as f:
        json.dump(out, f, indent=1)
    g96 = out[f"streaming(96)@{T}"] - out[f"lacache(96)@{T}"]
    g48 = out[f"streaming(48)@{T}"] - out[f"lacache(48)@{T}"]
    common.emit("wikitext_long", 0.0,
                f"gain96_at_{T}={g96:.3f};gain48_at_{T}={g48:.3f}")
    return out


if __name__ == "__main__":
    main()
