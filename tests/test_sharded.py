"""In-process unit tests for the sharded-pool plumbing (mesh/shard lane).

The heavy token-parity sweep lives in ``test_sharded_differential.py``
(it needs the forced 8-way host device count). Everything here runs on
whatever devices exist: the KV-rule spec selection, the loud
non-divisible ValueError (satellite: ``_safe``'s silent replication is
params-only), Engine construction-time validation, the degenerate 1x1
mesh (sharding machinery engaged, single device — still token-exact),
and the ``--mesh`` launcher parser.
"""
import dataclasses
import types

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.kernels.pool_mesh import (PoolMeshSpec, current_pool_mesh,
                                     use_pool_mesh)
from repro.launch import sharding as shardlib
from repro.launch.mesh import make_serving_mesh
from repro.models import model as M
from repro.serving.engine import Engine


def _stub_mesh(data=1, model=1):
    """pool_plane_spec and friends only read ``dict(mesh.shape)``, so a
    namespace stands in for a jax Mesh without touching device state."""
    return types.SimpleNamespace(shape={"data": data, "model": model})


def _cfg(n_kv_heads=2, budget=48):
    return ModelConfig(
        name="t", arch_type="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=n_kv_heads, d_ff=128, vocab_size=128, head_dim=16,
        dtype="float32",
        lacache=LaCacheConfig(budget=budget, n_sink=2, n_recent=8, chunk=2))


# --------------------------------------------------------------------- #
# KV rule -> plane spec selection
# --------------------------------------------------------------------- #
def test_pool_plane_spec_mesh_kv_rule():
    cfg = _cfg(n_kv_heads=4)
    # kv-heads divide the model axis: shard the kv-head axis (bitwise
    # clean, no collective)
    assert shardlib.pool_plane_spec(_stub_mesh(model=2), cfg,
                                    page_size=16) \
        == P(None, None, "model", None)
    # kv-heads don't divide but page_size does: shard in-block slots
    assert shardlib.pool_plane_spec(_stub_mesh(model=8), cfg,
                                    page_size=16) \
        == P(None, "model", None, None)
    # degenerate model axis: replicated planes (single-device routing)
    assert shardlib.pool_plane_spec(_stub_mesh(model=1), cfg,
                                    page_size=16) \
        == P(None, None, None, None)


def test_pool_plane_spec_loud_error_names_mesh_axis():
    """Satellite: non-dividing pool planes must be a loud ValueError that
    names the axis and suggests a divisible page_size/kv_heads pairing —
    never the silent replication ``_safe`` applies to params."""
    cfg = _cfg(n_kv_heads=3)
    with pytest.raises(ValueError) as ei:
        shardlib.pool_plane_spec(_stub_mesh(model=4), cfg, page_size=10)
    msg = str(ei.value)
    assert "'model'" in msg and "kv_heads=3" in msg and "page_size=10" in msg
    # the suggested pairings are the nearest divisible round-ups
    assert "page_size=12" in msg and "kv_heads=4" in msg
    assert "replication" in msg.lower()


def test_paged_pool_mesh_spec_lane_axis_shards_data_mesh():
    cfg = _cfg(n_kv_heads=4)
    pm = shardlib.paged_pool_mesh_spec(_stub_mesh(data=4, model=2), cfg,
                                       page_size=16, max_batch=8)
    assert pm.kv_axis == "model" and pm.slot_axis is None
    assert pm.lane_axis == "data" and pm.sharded
    # max_batch not divisible by data: lanes replicate (small metadata),
    # planes still shard
    pm = shardlib.paged_pool_mesh_spec(_stub_mesh(data=3, model=2), cfg,
                                       page_size=16, max_batch=8)
    assert pm.lane_axis is None and pm.kv_axis == "model"


def test_pool_mesh_registry_is_scoped_shard_dispatch():
    assert current_pool_mesh() is None
    spec = PoolMeshSpec(mesh=None, kv_axis="model")
    with use_pool_mesh(spec):
        assert current_pool_mesh() is spec
        inner = PoolMeshSpec(mesh=None, slot_axis="model")
        with use_pool_mesh(inner):
            assert current_pool_mesh() is inner
        assert current_pool_mesh() is spec
    assert current_pool_mesh() is None
    assert not PoolMeshSpec(mesh=None).sharded


def test_pool_mesh_registry_resets_when_dispatch_raises():
    """A raise mid-dispatch (trace error, OOM, user abort) must unwind
    the registry: the next engine on this thread — possibly unsharded —
    would otherwise trace against a stale mesh (SHD002's scenario)."""
    spec = PoolMeshSpec(mesh=None, kv_axis="model")
    with pytest.raises(RuntimeError, match="mid-dispatch"):
        with use_pool_mesh(spec):
            assert current_pool_mesh() is spec
            raise RuntimeError("mid-dispatch")
    assert current_pool_mesh() is None
    # nested: the inner raise restores the *outer* spec, not None
    outer = PoolMeshSpec(mesh=None, slot_axis="model")
    with use_pool_mesh(outer):
        with pytest.raises(RuntimeError):
            with use_pool_mesh(spec):
                raise RuntimeError("inner")
        assert current_pool_mesh() is outer
    assert current_pool_mesh() is None


# --------------------------------------------------------------------- #
# Engine construction-time validation
# --------------------------------------------------------------------- #
def test_engine_mesh_requires_paged_backend():
    cfg = _cfg()
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="kv_backend='paged'"):
        Engine(cfg, params, budget=48, mesh=_stub_mesh(model=2))


def test_engine_mesh_rejects_store_backed_fallback_archs():
    cfg = _cfg()
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    ineligible = dataclasses.replace(cfg, cross_attention=True)
    assert not M.paged_decode_eligible(ineligible)
    with pytest.raises(ValueError, match="in-model paged decode"):
        Engine(ineligible, params, budget=48, kv_backend="paged",
               mesh=_stub_mesh(model=2))


def test_engine_mesh_nondivisible_raises_at_construction():
    cfg = _cfg(n_kv_heads=3)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="divisible"):
        Engine(cfg, params, budget=48, kv_backend="paged", page_size=10,
               mesh=_stub_mesh(model=4))


def test_engine_degenerate_mesh_single_device_token_exact():
    """A real 1x1 mesh engages the whole placement path (NamedSharding
    plane placement, state device_put, jit wrappers) without requiring
    more than one device; tokens must match the mesh-free engine."""
    cfg = _cfg()
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(5).integers(0, cfg.vocab_size, (3, 18))

    def serve(mesh):
        eng = Engine(cfg, params, budget=48, max_batch=4,
                     kv_backend="paged", page_size=8, mesh=mesh)
        for p in prompts:
            eng.submit(p, 6)
        done = eng.run()
        toks = [r.tokens.tolist() for r in done]
        eng.close()
        return toks

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert serve(mesh) == serve(None)


# --------------------------------------------------------------------- #
# --mesh launcher parsing
# --------------------------------------------------------------------- #
def test_make_serving_mesh_validates_spec():
    for bad in ("4", "4x", "x2", "ax2", "4x2x1", "0x2"):
        with pytest.raises(ValueError):
            make_serving_mesh(bad)
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh(f"{n + 1}x1")
    mesh = make_serving_mesh(f"{n}x1")
    assert dict(mesh.shape) == {"data": n, "model": 1}
