"""End-to-end behaviour tests: decode==train equivalence, policy semantics,
serving engine, training convergence, and the paper's qualitative claims at
miniature scale."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # end-to-end train/decode equivalence

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.data.pipeline import CorpusConfig, SyntheticCorpus, lm_batches, needle_episode
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.serving.engine import Engine
from repro.train import trainer


def tiny_cfg(**kw):
    d = dict(name="t", arch_type="dense", n_layers=4, d_model=64, n_heads=4,
             n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
             dtype="float32",
             lacache=LaCacheConfig(budget=48, n_sink=2, n_recent=8, chunk=2))
    d.update(kw)
    return ModelConfig(**d)


@pytest.fixture(scope="module")
def trained():
    """A tiny model trained enough that PPL comparisons are meaningful."""
    cfg = tiny_cfg(n_layers=4, d_model=96)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=128, seed=3))
    params, hist = trainer.train(
        cfg, params, lm_batches(corpus, 8, 96, 80),
        AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=80), log_every=20,
        log_fn=lambda s: None)
    assert hist["loss"][-1] < hist["loss"][0]
    return cfg, params, corpus


def test_decode_equals_train_with_full_cache(trained):
    cfg, params, corpus = trained
    cfg = dataclasses.replace(cfg, lacache=dataclasses.replace(
        cfg.lacache, policy="full", rope_mode="original"))
    toks = jnp.asarray(corpus.stream(40, seed=5)[None], jnp.int32)
    full = M.forward_train(params, cfg, toks, remat=False)[0]
    last, state = M.prefill(params, cfg, toks[:, :30], n_slots=64)
    errs = [float(jnp.abs(last - full[:, 29]).max())]
    for t in range(30, 40):
        lg, state = M.decode_step(params, cfg, state, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-4


def policy_ppl(cfg, params, corpus, policy, budget, T=320):
    c = dataclasses.replace(cfg, lacache=dataclasses.replace(
        cfg.lacache, policy=policy, budget=budget))
    eng = Engine(c, params, budget=budget)
    toks = np.stack([corpus.stream(T, seed=100 + i) for i in range(2)])
    nll = eng.score_stream(toks)
    return float(nll.mean())


def test_policy_ordering_full_best_budgeted_close(trained):
    """Budgeted policies must not beat full cache, and LaCache should stay
    close to full (the Tab. 1 structure)."""
    cfg, params, corpus = trained
    ppl_full = policy_ppl(cfg, params, corpus, "full", 512, T=200)
    ppl_lad = policy_ppl(cfg, params, corpus, "lacache", 48, T=200)
    ppl_str = policy_ppl(cfg, params, corpus, "streaming", 48, T=200)
    assert ppl_full <= ppl_lad + 0.05
    assert ppl_full <= ppl_str + 0.05
    # ladder should not be catastrophically worse than streaming
    assert ppl_lad < ppl_str + 0.5


def test_generation_deterministic_greedy(trained):
    cfg, params, corpus = trained
    eng = Engine(cfg, params, budget=48)
    prompt = np.stack([corpus.stream(64, seed=9)])
    a = eng.generate(prompt, 12)
    b = eng.generate(prompt, 12)
    np.testing.assert_array_equal(a, b)


def test_engine_unbounded_stream_constant_memory(trained):
    cfg, params, corpus = trained
    eng = Engine(cfg, params, budget=48)
    toks = np.stack([corpus.stream(400, seed=11)])
    state = eng.new_state(1)
    b0 = eng.cache_bytes(state)
    nll = eng.score_stream(toks)                 # 400 >> budget 48
    assert np.isfinite(nll).all()
    assert eng.cache_bytes(eng.new_state(1)) == b0


def test_moe_aux_loss_encourages_balance():
    from repro.models import layers
    from repro.models.common import split_params
    cfg = tiny_cfg(arch_type="moe", n_experts=4, top_k=2, d_ff=64)
    w, _ = split_params(layers.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = layers.moe_ffn(w, cfg, x)
    assert y.shape == x.shape
    # for near-uniform routing, switch aux ~ 1.0; wildly unbalanced >> 1
    assert 0.5 < float(aux) < 4.0


def test_needle_episode_structure():
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=128))
    ep = needle_episode(corpus, 256, depth=0.3, seed=0)
    assert ep["tokens"].shape == (256,)
    s, e = ep["needle_span"]
    assert 0 < s < e < 256
    assert len(ep["answer"]) == 8


def test_data_deterministic():
    c1 = SyntheticCorpus(CorpusConfig(seed=5))
    c2 = SyntheticCorpus(CorpusConfig(seed=5))
    np.testing.assert_array_equal(c1.stream(500, 1), c2.stream(500, 1))
    assert not np.array_equal(c1.stream(500, 1), c1.stream(500, 2))


def test_h2o_uses_scores_and_runs(trained):
    cfg, params, corpus = trained
    ppl = policy_ppl(cfg, params, corpus, "h2o", 48, T=120)
    assert np.isfinite(ppl)
