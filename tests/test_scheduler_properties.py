"""Hypothesis property tests on the request scheduler, admission policies,
and the shared-prefix prompt cache (guarded like test_properties.py: the
suite skips cleanly when hypothesis is not installed)."""
import functools

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.models import model as M
from repro.serving.admission import admission_names
from repro.serving.engine import Engine, Request, Scheduler
from repro.serving.prefix import PrefixCache

BUILTIN_ADMISSIONS = ["fifo", "priority", "deadline"]


def _req(n=4, **kw):
    return Request(prompt=np.arange(n, dtype=np.int32), max_new_tokens=2,
                   **kw)


# --------------------------------------------------------------------------- #
# Scheduler invariants under churn, for every admission policy
# --------------------------------------------------------------------------- #
@given(
    st.sampled_from(BUILTIN_ADMISSIONS),
    st.integers(1, 5),
    st.lists(st.tuples(st.sampled_from(["submit", "admit", "retire"]),
                       st.integers(0, 7), st.integers(0, 100)),
             min_size=1, max_size=60),
)
@settings(max_examples=50, deadline=None)
def test_churn_preserves_slot_conservation(admission, n_slots, ops):
    """Random submit/admit/retire churn: n_running + n_free == n_slots
    always holds, no request is lost, none is served twice."""
    s = Scheduler(n_slots, admission=admission)
    submitted, served = [], []
    for op, pri, dl in ops:
        if op == "submit":
            submitted.append(s.submit(_req(priority=pri, deadline=float(dl))))
        elif op == "admit":
            s.admit()
        elif op == "retire" and s.running:
            served.append(s.retire(sorted(s.running)[0]))
        assert len(s.running) + len(s._free) == s.n_slots
        assert set(s._free).isdisjoint(s.running)
    # drain: everything submitted is served exactly once
    while s.has_work:
        s.admit()
        served.append(s.retire(sorted(s.running)[0]))
        assert len(s.running) + len(s._free) == s.n_slots
    assert {id(r) for r in served} == {id(r) for r in submitted}
    assert len(served) == len(submitted)


@given(st.lists(st.integers(0, 9), min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_priority_admission_order_is_sorted(priorities):
    """Admission order under 'priority' == stable sort by (-priority, seq)."""
    s = Scheduler(len(priorities), admission="priority")
    reqs = [s.submit(_req(priority=p)) for p in priorities]
    admitted = [r for _, r in s.admit()]
    expect = [reqs[i] for _, i in sorted(
        (-r.priority, i) for i, r in enumerate(reqs))]
    assert admitted == expect


@given(st.lists(st.one_of(st.none(), st.floats(0, 100)), min_size=1,
                max_size=20))
@settings(max_examples=50, deadline=None)
def test_deadline_admission_none_sorts_last(deadlines):
    s = Scheduler(len(deadlines), admission="deadline")
    reqs = [s.submit(_req(deadline=d)) for d in deadlines]
    admitted = [r for _, r in s.admit()]
    keys = [(float("inf") if r.deadline is None else r.deadline)
            for r in admitted]
    assert keys == sorted(keys)
    # every submitted request admitted exactly once
    assert {id(r) for r in admitted} == {id(r) for r in reqs}


def test_builtin_admissions_subset_of_registry():
    assert set(BUILTIN_ADMISSIONS) <= set(admission_names())


# --------------------------------------------------------------------------- #
# PrefixCache: longest-match is really longest-match
# --------------------------------------------------------------------------- #
@given(
    st.integers(0, 2**31 - 1),
    st.sets(st.integers(1, 40), min_size=1, max_size=8),
    st.integers(1, 40),
)
@settings(max_examples=60, deadline=None)
def test_prefix_cache_longest_match_property(seed, cached_lengths, qlen):
    base = np.random.default_rng(seed).integers(0, 1000, (40,)).astype(np.int32)
    pc = PrefixCache()
    payload = {"x": np.zeros((2,), np.float32)}
    logits = np.zeros((1, 3), np.float32)
    for length in cached_lengths:
        pc.insert(base[:length], payload, logits)
    hit = pc.lookup(base[:qlen])
    matching = [length for length in cached_lengths if length <= qlen]
    if matching:
        assert hit is not None and hit.length == max(matching)
    else:
        assert hit is None
    # an unrelated query never matches
    assert pc.lookup(base[:max(cached_lengths)] + 1000) is None


# --------------------------------------------------------------------------- #
# Random shared prefixes: prefix-cached prefill == cold prefill logits
# --------------------------------------------------------------------------- #
@functools.lru_cache(maxsize=1)
def _tiny_model():
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=2, d_model=48, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab_size=89, head_dim=12, dtype="float32",
        lacache=LaCacheConfig(budget=64, n_sink=2, n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@given(st.integers(0, 2**31 - 1), st.integers(8, 24), st.integers(1, 8))
@settings(max_examples=3, deadline=None)
def test_random_shared_prefix_logits_match_cold_prefill(seed, plen, slen):
    """Two random requests sharing a random-length prefix: the snapshot the
    warm engine stores for the extended prompt must carry logits identical
    to a cold dense prefill of that prompt (and identical greedy tokens)."""
    import jax.numpy as jnp
    cfg, params = _tiny_model()
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, (plen,))
    full = np.concatenate([pre, rng.integers(0, cfg.vocab_size, (slen,))])

    warm = Engine(cfg, params, budget=64, max_batch=2, prefix_block=8)
    wa = warm.submit(pre, 2, cache_prefix=True)
    wb = warm.submit(full, 2, cache_prefix=True)
    warm.run()
    assert warm.prefix_hit_rate > 0.0

    cold = Engine(cfg, params, budget=64, max_batch=2)
    ca = cold.submit(pre, 2)
    cb = cold.submit(full, 2)
    cold.run()
    np.testing.assert_array_equal(wa.tokens, ca.tokens)
    np.testing.assert_array_equal(wb.tokens, cb.tokens)

    entry = warm.prefix_cache.lookup(full)
    assert entry is not None and entry.length == full.shape[0]
    cold_logits, _ = M.prefill(params, cfg, jnp.asarray(full)[None],
                               n_slots=64)
    np.testing.assert_allclose(np.asarray(entry.logits),
                               np.asarray(cold_logits), atol=1e-4, rtol=1e-4)
