"""Per-assigned-architecture smoke tests (deliverable f).

Each test instantiates the REDUCED variant of the same family (<=2-3 periods,
d_model<=256, <=4 experts) and runs one forward + one train step + one decode
step on CPU, asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # one jitted prefill+decode per assigned architecture

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train import trainer


def _extras(cfg, batch, rng):
    ex = {}
    if cfg.n_patches:
        ex["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_patches, M.PATCH_DIM)), jnp.float32)
    if cfg.encoder_layers:
        ex["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_audio_frames, M.FRAME_DIM)), jnp.float32)
    return ex


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_smoke(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and (cfg.n_experts or 0) <= 4
    rng = np.random.default_rng(0)
    batch, t = 2, 32
    params, axes = M.init(cfg, jax.random.PRNGKey(0))
    # param/axes trees mirror each other
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))

    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, t)), jnp.int32)
    ex = _extras(cfg, batch, rng)
    logits, aux, _ = M.forward_train(params, cfg, toks, remat=False, **ex)
    t_out = t + (cfg.n_patches or 0)
    assert logits.shape == (batch, t_out, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one train step
    step = jax.jit(trainer.make_train_step(cfg, AdamWConfig(total_steps=10)))
    from repro.optim import adamw
    opt = adamw.init(params)
    batch_d = dict(tokens=jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, t + 1)), jnp.int32), **ex)
    params2, _, metrics = step(params, opt, batch_d)
    assert np.isfinite(float(metrics["loss"]))
    g = float(metrics["grad_norm"])
    assert np.isfinite(g) and g > 0

    # prefill + decode step under the arch's lacache defaults
    last, state = M.prefill(params, cfg, toks, n_slots=cfg.lacache.budget, **ex)
    lg, state2 = M.decode_step(params, cfg, state, toks[:, :1])
    assert lg.shape == (batch, cfg.padded_vocab)
    assert not bool(jnp.isnan(lg).any())
    assert int(state2.pos) == int(state.pos) + 1


@pytest.mark.parametrize("arch", ["llama3.2-1b", "jamba-1.5-large-398b",
                                  "gemma3-27b", "falcon-mamba-7b"])
def test_decode_memory_is_constant(arch):
    """Paper's O(1) claim: decode state bytes do not grow with steps."""
    cfg = get_config(arch).reduced()
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    state = M.init_decode_state(params, cfg, 2, cfg.lacache.budget)

    def nbytes(s):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s))

    b0 = nbytes(state)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(lambda p, s, t: M.decode_step(p, cfg, s, t))
    for _ in range(cfg.lacache.budget + 16):   # force >1 compaction
        _, state = step(params, state, tok)
    assert nbytes(state) == b0


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    import repro.configs as C
    want = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155, 32, 8),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936, 0, 0),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072, 8, 2),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064, 0, 0),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024, 0, 0),
        "whisper-small": (12, 768, 12, 12, 3072, 51865, 0, 0),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256, 0, 0),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536, 16, 2),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144, 0, 0),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152, 0, 0),
    }
    for arch, (L, d, h, kv, ff, v, e, k) in want.items():
        cfg = C.get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size, cfg.n_experts, cfg.top_k)
        assert got == (L, d, h, kv, ff, v, e, k), (arch, got)
    assert C.get_config("falcon-mamba-7b").attn_every == -1
    assert C.get_config("jamba-1.5-large-398b").attn_every == 8
    assert C.get_config("gemma3-27b").local_global_pattern == 5
    assert C.get_config("qwen1.5-110b").qkv_bias
    assert C.get_config("qwen2-vl-2b").mrope
    assert C.get_config("whisper-small").cross_attention
