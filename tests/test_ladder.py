"""Property tests for the ladder pattern math (paper Sec. 3.2/3.3)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ladder
from repro.core.ladder import LadderSpec


def make_spec(L, S, O, C=4, sink=2, recent=8, budget=64):
    return LadderSpec(n_layers=L, span=S, overlap=O, chunk=C,
                      n_sink=sink, n_recent=recent, budget=budget)


spec_strategy = st.integers(2, 32).flatmap(
    lambda L: st.integers(1, L).flatmap(
        lambda S: st.tuples(st.just(L), st.just(S),
                            st.integers(0, max(0, S - 1)),
                            st.integers(1, 8))))


@given(spec_strategy, st.integers(0, 31))
@settings(max_examples=60, deadline=None)
def test_keep_mask_invariants(lso, layer):
    L, S, O, C = lso
    layer = layer % L
    spec = make_spec(L, S, O, C)
    length = 60
    mask = ladder.ladder_keep_mask_np(spec, length, layer)
    # sinks always kept
    assert mask[:spec.n_sink].all()
    # recent window always kept
    assert mask[length - spec.n_recent:].all()


@given(spec_strategy)
@settings(max_examples=40, deadline=None)
def test_every_token_kept_somewhere(lso):
    """Band extension (footnote 1) -> no token chunk is dropped from ALL
    layers by a single pass: every rung's band is non-empty and within [0,L)."""
    L, S, O, C = lso
    spec = make_spec(L, S, O, C)
    for r in range(spec.n_rungs):
        lo = r * spec.stride
        hi = min(lo + spec.span, L) if r < spec.n_rungs - 1 else L
        assert 0 <= lo < L and lo < hi <= L


@given(spec_strategy)
@settings(max_examples=30, deadline=None)
def test_coverage_near_equal(lso):
    """Rationale 1: per-layer coverage of the middle region is near-equal
    (within one rung's worth of chunks per ladder period)."""
    L, S, O, C = lso
    spec = make_spec(L, S, O, C, sink=0, recent=0)
    W = spec.n_rungs * C
    cov = []
    for l in range(L):
        mask = ladder.ladder_keep_mask_np(spec, W, l)
        cov.append(mask.sum())
    cov = np.array(cov)
    # every layer covers >= 1 chunk and <= ceil(S/stride)+1 chunks
    assert (cov >= C).all()
    import math
    assert (cov <= (math.ceil(spec.span / spec.stride) + 2) * C).all()


def test_compaction_perm_stable_order():
    import jax.numpy as jnp
    keep = jnp.array([True, False, True, True, False, True])
    perm, n = ladder.compaction_perm(keep)
    assert int(n) == 4
    assert perm[:4].tolist() == [0, 2, 3, 5]  # age order preserved


def test_simulate_stream_budget_never_exceeded():
    spec = make_spec(L=8, S=2, O=1, C=2, sink=2, recent=4, budget=24)
    sim = ladder.simulate_stream(spec, 400)
    assert (sim.coverage() <= spec.budget).all()
    assert min(sim.compactions) >= 1


def test_ladder_span_extends_beyond_streaming():
    """The paper's core claim: same budget -> ladder retains a strictly
    longer union of past positions than the recency window."""
    spec = make_spec(L=16, S=4, O=2, C=2, sink=2, recent=8, budget=32)
    lad = ladder.simulate_stream(spec, 600, policy="lacache")
    stream = ladder.simulate_stream(spec, 600, policy="streaming")
    assert lad.union_span() > stream.union_span()
    # and older tokens survive somewhere in the ladder
    oldest_lad = min(min(k) for k in lad.kept)
    oldest_str = min(min(k) for k in stream.kept)
    assert oldest_lad <= oldest_str


def test_iterative_compaction_thins_older_tokens_more():
    """Fig. 4: after many steps, retention (fraction of layers holding a
    token) is non-increasing in token age, up to chunk granularity."""
    spec = make_spec(L=8, S=2, O=0, C=2, sink=2, recent=8, budget=32)
    sim = ladder.simulate_stream(spec, 500)
    ret = [sim.retention_of(p) for p in [50, 200, 350, 470]]
    assert ret[0] <= ret[-1] + 1e-9
    assert ret[-1] > 0  # recent fully retained


def test_streaming_mask_is_pure_recency():
    import jax.numpy as jnp
    spec = make_spec(L=4, S=2, O=0, C=2, sink=2, recent=4, budget=16)
    m = np.asarray(ladder.streaming_keep_mask(spec, 16, jnp.asarray(16), 0))
    kept = np.where(m)[0]
    assert set(kept[:2]) == {0, 1}           # sinks
    assert (np.diff(kept[2:]) == 1).all()    # contiguous recent suffix
    assert kept[-1] == 15
