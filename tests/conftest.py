"""Shared test config.

``jax.clear_caches()`` between modules: a single pytest process otherwise
accumulates hundreds of jitted executables (property sweeps + per-arch smoke
+ pallas interpret kernels) until XLA's CPU ORC JIT fails with
"Failed to materialize symbols" / MemoryError late in the run.
"""
import jax
import pytest


def pytest_configure(config):
    # registered here (not pytest.ini) so the mark works without the
    # pytest-timeout plugin installed; with the plugin it enforces.
    config.addinivalue_line(
        "markers",
        "timeout(seconds): soft per-test time budget (enforced only when "
        "pytest-timeout is installed)")
    config.addinivalue_line(
        "markers",
        "slow: long-running test excluded from the CI fast lane "
        "(-m 'not slow'); the full tier-1 job still runs it")


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
