"""decode_chunk (streaming prefill) equivalence + TOVA policy + microbatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # chunk-vs-stepwise sweeps dominate suite wall time

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.models import model as M


def cfg_for(kind):
    base = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=97,
                head_dim=16, dtype="float32",
                lacache=LaCacheConfig(budget=256, policy="full",
                                      rope_mode="cache"))
    if kind == "dense":
        return ModelConfig(name="d", arch_type="dense", n_layers=3, **base)
    if kind == "hybrid":
        return ModelConfig(name="h", arch_type="hybrid", n_layers=8,
                           attn_every=4, **base)
    if kind == "localglobal":
        return ModelConfig(name="g", arch_type="dense", n_layers=6,
                           local_global_pattern=2, sliding_window=8, **base)
    raise ValueError(kind)


@pytest.mark.parametrize("kind", ["dense", "hybrid", "localglobal"])
def test_decode_chunk_equals_stepwise(kind):
    cfg = cfg_for(kind)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0, 97)
    st = M.init_decode_state(params, cfg, 2, 256)
    step_logits = []
    for t in range(40):
        lg, st = M.decode_step(params, cfg, st, toks[:, t:t + 1])
        step_logits.append(lg)
    L1 = jnp.stack(step_logits, axis=1)
    st2 = M.init_decode_state(params, cfg, 2, 256)
    lgA, st2 = M.decode_chunk(params, cfg, st2, toks[:, :25])
    lgB, st2 = M.decode_chunk(params, cfg, st2, toks[:, 25:])
    L2 = jnp.concatenate([lgA, lgB], axis=1)
    np.testing.assert_allclose(np.asarray(L1), np.asarray(L2),
                               atol=1e-4, rtol=1e-4)


def test_chunked_scoring_matches_stepwise_under_lacache():
    cfg = dataclasses.replace(
        cfg_for("dense"),
        lacache=LaCacheConfig(budget=48, n_sink=2, n_recent=8, chunk=2,
                              policy="lacache"))
    from repro.serving.engine import Engine
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, budget=48)
    toks = np.random.default_rng(0).integers(0, 97, (1, 200))
    nc = eng.score_stream_chunked(toks, chunk=25)
    ns = eng.score_stream(toks)
    assert np.isfinite(nc).all()
    # identical semantics modulo intra-chunk compaction timing
    assert abs(nc.mean() - ns.mean()) < 0.05


def test_chunked_scoring_ragged_tail_full_policy_exact():
    """Regression: the ragged tail chunk must not pad-append past the slot
    buffer — under the non-evicting full policy that overflow used to
    corrupt live slots and silently skew the final chunk's NLL."""
    T = 130                                        # 129 = 2*48 + ragged 33
    cfg = dataclasses.replace(
        cfg_for("dense"),
        lacache=LaCacheConfig(budget=T, policy="full", rope_mode="original"))
    from repro.serving.engine import Engine
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, budget=T)
    toks = np.random.default_rng(0).integers(0, 97, (1, T))
    nc = eng.score_stream_chunked(toks, chunk=48)
    ns = eng.score_stream(toks)
    np.testing.assert_allclose(nc, ns, atol=1e-4, rtol=1e-4)


def test_tova_policy_evicts_by_last_attention():
    import repro.core.cache as cachelib
    from repro.core.ladder import LadderSpec
    spec = LadderSpec(n_layers=4, span=1, overlap=0, chunk=2, n_sink=2,
                      n_recent=4, budget=24)
    c = cachelib.init_cache(1, 24, 1, 4, jnp.float32, with_scores=True)
    k = jnp.ones((1, 24, 1, 4))
    c = cachelib.append(c, k, k, jnp.arange(24))
    probs = jnp.zeros((1, 1, 1, 24)).at[..., 10].set(0.9)
    c = cachelib.set_scores(c, probs)      # TOVA: last-step attention only
    c2 = cachelib.compact(c, spec, 0, "tova")
    kept = set(np.asarray(c2.pos[: int(c2.length)]).tolist())
    assert 10 in kept


def test_microbatched_train_step_matches_full_batch():
    from repro.optim import adamw
    from repro.train import trainer
    cfg = cfg_for("dense")
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    ocfg = adamw.AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (8, 33),
                                          0, 97)}
    s1 = jax.jit(trainer.make_train_step(cfg, ocfg, microbatches=1))
    s4 = jax.jit(trainer.make_train_step(cfg, ocfg, microbatches=4))
    opt = adamw.init(params)
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, adamw.init(params), batch)
    # same gradients (up to accumulation-order fp noise) => same update
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-5, d
