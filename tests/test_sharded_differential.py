"""Differential leg (g): sharded paged serving == single-device paged.

The forced host device count (``--xla_force_host_platform_device_count=8``)
must be set before jax initializes its backends, so these tests exec
``tests/sharded_worker.py`` in a fresh interpreter — EXCEPT when the
current process already sees >= 8 devices (the CI mesh lane exports the
flag), in which case the worker module runs in-process and the sweep
shares this process's jit caches.

The worker runs both engines of every case in one process and asserts:

* token-for-token parity (kv-head-sharded planes are bitwise clean — each
  shard computes its own query-head group end to end),
* free-list conservation mid-serve (every block free or referenced),
* a zero-leak ``close()`` (the shutdown audit runs in every case; the
  dedicated sanitizer case re-runs one config with ``REPRO_SANITIZE=1``
  so a violation reports per-block allocation sites),
* per-device plane bytes exactly 1/model-axis of the single-device pool.

Fast lane: one config each for the XLA and Pallas (shard_map) kernel
routes. The slow leg sweeps the full {fifo,deadline} x
{global,ring,hybrid} x {compaction on,off} matrix.
"""
import json
import os
import subprocess
import sys

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKER = os.path.join(REPO, "tests", "sharded_worker.py")
MESH = [4, 2]


def _run_worker(cases, *, impl=None, env_extra=None, timeout=1200):
    """Run the leg-(g) worker over ``cases``; in-process when this
    process already has the forced device count (CI mesh lane)."""
    spec = {"cases": cases, "mesh": MESH, "impl": impl}
    if (env_extra is None and impl is None
            and len(jax.devices()) >= MESH[0] * MESH[1]):
        sys.path.insert(0, os.path.dirname(WORKER))
        try:
            import sharded_worker
            return {"ok": True,
                    "cases": [sharded_worker.run_case(c, MESH)
                              for c in cases]}
        finally:
            sys.path.pop(0)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)      # the worker sets the device count
    env.update(env_extra or {})
    out = subprocess.run(
        [sys.executable, WORKER, json.dumps(spec)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)
    assert out.returncode == 0, \
        f"worker failed:\nstdout: {out.stdout}\nstderr: {out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_differential_fast_mesh_leg():
    """(g) fast: one representative config through the XLA route, plus
    free-list conservation and the 1/model per-device plane footprint
    (asserted inside the worker)."""
    res = _run_worker([{"kind": "global", "admission": "fifo",
                        "compaction": True}])
    assert res["ok"] and res["cases"][0]["tokens_match"]
    b = res["cases"][0]["bytes_per_device"]
    assert b["sharded"] * MESH[1] == b["single"]


def test_sharded_pallas_shard_map_mesh_smoke():
    """(g) the Pallas kernel route: shard_map carries the scalar-prefetch
    paged kernel per shard; still token-for-token vs single-device."""
    res = _run_worker([{"kind": "global", "admission": "fifo",
                        "compaction": True}], impl="pallas")
    assert res["ok"] and res["cases"][0]["tokens_match"]


def test_sharded_sanitizer_zero_leak_close_mesh():
    """(g) REPRO_SANITIZE=1 on a mesh engine: lane lifecycle checks every
    tick plus the shutdown audit with per-block allocation sites — close()
    must drain the pool to exactly the lane-owned reserve."""
    res = _run_worker([{"kind": "hybrid", "admission": "fifo",
                        "compaction": True}],
                      env_extra={"REPRO_SANITIZE": "1"})
    assert res["ok"] and res["cases"][0]["tokens_match"]


@pytest.mark.slow
@pytest.mark.parametrize("compaction", [False, True],
                         ids=["no-compaction", "compaction"])
@pytest.mark.parametrize("admission", ["fifo", "deadline"])
def test_sharded_differential_full_mesh_matrix(admission, compaction):
    """(g) full: {fifo,deadline} x {global,ring,hybrid} x compaction
    on/off — sharded == single-device token-for-token everywhere. One
    worker invocation per (admission, compaction) cell batches the three
    architectures to amortize interpreter + compile startup."""
    cases = [{"kind": kind, "admission": admission, "compaction": compaction}
             for kind in ("global", "ring", "hybrid")]
    res = _run_worker(cases)
    assert res["ok"]
    assert all(c["tokens_match"] for c in res["cases"])
