"""In-model paged decode for ring-window / SSM / hybrid stacks.

Coverage, bottom-up:

* **eligibility** — ``paged_decode_eligible`` admits ring-window, pure-SSM
  and hybrid configs (and still rejects cross-attention / M-RoPE),
* **ring table ops** — ``paged_ring_append`` vs the dense ring oracle
  through the wrap boundary (``pos == w-1 -> w -> w+1``), plus a
  hypothesis churn property extending the fork/splice protocol of
  ``tests/test_paged.py`` to ring lanes (refcount conservation, CoW
  isolation of forked snapshots),
* **kernel** — the windowed paged-decode dispatch: Pallas (interpret),
  the ring oracle and the dense ring-mask reference agree,
* **engine** — wrap-boundary decode through both backends token-for-token,
  hybrid snapshot byte accounting (ring metadata + SSM states charged, the
  LRU residency identity holds under any eviction order), and bucketed
  prefill for SSM/hybrid stacks via the pad-masked scan.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.core import paged
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import layers as L
from repro.models import model as M
from repro.serving.engine import Engine

KVH, HD = 2, 8
W, BS = 6, 4          # ring window / pool block size for the table tests


def base_cfg(**kw) -> ModelConfig:
    d = dict(name="t", arch_type="dense", n_layers=2, d_model=32, n_heads=2,
             n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8,
             dtype="float32",
             lacache=LaCacheConfig(budget=24, n_sink=2, n_recent=4, chunk=2))
    d.update(kw)
    return ModelConfig(**d)


def ring_cfg(**kw):
    return base_cfg(local_global_pattern=1, sliding_window=W, **kw)


def ssm_cfg(**kw):
    return base_cfg(arch_type="ssm", attn_every=-1, d_state=8, d_conv=3, **kw)


def hybrid_cfg(**kw):
    # mamba(0), local-attn(1), mamba(2), global-attn(3): all three kinds
    return base_cfg(arch_type="hybrid", attn_every=2, n_layers=4,
                    local_global_pattern=3, sliding_window=W,
                    d_state=8, d_conv=3, **kw)


# --------------------------------------------------------------------------- #
# Eligibility matrix
# --------------------------------------------------------------------------- #
def test_paged_decode_eligible_covers_ring_ssm_hybrid():
    """The acceptance gate: every layer kind has a paged representation, so
    only cross-attention and M-RoPE remain on the store-backed fallback."""
    assert M.paged_decode_eligible(base_cfg())
    assert M.paged_decode_eligible(ring_cfg())
    assert M.paged_decode_eligible(ssm_cfg())
    assert M.paged_decode_eligible(hybrid_cfg())
    assert not M.paged_decode_eligible(base_cfg(mrope=True))
    assert not M.paged_decode_eligible(base_cfg(cross_attention=True,
                                                encoder_layers=2))


# --------------------------------------------------------------------------- #
# Ring table ops: wrap boundary + churn vs the dense ring oracle
# --------------------------------------------------------------------------- #
def _fresh_ring_lane(n_blocks=48):
    store = paged.PagedStateStore(n_blocks, BS, KVH, HD, jnp.float32)
    mb = paged.blocks_for(W, BS)
    owned = store.alloc_blocks(mb)
    kv = paged.PoolKV(k=store.pool.k, v=store.pool.v)
    st = paged.PagedRingCache(
        blocks=jnp.full((1, mb), -1, jnp.int32),
        owned=jnp.asarray(owned, jnp.int32)[None],
        pos=jnp.full((1, W), -1, jnp.int32),
        next_pos=jnp.zeros((1,), jnp.int32))
    return store, kv, st


def _check_ring_oracle(kv, st, oracle):
    """Gathered paged ring view == dense ring buffer at every live slot;
    metadata identical everywhere."""
    gk, gv = paged.paged_gather_view(kv, st, W)
    opos = np.asarray(oracle.pos)
    np.testing.assert_array_equal(np.asarray(st.pos[0]), opos)
    assert int(st.next_pos[0]) == int(oracle.next_pos)
    live = opos >= 0
    np.testing.assert_array_equal(np.asarray(gk[0])[live],
                                  np.asarray(oracle.k[0])[live])
    np.testing.assert_array_equal(np.asarray(gv[0])[live],
                                  np.asarray(oracle.v[0])[live])


def test_ring_append_wrap_boundary_matches_dense():
    """Appends driven through pos == w-1 -> w -> w+1: the wrap overwrites
    slot 0 then slot 1, the table stays mapped to the occupied prefix, and
    the gathered view equals the dense ring buffer at every step."""
    rng = np.random.default_rng(0)
    store, kv, st = _fresh_ring_lane()
    oracle = L.init_ring_cache(1, W, KVH, HD, jnp.float32)
    for step in range(W + 3):          # crosses the wrap by 3 slots
        kn = jnp.asarray(rng.normal(size=(1, 1, KVH, HD)), jnp.float32)
        vn = jnp.asarray(rng.normal(size=(1, 1, KVH, HD)), jnp.float32)
        kv, st = paged.paged_ring_append(kv, st, kn, vn)
        oracle = L.ring_append(oracle, kn, vn)
        _check_ring_oracle(kv, st, oracle)
        paged.check_invariants(store.pool)
    # wrapped: every slot occupied, positions cover the last W appends
    assert (np.asarray(st.pos[0]) >= 0).all()
    assert sorted(np.asarray(st.pos[0]).tolist()) == list(range(3, W + 3))
    store.release_blocks(np.asarray(st.owned[0]))
    paged.check_invariants(store.pool)
    assert paged.blocks_in_use(store.pool) == 0


def _run_ring_ops(ops):
    """Drive one lane's paged ring through a random interleaving of
    append/fork/splice while mirroring every mutation on a dense
    RingKVCache oracle and the engine's host-side refcount protocol —
    the ring extension of ``tests/test_paged.py::_run_inmodel_ops``."""
    rng = np.random.default_rng(31)
    mb = paged.blocks_for(W, BS)
    store, kv, st = _fresh_ring_lane()
    oracle = L.init_ring_cache(1, W, KVH, HD, jnp.float32)
    lane_shared = np.zeros((0,), np.int64)
    snaps = []   # (blocks, pos, next_pos, gathered k, gathered v)

    for name, arg in ops:
        if name == "append":
            for _ in range(max(1, arg % 4)):
                kn = jnp.asarray(rng.normal(size=(1, 1, KVH, HD)),
                                 jnp.float32)
                vn = jnp.asarray(rng.normal(size=(1, 1, KVH, HD)),
                                 jnp.float32)
                kv, st = paged.paged_ring_append(kv, st, kn, vn)
                oracle = L.ring_append(oracle, kn, vn)
        elif name == "fork":
            # engine-style refcount fork: the snapshot holds every mapped
            # block; the lane's owned mapped blocks swap for fresh reserves
            blocks = np.asarray(st.blocks[0])
            ownd = np.asarray(st.owned[0])
            mapped = blocks >= 0
            swap = mapped & (blocks == ownd)
            try:
                fresh = store.alloc_blocks(int(swap.sum()))
            except paged.PoolExhausted:
                continue
            new_owned = ownd.copy()
            new_owned[swap] = fresh
            store.retain_blocks(blocks[mapped])
            lane_shared = np.concatenate([lane_shared, blocks[swap]])
            st = st._replace(owned=jnp.asarray(new_owned, jnp.int32)[None])
            gk, gv = paged.paged_gather_view(kv, st, W)
            snaps.append((blocks.copy(), np.asarray(st.pos[0]).copy(),
                          int(st.next_pos[0]), np.asarray(gk[0]).copy(),
                          np.asarray(gv[0]).copy()))
        elif name == "splice" and snaps:
            sblocks, spos, snext, sk, sv = snaps[arg % len(snaps)]
            store.release_blocks(lane_shared)
            ids = sblocks[sblocks >= 0]
            store.retain_blocks(ids)
            lane_shared = ids.astype(np.int64).copy()
            st = st._replace(blocks=jnp.asarray(sblocks, jnp.int32)[None],
                             pos=jnp.asarray(spos, jnp.int32)[None],
                             next_pos=jnp.asarray([snext], jnp.int32))
            oracle = L.RingKVCache(
                k=jnp.asarray(sk, jnp.float32)[None],
                v=jnp.asarray(sv, jnp.float32)[None],
                pos=jnp.asarray(spos, jnp.int32),
                next_pos=jnp.asarray(snext, jnp.int32))
        _check_ring_oracle(kv, st, oracle)
        paged.check_invariants(store.pool)

    # CoW isolation: every forked snapshot's live view is intact
    for sblocks, spos, snext, sk, sv in snaps:
        view = paged.PagedRingCache(
            blocks=jnp.asarray(sblocks, jnp.int32)[None], owned=st.owned,
            pos=jnp.asarray(spos, jnp.int32)[None],
            next_pos=jnp.asarray([snext], jnp.int32))
        gk, gv = paged.paged_gather_view(kv, view, W)
        live = spos >= 0
        np.testing.assert_array_equal(np.asarray(gk[0])[live], sk[live])
        np.testing.assert_array_equal(np.asarray(gv[0])[live], sv[live])

    store.release_blocks(lane_shared)
    store.release_blocks(np.asarray(st.owned[0]))
    for sblocks, *_ in snaps:
        store.release_blocks(sblocks[sblocks >= 0])
    paged.check_invariants(store.pool)
    assert paged.blocks_in_use(store.pool) == 0


def test_ring_table_churn_deterministic():
    """A fixed, branch-covering interleaving (runs without hypothesis):
    warmup -> fork -> CoW append over the shared wrap slot -> splice back
    -> append over the spliced (shared) table -> second fork/splice."""
    _run_ring_ops([
        ("append", 3), ("append", 3), ("fork", 0), ("append", 2),
        ("append", 3), ("fork", 1), ("splice", 0), ("append", 1),
        ("splice", 1), ("append", 2),
    ])


def test_ring_table_invariants_random_churn():
    """Hypothesis: random append/fork/splice interleavings on a live paged
    ring never double-free, never leak, match the dense ring oracle after
    every op, and never corrupt a forked snapshot (CoW isolation)."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st_

    op = st_.tuples(st_.sampled_from(["append", "fork", "splice"]),
                    st_.integers(0, 11))

    @settings(max_examples=25, deadline=None)
    @given(st_.lists(op, min_size=1, max_size=20))
    def run(ops):
        _run_ring_ops(ops)

    run()


# --------------------------------------------------------------------------- #
# Kernel: windowed paged decode dispatch vs oracle vs dense ring mask
# --------------------------------------------------------------------------- #
def _ring_layout(rng, b, next_pos):
    """Random per-lane rings satisfying the residue invariant, scattered
    into a shuffled pool."""
    mb = paged.blocks_for(W, BS)
    n_blocks = b * mb + 2
    pool_k = jnp.asarray(rng.normal(size=(n_blocks, BS, KVH, HD)),
                         jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(n_blocks, BS, KVH, HD)),
                         jnp.float32)
    perm = rng.permutation(n_blocks)
    tables = np.full((b, mb), -1, np.int32)
    pos = np.full((b, W), -1, np.int32)
    pi = 0
    for bi in range(b):
        occ = min(int(next_pos[bi]), W)
        for j in range(-(-occ // BS)):
            tables[bi, j] = int(perm[pi]); pi += 1
        for j in range(occ):
            last = int(next_pos[bi]) - 1
            pos[bi, j] = last - ((last - j) % W)
    # dense view for the reference mask computation
    ids = np.clip(tables, 0, None)
    kd = np.asarray(pool_k)[ids].reshape(b, mb * BS, KVH, HD)[:, :W]
    vd = np.asarray(pool_v)[ids].reshape(b, mb * BS, KVH, HD)[:, :W]
    return (pool_k, pool_v, jnp.asarray(tables), jnp.asarray(pos),
            jnp.asarray(kd), jnp.asarray(vd))


def test_paged_ring_kernel_matches_oracle_and_dense_mask():
    """The windowed paged-decode dispatch: Pallas (interpret) == the ring
    oracle == the dense ring-mask reference on the same KV, to <= 1e-5."""
    rng = np.random.default_rng(5)
    b = 3
    next_pos = jnp.asarray([3, W, W + 5], jnp.int32)   # warmup/wrap/wrapped
    q = jnp.asarray(rng.normal(size=(b, 4, HD)), jnp.float32)  # h=4, g=2
    pk, pv, tables, pos, kd, vd = _ring_layout(rng, b, np.asarray(next_pos))
    ref = kref.paged_ring_attention_reference(q, pk, pv, tables, pos,
                                              next_pos, window=W)
    pal = kops.paged_ring_decode_attention(q, pk, pv, tables, pos, next_pos,
                                           window=W, impl="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    # dense ring-mask reference per lane (the mask the dense decode applies)
    valid = (pos >= 0) & (pos > (next_pos - 1 - W)[:, None]) \
        & (pos <= (next_pos - 1)[:, None])
    dense = kref.mha_reference(q[:, None], kd, vd, causal=False,
                               kv_valid=valid)[:, 0]
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dense),
                               atol=1e-6, rtol=1e-6)


# --------------------------------------------------------------------------- #
# Engine: wrap-boundary serving, accounting, bucketed SSM/hybrid prefill
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(mk):
        if mk not in cache:
            cfg = {"ring": ring_cfg, "ssm": ssm_cfg,
                   "hybrid": hybrid_cfg}[mk]()
            params, _ = M.init(cfg, jax.random.PRNGKey(0))
            cache[mk] = (cfg, params)
        return cache[mk]

    return get


def test_ring_wrap_boundary_dense_vs_paged_serving(models):
    """A prompt of length w-1 decoded 4 tokens appends at positions
    w-1 -> w -> w+1 (the wrap overwrites slot 0 then slot 1) through both
    backends; tokens must agree at every step and the paged engine must
    have decoded through ring residue tables."""
    cfg, params = models("ring")
    prompt = np.random.default_rng(11).integers(0, cfg.vocab_size, (W - 1,))

    def serve(kv_backend):
        eng = Engine(cfg, params, budget=24, max_batch=1,
                     kv_backend=kv_backend)
        req = eng.submit(prompt, 4)
        eng.run()
        return eng, req.tokens

    _, dense_toks = serve("dense")
    eng, paged_toks = serve("paged")
    np.testing.assert_array_equal(paged_toks, dense_toks)
    ring_leaves = [v for v in list(eng._slot_states.blocks.values())
                   + list(eng._slot_states.tail.values())
                   if isinstance(v, paged.PagedRingCache)]
    assert ring_leaves
    # prompt (w-1) then 3 decode appends (the 4th token samples without an
    # append): positions w-1, w, w+1 went through the ring — the wrap
    assert int(np.asarray(ring_leaves[0].next_pos).max()) == W + 2
    assert all(not isinstance(v, L.RingKVCache)
               for v in list(eng._slot_states.blocks.values())
               + list(eng._slot_states.tail.values()))


def test_hybrid_snapshot_accounting_charges_ring_and_ssm(models):
    """Satellite-bugfix regression: hybrid TableSnapshots must charge ring
    metadata AND whole SSM states as dense bytes (under-charging them would
    let the LRU keep hybrid entries long past their real footprint), and
    the residency identity nbytes == resident-blocks + dense overhead must
    hold through any eviction order."""
    from repro.serving.prefix import tree_bytes
    cfg, params = models("hybrid")
    eng = Engine(cfg, params, budget=24, max_batch=1, kv_backend="paged")
    prompt = np.random.default_rng(13).integers(0, cfg.vocab_size, (40,))
    eng.submit(prompt, 2, cache_prefix=True)
    eng.run()
    pc, store = eng.prefix_cache, eng.kv_store
    assert len(pc) >= 2
    # every snapshot layer set carries all three kinds, and SSM/ring bytes
    # are part of the charge
    n_mamba = sum(1 for s in cfg.layer_specs() if s.kind == "mamba")
    ssm_bytes = n_mamba * (
        (cfg.d_conv - 1) * cfg.d_inner * 4 + cfg.d_inner * cfg.d_state * 4)
    for e in pc._entries.values():
        kinds = {layer.get("kind") for sec in e.snap.tables.values()
                 for layer in sec.values()}
        assert kinds == {"kv", "ring", "ssm"}
        assert e.snap.dense_bytes > ssm_bytes
        assert e.nbytes >= e.snap.dense_bytes

    def attributable():
        return store.bytes_in_use - eng.lane_owned_bytes + sum(
            e.snap.dense_bytes + tree_bytes(e.logits)
            for e in pc._entries.values())

    assert pc.nbytes == attributable()
    while len(pc) > 0:
        assert pc.evict_lru()
        assert pc.nbytes == attributable()
        paged.check_invariants(store.pool)
    assert pc.nbytes == 0
    assert store.bytes_in_use == eng.lane_owned_bytes


@pytest.mark.parametrize("mk", ["ssm", "hybrid"])
def test_bucketed_prefill_ssm_hybrid_exact(mk, models):
    """Bucketed prefill via the pad-masked scan: padded dispatches with a
    traced true_len produce token streams identical to exact-length
    prefill for SSM and hybrid stacks, while actually sharing bucket
    shapes across distinct prompt lengths."""
    cfg, params = models(mk)
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n in (5, 9, 13)]
    outs = {}
    for bucket in (False, True):
        eng = Engine(cfg, params, budget=24, max_batch=2,
                     bucket_prefill=bucket, min_bucket=8)
        assert eng.bucket_prefill == bucket   # _can_bucket admits SSM now
        reqs = [eng.submit(p, 4) for p in prompts]
        eng.run()
        outs[bucket] = [r.tokens for r in reqs]
        if bucket:
            shapes = {s for k, s in eng.prefill_shapes if k == "prefill"}
            assert shapes == {8, 16}          # 3 lengths -> 2 buckets
    for exact, padded in zip(outs[False], outs[True]):
        np.testing.assert_array_equal(padded, exact)


def test_mamba_train_pad_masked_scan_freezes_state():
    """Unit check of the pad-masked scan: with true_len = t_real, the
    padded forward's final MambaState (ssm + conv window) equals the
    unpadded forward's, and real-position outputs are identical."""
    cfg = ssm_cfg()
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(19)
    t_real, t_pad = 9, 16
    toks = rng.integers(0, cfg.vocab_size, (1, t_real))
    padded = np.zeros((1, t_pad), np.int64)
    padded[:, :t_real] = toks
    logits_a, state_a = M.prefill(params, cfg, jnp.asarray(toks), n_slots=24)
    logits_b, state_b = M.prefill(params, cfg, jnp.asarray(padded),
                                  n_slots=24,
                                  true_len=jnp.asarray(t_real, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               atol=1e-5, rtol=1e-5)
    assert int(state_a.pos) == int(state_b.pos) == t_real
    for la, lb in zip(jax.tree.leaves((state_a.blocks, state_a.tail)),
                      jax.tree.leaves((state_b.blocks, state_b.tail))):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5, rtol=1e-5)
    # decoding from both states stays in lockstep
    tok = jnp.asarray([[7]])
    a, _ = M.decode_step(params, cfg, state_a, tok)
    b, _ = M.decode_step(params, cfg, state_b, tok)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)
