"""Observability layer: metrics registry / tracer semantics, engine
instrumentation against hand-computed expectations, the no-op-registry
zero-overhead contract, and the benchmark envelope + traffic harness.
"""
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.models import model as M
from repro.obs.metrics import (MetricsRegistry, NullRegistry, NULL_REGISTRY,
                               DEFAULT_LATENCY_BUCKETS)
from repro.obs.trace import Tracer, NullTracer, NULL_TRACER
from repro.serving.engine import (Engine, SamplingParams, FAILED, FINISHED)
from repro.serving.speculative import SpecConfig

# benchmarks/ is a repo-root package (reachable when pytest runs as
# ``python -m pytest`` from the root); make the import robust to bare
# ``pytest`` invocations too
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))
from benchmarks import common as bench_common          # noqa: E402
from benchmarks import traffic                         # noqa: E402


@pytest.fixture(scope="module")
def small_model():
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16, dtype="float32",
        lacache=LaCacheConfig(budget=48, n_sink=2, n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


# --------------------------------------------------------------------------- #
# Metrics registry (pure host, no model)
# --------------------------------------------------------------------------- #
def test_counter_and_gauge_basics():
    m = MetricsRegistry()
    c = m.counter("reqs_total", "requests")
    c.inc()
    c.inc(3)
    assert m.value("reqs_total") == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    g = m.gauge("depth", "queue depth")
    g.set(5)
    g.inc()
    g.dec(2)
    assert m.value("depth") == 4


def test_counter_labels_are_independent_children():
    m = MetricsRegistry()
    fam = m.counter("toks_total", "tokens", labels=("kind",))
    fam.labels("computed").inc(10)
    fam.labels("reused").inc(3)
    assert m.value("toks_total", "computed") == 10
    assert m.value("toks_total", "reused") == 3
    # a labeled family has no label-less child to proxy to
    with pytest.raises(ValueError):
        fam.inc()


def test_histogram_hand_computed():
    m = MetricsRegistry()
    h = m.histogram("lat", "latency", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(56.05)
    snap = m.snapshot()["lat"]["values"][0]
    # cumulative bucket counts: le=0.1 ->1, le=1 ->3, le=10 ->4, +Inf ->5
    assert snap["buckets"] == [[0.1, 1], [1.0, 3], [10.0, 4],
                               [float("inf"), 5]]
    # median rank falls in the (0.1, 1.0] bucket
    assert 0.1 < h.percentile(50.0) <= 1.0
    assert h.percentile(100.0) == 10.0     # overflow clamps to lower bound


def test_registry_idempotent_and_conflict():
    m = MetricsRegistry()
    a = m.counter("x_total", "x")
    b = m.counter("x_total", "x")
    assert a is b
    with pytest.raises(ValueError):
        m.gauge("x_total", "redefined as a gauge")


def test_gauge_fn_sampled_at_snapshot_and_errors_skipped():
    m = MetricsRegistry()
    depth = [7]
    m.gauge_fn("live_depth", lambda: depth[0], "sampled")
    m.gauge_fn("broken", lambda: 1 / 0, "raises")
    depth[0] = 9                      # mutate after registration
    snap = m.snapshot()
    assert snap["live_depth"]["values"][0]["value"] == 9
    assert "broken" not in snap


def test_prometheus_exposition_format():
    m = MetricsRegistry()
    m.counter("a_total", "a counter").inc(2)
    m.histogram("h", "a histogram", buckets=(1.0,)).observe(0.5)
    m.counter("lbl_total", "labeled", labels=("k",)).labels("v").inc()
    text = m.to_prometheus()
    assert "# TYPE a_total counter" in text
    assert "a_total 2" in text
    assert 'h_bucket{le="+Inf"} 1' in text
    assert "h_sum 0.5" in text and "h_count 1" in text
    assert 'lbl_total{k="v"} 1' in text
    json.loads(m.to_json())           # valid JSON snapshot


def test_null_registry_is_inert():
    n = NullRegistry()
    assert not n.enabled and not NULL_REGISTRY.enabled
    c = n.counter("x_total", "x")
    c.inc()
    c.labels("a").inc(5)
    n.gauge("g", "g").set(3)
    n.histogram("h", "h").observe(1.0)
    n.gauge_fn("f", lambda: 1, "f")
    assert n.snapshot() == {}
    with pytest.raises(KeyError):
        n.value("x_total")
    assert n.get("h").percentile(50.0) == 0.0


# --------------------------------------------------------------------------- #
# Tracer
# --------------------------------------------------------------------------- #
def _fake_clock(times):
    seq = list(times)

    def clock():
        return seq.pop(0) if len(seq) > 1 else seq[0]
    return clock


def test_tracer_spans_instants_export(tmp_path):
    # reads: t0 at construction, begin, end, span enter/exit, instant
    tr = Tracer(clock=_fake_clock([0.0, 0.001, 0.003, 0.004, 0.0045,
                                   0.005]))
    tr.thread_name(1, "req 0")
    tr.begin(("run", 0), "running", tid=1, slot=2)
    tr.end(("run", 0), outcome="finished")
    with tr.span("decode", tid=0, tick=1):
        pass
    tr.instant("compaction", tid=0, slot=2)
    d = tr.to_dict()
    evs = {e["name"]: e for e in d["traceEvents"] if e["ph"] != "M"}
    run = evs["running"]
    assert run["ph"] == "X" and run["ts"] == 1000 and run["dur"] == 2000
    assert run["args"] == {"slot": 2, "outcome": "finished"}
    assert evs["decode"]["ph"] == "X"
    assert evs["compaction"]["ph"] == "i"
    path = os.path.join(tmp_path, "t.json")
    n = tr.export(path)
    with open(path) as f:
        assert len(json.load(f)["traceEvents"]) == n


def test_tracer_unfinished_spans_and_event_bound():
    tr = Tracer(clock=_fake_clock([float(i) for i in range(10)]),
                max_events=2)
    # deliberately never ended: flushed as unfinished
    tr.begin("a", "a")  # analysis: allow(OBS002)
    tr.instant("i1")
    tr.instant("i2")
    tr.instant("dropped")              # over max_events
    d = tr.to_dict()
    names = [e["name"] for e in d["traceEvents"]]
    assert "dropped" not in names
    a = [e for e in d["traceEvents"] if e["name"] == "a"]
    assert a and a[0]["args"]["unfinished"] is True
    assert tr.dropped >= 1


def test_null_tracer_records_nothing():
    tr = NullTracer()
    assert not tr.enabled and not NULL_TRACER.enabled
    tr.begin("k", "n")
    tr.end("k")
    with tr.span("s"):
        pass
    tr.instant("i")
    tr.thread_name(1, "row")
    assert len(tr) == 0
    assert tr.to_dict()["traceEvents"] == []


# --------------------------------------------------------------------------- #
# Benchmark envelope
# --------------------------------------------------------------------------- #
def test_write_bench_envelope(tmp_path, monkeypatch):
    monkeypatch.setattr(bench_common, "RESULTS", str(tmp_path))
    path = bench_common.write_bench("unit", {"x": 1},
                                    config={"seed": 0})
    assert os.path.basename(path) == "BENCH_unit.json"
    with open(path) as f:
        env = json.load(f)
    assert env["schema_version"] == bench_common.SCHEMA_VERSION
    assert env["bench"] == "unit"
    assert env["config"] == {"seed": 0}
    assert env["data"] == {"x": 1}
    assert "git_sha" in env           # None outside a checkout is fine


# --------------------------------------------------------------------------- #
# Engine instrumentation: scripted workloads, hand-computed counters
# --------------------------------------------------------------------------- #
def test_engine_counters_shared_prefix_workload(small_model):
    """Two sequential requests where the second's prompt strictly extends
    the first's: the second reuses the whole 24-token cached prefix, so
    prefill computes 24 + 8 tokens and reuses 24."""
    cfg, params = small_model
    m = MetricsRegistry()
    eng = Engine(cfg, params, budget=48, max_batch=2, metrics=m)
    rng = np.random.default_rng(0)
    p1 = rng.integers(0, cfg.vocab_size, (24,))
    p2 = np.concatenate([p1, rng.integers(0, cfg.vocab_size, (8,))])
    eng.submit(p1, 4, cache_prefix=True)
    eng.run()
    eng.submit(p2, 4, cache_prefix=True)
    eng.run()
    assert m.value("engine_submitted_total") == 2
    assert m.value("engine_retired_total", FINISHED) == 2
    assert m.value("engine_tokens_total") == 8
    assert m.value("engine_prefill_tokens_total", "computed") == 32
    assert m.value("engine_prefill_tokens_total", "reused") == 24
    assert m.value("prefix_lookups_total") == 2
    assert m.value("prefix_hits_total") == 1
    # the registry mirrors the engine's own host counters exactly
    assert m.value("engine_prefill_tokens_total", "computed") \
        == eng.prefill_tokens
    assert m.value("engine_prefill_tokens_total", "reused") \
        == eng.prefix_tokens_reused
    assert m.value("prefix_hits_total") == eng.prefix_cache.hits
    assert m.get("engine_ttft_seconds").count == 2
    assert m.get("engine_tpot_seconds").count == 2
    assert m.get("engine_queue_wait_seconds").count == 2
    snap = m.snapshot()
    assert snap["engine_running"]["values"][0]["value"] == 0


def test_engine_counters_preemption_and_deadline(small_model):
    """One slot, deadline admission: a tighter-deadline request preempts
    the runner (1 preemption + 1 resume), and both deadline outcomes are
    recorded against the injected virtual clock."""
    cfg, params = small_model
    m = MetricsRegistry()
    t = [0.0]
    # deadline-pressure preemption swaps state through the paged pool,
    # so it only arms on the paged backend
    eng = Engine(cfg, params, budget=48, max_batch=1, admission="deadline",
                 kv_backend="paged", metrics=m, clock=lambda: t[0])
    rng = np.random.default_rng(1)
    r1 = eng.submit(rng.integers(0, cfg.vocab_size, (12,)), 10,
                    deadline=100.0)
    eng.step()                         # r1 admitted and running
    r2 = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 2, deadline=1.0)
    while eng.scheduler.has_work:
        eng.step()
        t[0] += 0.1
    assert r1.status == FINISHED and r2.status == FINISHED
    assert r1.n_preempts == 1
    assert m.value("engine_preemptions_total") == 1
    assert m.value("engine_resumes_total") == 1
    assert m.value("engine_retired_total", FINISHED) == 2
    # r2 finishes well before t=1.0; r1 well before t=100
    assert m.value("engine_deadline_outcomes_total", "met") == 2
    assert m.get("engine_deadline_slack_seconds").count == 2


def test_spec_fallback_counter(small_model):
    """A stochastically-sampling request forces the speculative decoder
    to fall back to stepwise decode every tick, labeled 'stochastic'."""
    cfg, params = small_model
    m = MetricsRegistry()
    eng = Engine(cfg, params, budget=48, max_batch=2, kv_backend="paged",
                 spec_config=SpecConfig(k=3), metrics=m)
    rng = np.random.default_rng(2)
    eng.submit(rng.integers(0, cfg.vocab_size, (10,)), 6,
               SamplingParams(temperature=1.0, seed=7))
    eng.run()
    assert eng._spec.fallback_steps > 0
    assert m.value("spec_fallback_steps_total", "stochastic") \
        == eng._spec.fallback_steps
    assert m.value("spec_waves_total") == 0


def test_compaction_events_counted(small_model):
    """Generation past the ladder budget (48) compacts inside the jitted
    decode; the host-side occupancy probe surfaces it as a counter."""
    cfg, params = small_model
    m = MetricsRegistry()
    eng = Engine(cfg, params, budget=48, max_batch=1, metrics=m)
    rng = np.random.default_rng(3)
    eng.submit(rng.integers(0, cfg.vocab_size, (16,)), 60)
    eng.run()
    assert m.value("engine_compaction_events_total") >= 1


def test_on_token_failure_marks_request_failed(small_model):
    """A raising on_token callback fails its own request (recorded in the
    registry) without unwinding step() or poisoning other requests."""
    cfg, params = small_model
    m = MetricsRegistry()
    eng = Engine(cfg, params, budget=48, max_batch=2, metrics=m)
    rng = np.random.default_rng(4)

    def bad(req, tok):
        raise ValueError("stream broke")

    r_bad = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 6,
                       on_token=bad)
    r_ok = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 6)
    done = eng.run()
    assert len(done) == 2
    assert r_bad.status == FAILED
    assert isinstance(r_bad.error, ValueError)
    assert len(r_bad.output_tokens) == 1       # failed on its first token
    assert r_ok.status == FINISHED and len(r_ok.output_tokens) == 6
    assert m.value("engine_callback_errors_total") == 1
    assert m.value("engine_retired_total", FAILED) == 1
    assert m.value("engine_retired_total", FINISHED) == 1
    # the engine still serves new work after the failure
    r3 = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 2)
    eng.run()
    assert r3.status == FINISHED


def test_noop_registry_output_bit_identical(small_model):
    """Default (null) instrumentation vs live metrics + tracer: the
    generated streams must be bit-identical — observability must never
    perturb the computation."""
    cfg, params = small_model
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (10 + 3 * i,))
               for i in range(3)]

    def serve(**kw):
        eng = Engine(cfg, params, budget=48, max_batch=2, **kw)
        reqs = [eng.submit(p, 6, SamplingParams(seed=i))
                for i, p in enumerate(prompts)]
        eng.run()
        return [r.tokens.tolist() for r in reqs]

    base = serve()
    instrumented = serve(metrics=MetricsRegistry(), tracer=Tracer())
    assert base == instrumented


def test_engine_trace_spans(small_model):
    """The request lifecycle shows up as Perfetto events: queued/running
    rows per request, prefill/decode spans on the engine row."""
    cfg, params = small_model
    tr = Tracer()
    eng = Engine(cfg, params, budget=48, max_batch=1, metrics=None,
                 tracer=tr)
    rng = np.random.default_rng(6)
    eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 3)
    eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 3)
    eng.run()
    evs = tr.to_dict()["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"queued", "running", "prefill", "decode"} <= names
    runs = [e for e in evs if e["name"] == "running"]
    assert len(runs) == 2 and all(e["ph"] == "X" for e in runs)
    assert {e["tid"] for e in runs} == {1, 2}      # one row per request
    assert all(e["args"]["outcome"] == FINISHED for e in runs)


# --------------------------------------------------------------------------- #
# Traffic harness
# --------------------------------------------------------------------------- #
def test_traffic_workload_deterministic_and_sorted():
    w1 = traffic.gen_workload(16, seed=0, pattern="bursty", rate=20.0,
                              vocab=128)
    w2 = traffic.gen_workload(16, seed=0, pattern="bursty", rate=20.0,
                              vocab=128)
    arr = [w["arrival"] for w in w1]
    assert arr == [w["arrival"] for w in w2]
    assert arr == sorted(arr)
    assert all(np.array_equal(a["prompt"], b["prompt"])
               for a, b in zip(w1, w2))
    with pytest.raises(ValueError):
        traffic.gen_workload(4, 0, "sawtooth", 20.0, 128)


def test_traffic_scenario_report(small_model):
    cfg, params = small_model
    work = traffic.gen_workload(4, seed=0, pattern="steady", rate=20.0,
                                vocab=cfg.vocab_size)
    for w in work:
        w["max_new"] = min(w["max_new"], 6)
    rep = traffic.run_scenario(cfg, params, work, "fifo", budget=48)
    assert rep["n_finished"] == 4 and rep["n_failed"] == 0
    assert rep["ttft_s"]["p50"] > 0 and rep["tpot_s"]["p50"] > 0
    assert rep["goodput_tok_per_s"] <= rep["throughput_tok_per_s"]
    assert rep["deadline"]["met"] + rep["deadline"]["missed"] == 4
    assert set(rep["per_tenant"]) == {"interactive", "batch"}
    assert rep["prefill_tokens"]["computed"] > 0
