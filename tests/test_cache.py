"""KV cache state machine: append / compact / policies (paper Sec. 3.3)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cachelib
from repro.core.ladder import LadderSpec


def spec(**kw):
    d = dict(n_layers=8, span=2, overlap=1, chunk=2, n_sink=2, n_recent=4,
             budget=24)
    d.update(kw)
    return LadderSpec(**d)


def filled_cache(n=24, batch=2, kv=2, hd=8, with_scores=False):
    c = cachelib.init_cache(batch, n, kv, hd, jnp.float32,
                            with_scores=with_scores)
    k = jnp.arange(batch * n * kv * hd, dtype=jnp.float32).reshape(batch, n, kv, hd)
    c = cachelib.append(c, k, k + 1.0, jnp.arange(n, dtype=jnp.int32))
    if with_scores:
        c = c._replace(scores=jnp.linspace(0, 1, n))
    return c


def test_append_tracks_positions_and_length():
    c = cachelib.init_cache(1, 16, 2, 4, jnp.float32)
    c = cachelib.append(c, jnp.ones((1, 3, 2, 4)), jnp.ones((1, 3, 2, 4)),
                        jnp.array([10, 11, 12]))
    assert int(c.length) == 3
    assert c.pos[:3].tolist() == [10, 11, 12]
    assert int(c.pos[3]) == -1


@pytest.mark.parametrize("policy", ["lacache", "streaming"])
def test_compact_frees_space_and_keeps_order(policy):
    s = spec()
    c = filled_cache()
    c2 = cachelib.compact(c, s, layer=3, policy=policy)
    assert int(c2.length) < int(c.length)
    pos = np.asarray(c2.pos[: int(c2.length)])
    assert (np.diff(pos) > 0).all()            # age order preserved
    assert pos[0] == 0 and pos[1] == 1         # sinks survive
    assert pos[-1] == 23                       # newest survives
    # slots past new length are zeroed
    assert float(jnp.abs(c2.k[:, int(c2.length):]).max()) == 0.0


def test_maybe_compact_noop_when_space():
    s = spec()
    c = filled_cache(n=24)
    c = c._replace(length=jnp.asarray(10, jnp.int32))
    c2 = cachelib.maybe_compact(c, s, 0, "lacache", n_incoming=1)
    assert int(c2.length) == 10


def test_maybe_compact_triggers_on_overflow():
    s = spec()
    c = filled_cache(n=24)
    c2 = cachelib.maybe_compact(c, s, 0, "lacache", n_incoming=1)
    assert int(c2.length) < 24


def test_full_policy_never_evicts():
    s = spec()
    c = filled_cache(n=24)
    c2 = cachelib.maybe_compact(c, s, 0, "full", n_incoming=1)
    assert int(c2.length) == 24


def test_compact_to_budget_terminates_and_fits():
    s = spec(budget=16)
    c = filled_cache(n=24)
    c2 = cachelib.compact_to_budget(c, s, layer=1, policy="lacache", target=16)
    assert int(c2.length) <= 16
    c3 = cachelib.crop(c2, 16)
    assert c3.k.shape[1] == 16


def test_h2o_keeps_heavy_hitters():
    s = spec()
    c = filled_cache(with_scores=True)
    # give slot 10 a huge score, slot 11 a tiny one
    scores = np.zeros(24, np.float32)
    scores[10] = 100.0
    scores[11] = 1e-6
    c = c._replace(scores=jnp.asarray(scores))
    c2 = cachelib.compact(c, s, layer=0, policy="h2o")
    kept = set(np.asarray(c2.pos[: int(c2.length)]).tolist())
    assert 10 in kept
    assert 0 in kept and 1 in kept             # sinks
    assert 23 in kept                          # recent


def test_ladder_differs_across_layers_streaming_does_not():
    s = spec()
    c = filled_cache()
    kept_by_layer = []
    for layer in range(s.n_layers):
        c2 = cachelib.compact(c, s, layer=layer, policy="lacache")
        kept_by_layer.append(tuple(np.asarray(c2.pos[: int(c2.length)])))
    assert len(set(kept_by_layer)) > 1         # ladder: layer-dependent
    kept_stream = [tuple(np.asarray(
        cachelib.compact(c, s, layer, "streaming").pos)) for layer in range(4)]
    assert len(set(kept_stream)) == 1          # streaming: uniform


def test_checkpoint_roundtrip():
    from repro.checkpoint import io as ck
    import tempfile, os
    c = filled_cache()
    tree = {"a": c, "b": [jnp.arange(3), {"c": jnp.ones((2, 2))}]}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.npz")
        ck.save(p, tree)
        back = ck.load(p, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
