"""Runtime paged-pool sanitizer (``REPRO_SANITIZE=1``) + enriched
PoolExhausted.

Every violation class the sanitizer claims to detect is manufactured here
on purpose — double release, retain of a dead block, corrupted refcounts,
a CoW-violating lane table, a leaked reference at engine shutdown — and
asserted to raise :class:`SanitizerError` with an actionable message
(allocation sites included). The happy paths (full serve + clean
``close()``) must stay silent under the sanitizer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sanitizer as sanlib
from repro.analysis.sanitizer import SanitizerError
from repro.configs.base import LaCacheConfig, ModelConfig
from repro.core import paged
from repro.models import model as M
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def small_model():
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16, dtype="float32",
        lacache=LaCacheConfig(budget=48, n_sink=2, n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture
def sanitize(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")


def make_store(n_blocks=16):
    return paged.PagedStateStore(n_blocks, 4, 2, 8, jnp.float32)


# --------------------------------------------------------------------------- #
# enablement
# --------------------------------------------------------------------------- #
def test_sanitizer_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanlib.enabled()
    assert make_store()._sanitizer is None
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert not sanlib.enabled()


def test_sanitizer_attaches_on_env(sanitize):
    store = make_store()
    assert isinstance(store._sanitizer, sanlib.PoolSanitizer)


# --------------------------------------------------------------------------- #
# op-level violations
# --------------------------------------------------------------------------- #
def test_double_release_reports_allocation_site(sanitize):
    store = make_store()
    ids = store.alloc_blocks(2)
    store.release_blocks(ids)
    with pytest.raises(SanitizerError, match="double release"):
        store.release_blocks(ids)


def test_double_release_message_names_this_file(sanitize):
    store = make_store()
    ids = store.alloc_blocks(1)
    store.release_blocks(ids)
    with pytest.raises(SanitizerError, match="test_sanitizer"):
        store.release_blocks(ids)


def test_over_release_within_one_call(sanitize):
    store = make_store()
    ids = store.alloc_blocks(1)
    twice = np.concatenate([ids, ids])
    with pytest.raises(SanitizerError, match="double release"):
        store.release_blocks(twice)


def test_retain_of_dead_block(sanitize):
    store = make_store()
    ids = store.alloc_blocks(1)
    store.release_blocks(ids)
    with pytest.raises(SanitizerError, match="retain of unreferenced"):
        store.retain_blocks(ids)


def test_corrupted_refcount_caught_after_next_op(sanitize):
    store = make_store()
    ids = store.alloc_blocks(2)
    # simulate external corruption: a negative refcount in the pool
    ref = jnp.asarray(store.pool.ref).at[int(ids[0])].set(-1)
    store.pool = store.pool._replace(ref=ref)
    with pytest.raises(SanitizerError, match="pool invariant broken"):
        store.retain_blocks(ids[1:])


def test_clean_churn_is_silent(sanitize):
    store = make_store()
    rng = np.random.default_rng(0)
    held = []
    for _ in range(30):
        if held and rng.random() < 0.5:
            store.release_blocks(held.pop())
        else:
            held.append(store.alloc_blocks(int(rng.integers(1, 3))))
    for ids in held:
        store.release_blocks(ids)
    assert store.bytes_in_use == 0


# --------------------------------------------------------------------------- #
# enriched PoolExhausted
# --------------------------------------------------------------------------- #
def test_pool_exhausted_carries_utilization_and_suggestion():
    store = make_store(n_blocks=4)
    store.alloc_blocks(3)
    with pytest.raises(paged.PoolExhausted) as ei:
        store.alloc_blocks(3)
    e = ei.value
    assert (e.need, e.free, e.in_use, e.total) == (3, 1, 3, 4)
    assert e.suggested_pool_blocks == 4 + (3 - 1)
    msg = str(e)
    assert "need 3 blocks, 1 free (3/4 in use)" in msg
    assert "retry with pool_blocks >= 6" in msg


def test_pool_exhausted_attributes_prefix_cache_blocks():
    store = make_store(n_blocks=4)
    store.pressure_context = lambda: 2
    store.alloc_blocks(4)
    with pytest.raises(paged.PoolExhausted,
                       match=r"2 held by prefix cache") as ei:
        store.alloc_blocks(1)
    assert ei.value.cache_blocks == 2


# --------------------------------------------------------------------------- #
# engine-level checks
# --------------------------------------------------------------------------- #
def _serve(cfg, params, n_reqs=2, **kw):
    eng = Engine(cfg, params, budget=48, max_batch=2, kv_backend="paged",
                 **kw)
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, (12,))
    for _ in range(n_reqs):
        p = np.concatenate([shared, rng.integers(0, cfg.vocab_size, (4,))])
        eng.submit(p, 4, cache_prefix=True)
    return eng


def test_engine_serves_and_closes_clean_under_sanitizer(sanitize,
                                                        small_model):
    cfg, params = small_model
    eng = _serve(cfg, params)
    eng.run()
    assert eng._sanitizer is not None
    eng.close()                       # must not raise: pool fully drained


def test_close_detects_leaked_reference(sanitize, small_model):
    cfg, params = small_model
    eng = _serve(cfg, params)
    eng.run()
    # manufacture a leak: an extra reference nobody will ever release
    ref = np.asarray(eng.kv_store.pool.ref)
    victim = np.asarray([int(np.nonzero(ref > 0)[0][0])])
    eng.kv_store.retain_blocks(victim)
    with pytest.raises(SanitizerError, match="leaked at engine shutdown"):
        eng.close()
    # the report names where the block was ALLOCATED (the engine's lane
    # reservation), not where the extra reference was taken
    report = eng._sanitizer.live_report(set(victim.tolist()))
    assert "allocated at" in report and "<untracked>" not in report


def test_check_lanes_flags_writable_shared_block(sanitize, small_model):
    cfg, params = small_model
    eng = _serve(cfg, params, n_reqs=1)
    while not eng.scheduler.running:
        eng.step()
    eng.step()                        # per-step audit passes while healthy
    slot = next(iter(eng.scheduler.running))
    victim = None
    for _, _, blocks, owned in sanlib._lane_leaf_tables(eng._slot_states,
                                                        slot):
        writable = blocks[(blocks >= 0) & (blocks == owned)]
        if writable.size:
            victim = np.asarray([int(writable[0])])
            break
    assert victim is not None
    eng.kv_store.retain_blocks(victim)    # ref 2 while still writable
    with pytest.raises(SanitizerError, match="CoW violation"):
        sanlib.check_lanes(eng)
    eng.kv_store.release_blocks(victim)
    sanlib.check_lanes(eng)               # healthy again


def test_check_lanes_flags_unheld_foreign_block(sanitize, small_model):
    cfg, params = small_model
    eng = _serve(cfg, params, n_reqs=2)
    # drive until a prefix hit maps shared (non-owned) blocks into a lane
    spins = 0
    target = None
    while target is None and spins < 200:
        eng.step()
        spins += 1
        for slot in eng.scheduler.running:
            if eng._lane_shared[slot].size:
                target = slot
                break
    assert target is not None, "no lane ever held a shared block"
    held = eng._lane_shared[target]
    eng._lane_shared[target] = held[:0]   # forget the travelling refs
    with pytest.raises(SanitizerError, match="neither owns"):
        sanlib.check_lanes(eng)
    eng._lane_shared[target] = held
    sanlib.check_lanes(eng)
