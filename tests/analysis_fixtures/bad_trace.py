"""Known-bad trace-purity fixture.

Parsed by ``tests/test_analysis.py`` (never imported): every line that a
pass must flag carries a trailing ``# expect: RULE`` marker, and the test
asserts the finding set equals the marker set exactly — rule ID *and*
line number.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np


class PoolExhausted(RuntimeError):
    pass


class Store:
    def alloc_blocks(self, n):
        self.used = self.used + n                         # expect: TRC003
        return list(range(n))


def raiser():
    raise PoolExhausted("no blocks")                      # expect: TRC001


def helper(state):
    raiser()                                              # expect: TRC001
    return jnp.sum(state)


def traced_body(state, store):
    ids = store.alloc_blocks(2)                           # expect: TRC001
    host = np.asarray(state)                              # expect: TRC002
    env = os.environ.get("REPRO_X", "0")                  # expect: TRC002
    helper(state)                                         # expect: TRC001
    return state + len(ids) + host.sum() + len(env)


def outer(state, store):
    # the traced region roots here: both branch callables of the cond
    return jax.lax.cond(state.sum() > 0,
                        lambda s: traced_body(s, store),  # expect: TRC001
                        lambda s: s, state)
