"""Known-bad fixture for the observability-purity pass (OBS001-OBS002).

Every flagged line carries a trailing ``# expect:`` marker; the tests
assert exact (rule, line) set equality. Parsed only, never imported.
"""
import jax
import jax.numpy as jnp


@jax.jit
def traced_count(state, metrics):
    # host instrument mutated under trace: records once at trace time,
    # then never again on cached executions
    metrics.tokens.inc()  # expect: OBS001
    return state + 1


@jax.jit
def traced_span(x, tracer):
    tracer.instant("decode_step")  # expect: OBS001
    return x * 2


def submit(tracer, rid):
    # span begun but no end()/discard() for "queued" anywhere
    tracer.begin(("queued", rid), t0=0.0)  # expect: OBS002


def retire(tracer, rid):
    # end without a begin: dead call, or the begin was dropped
    tracer.end(("evicted", rid))  # expect: OBS002


def balanced(tracer, rid):
    # a properly paired span: no finding
    tracer.begin(("running", rid))
    try:
        pass
    finally:
        tracer.end(("running", rid))
