"""Known-good twin of ``bad_obs.py``: instruments at the eager dispatch
site, keyed spans paired (end on retirement, discard on abort). Must
produce zero findings from every pass.
"""
import jax
import jax.numpy as jnp


@jax.jit
def pure_step(state, tokens):
    return state + tokens.sum()


def dispatch(params, state, tokens, metrics, tracer):
    # observability wraps the dispatch, never lives inside it
    tracer.begin(("step", id(state)))
    out = pure_step(state, tokens)
    metrics.tokens.inc()
    metrics.queue_depth.set(3)
    tracer.end(("step", id(state)))
    return out


def lifecycle(tracer, rid, ok):
    tracer.begin(("queued", rid), t0=0.0)
    tracer.begin(("running", rid))
    if ok:
        tracer.end(("running", rid))
        tracer.end(("queued", rid))
    else:
        # abort path: discard closes the key too
        tracer.discard(("running", rid))
        tracer.discard(("queued", rid))


def snapshot(metrics):
    # reads and registrations are host-side and unflagged
    h = metrics.histogram("latency_s")
    h.observe(0.25)
    return metrics
