"""Known-good twin of ``bad_sharding.py``: the same shapes done right —
collectives inside a shard_map whose mesh declares the axis, scoped
registry publication, axis names the mesh knows. Must produce zero
findings from every pass.
"""
import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE = threading.local()


def current_spec():
    return getattr(_ACTIVE, "spec", None)


@contextlib.contextmanager
def use_spec(spec):
    # the approved shape: publish inside try, restore in finally — a
    # raise mid-dispatch can never leave the registry armed
    prev = getattr(_ACTIVE, "spec", None)
    try:
        _ACTIVE.spec = spec
        yield
    finally:
        _ACTIVE.spec = prev


def declared_axis(xs, devs):
    mesh = Mesh(devs, ("data", "model"))

    def body(x):
        part = jnp.max(x, axis=-1, keepdims=True)
        total = jax.lax.pmax(part, "model")
        return x - total

    fn = shard_map(body, mesh=mesh, in_specs=P("data", "model"),
                   out_specs=P("data", "model"))
    return fn(xs)


def dynamic_axis(xs, devs, axis_name):
    # non-literal axis: the checker cannot prove a typo, stays silent
    mesh = Mesh(devs, ("data",))

    def body(x):
        return jax.lax.psum(x, axis_name)

    fn = shard_map(body, mesh=mesh, in_specs=P("data"),
                   out_specs=P("data"))
    return fn(xs)


def well_placed(xs, devs):
    mesh = Mesh(devs, ("data", "model"))
    s = NamedSharding(mesh, P("data", "model"))
    return jax.device_put(xs, s)


def good_plane(devs, cfg):
    mesh = Mesh(devs, ("data", "model"))
    return pool_plane_spec(mesh, cfg, axis="model")


def pool_plane_spec(mesh, cfg, axis=None):
    return axis
