"""Known-bad fixture for the recompile-churn pass (CMP001-CMP003).

Every flagged line carries a trailing ``# expect:`` marker; the tests
assert exact (rule, line) set equality. Parsed only, never imported.
"""
import jax
import jax.numpy as jnp


def _kernel(params, tokens):
    return tokens.sum()


def _sized(params, n):
    return jnp.zeros((n,), jnp.float32)


step = jax.jit(_kernel)
sized = jax.jit(_sized, static_argnums=(1,))


def stream(params, chunks):
    # one executable per distinct chunk width: the dispatch shape is
    # rebuilt from the loop variable every iteration
    out = []
    for c in chunks:
        buf = jnp.zeros((1, c), jnp.int32)
        out.append(step(params, buf))  # expect: CMP001
    return out


def ragged(params, xs, widths):
    off = 0
    for size in widths:
        seg = xs[off:off + size]
        logits = step(params, seg)  # expect: CMP001
        off += size
    return logits


def static_churn(params):
    out = None
    for n in range(3):
        out = sized(params, n)  # expect: CMP001
    return out


def unstable_kwargs(params, opts):
    # the executable cache keys on the keyword set — a dynamically
    # built dict recompiles when a key is added or reordered
    return step(params, **opts)  # expect: CMP002


@jax.jit
def concretize(x):
    k = int(x.sum())  # expect: CMP003
    return jnp.zeros((k,), jnp.float32)


@jax.jit
def host_read(x):
    return x.max().item()  # expect: CMP003
