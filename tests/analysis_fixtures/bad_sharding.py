"""Known-bad fixture for the sharding-discipline pass (SHD001-SHD003).

Every flagged line carries a trailing ``# expect:`` marker; the tests
assert exact (rule, line) set equality. Parsed only, never imported.
"""
import threading

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE = threading.local()

_ACTIVE.spec = None  # expect: SHD002


@jax.jit
def unsharded_reduce(x):
    # a collective with no shard_map anywhere on the call chain: no
    # bound axis to reduce over
    return jax.lax.psum(x, "model")  # expect: SHD001


def undeclared_axis(xs, devs):
    mesh = Mesh(devs, ("data",))

    def body(x):
        # the binding mesh declares only "data"
        return jax.lax.pmax(x, "model")  # expect: SHD001

    fn = shard_map(body, mesh=mesh, in_specs=P("data"),
                   out_specs=P("data"))
    return fn(xs)


def install(spec):
    # bare publication: a raise before the caller's cleanup leaves the
    # registry armed for the next engine on this thread
    _ACTIVE.spec = spec  # expect: SHD002


def misplaced(xs, devs):
    mesh = Mesh(devs, ("data", "model"))
    s = NamedSharding(mesh, P("data", "tensor"))  # expect: SHD003
    return jax.device_put(xs, s)


def bad_plane(mesh_axes_devs, cfg):
    mesh = Mesh(mesh_axes_devs, ("data", "model"))
    return pool_plane_spec(mesh, cfg, axis="tensor")  # expect: SHD003


def pool_plane_spec(mesh, cfg, axis=None):
    return axis
