"""Known-good fixture: the clean twin of every known-bad snippet.

``tests/test_analysis.py`` asserts the passes report ZERO findings here —
each construct below is the approved way to do what the bad fixtures do
wrong, including one intentional boundary suppressed with an
``# analysis: allow(...)`` annotation.
"""
import dataclasses
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PoolExhausted(RuntimeError):
    pass


class Store:
    def alloc_blocks(self, n):
        return list(range(n))


class Carry(NamedTuple):
    """NamedTuples are auto-registered pytrees: fine to build under trace."""

    buf: np.ndarray
    step: int


@dataclasses.dataclass
class RegisteredMeta:
    scale: np.ndarray
    name: str


jax.tree_util.register_dataclass(
    RegisteredMeta, data_fields=["scale"], meta_fields=["name"])


@jax.jit
def advance(x):
    # registered dataclass + NamedTuple under trace: both fine
    m = RegisteredMeta(scale=x, name="gain")
    c = Carry(buf=m.scale, step=1)
    # np on *static* metadata (shapes) is trace-safe
    n = int(np.prod(jnp.shape(x)))
    return c.buf * n


def eager_driver(store, state):
    # pool ops BEFORE dispatch — the approved shape of the bad fixture
    ids = store.alloc_blocks(2)
    threads = os.environ.get("REPRO_THREADS", "1")
    return advance(state), ids, threads


class Server:
    def __init__(self, step_fn, prefix_cache):
        self._step = jax.jit(step_fn, donate_argnums=(0,))
        self.prefix_cache = prefix_cache

    def refresh(self, state):
        # rebinding the donated name kills the hazard
        state = self._step(state)
        return state + 1

    def drain(self, state):
        for _ in range(4):
            state = self._step(state)
        return state

    def resume(self, key):
        # copy a by-reference store result into a FRESH pytree before
        # donating — the cache keeps (and keeps using) its own buffers
        cached = self.prefix_cache.restore(key)
        state = jax.tree_util.tree_map(jnp.asarray, cached)
        return self._step(state)


def traced_edge(state):
    # an intentional, reviewed boundary: suppressed with an allow
    host = np.asarray(state)  # analysis: allow(TRC002)
    return state + host.sum()


def outer(state):
    return jax.lax.cond(state.sum() > 0, traced_edge, lambda s: s, state)
