"""Known-bad pytree-registration fixture (parsed, never imported).

``# expect: RULE`` markers sit on the exact line each finding must
anchor to: PYT001 at the in-trace construction, PYT002 at the
``register_dataclass`` call / the ``tree_flatten`` return.
"""
import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class StepMeta:
    scale: float


@jax.jit
def advance(x):
    m = StepMeta(scale=2.0)                               # expect: PYT001
    return x * m.scale


@dataclasses.dataclass
class Windowed:
    data: np.ndarray
    width: int


jax.tree_util.register_dataclass(                         # expect: PYT002
    Windowed, data_fields=["data"], meta_fields=["data", "width"])


@dataclasses.dataclass
class RingAux:
    ring: np.ndarray
    period: int

    def tree_flatten(self):
        return ((self.period,), (self.ring, self.period))  # expect: PYT002

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(ring=aux[0], period=children[0])


jax.tree_util.register_pytree_node_class(RingAux)
