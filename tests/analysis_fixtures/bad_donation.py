"""Known-bad donation-discipline fixture (parsed, never imported).

``# expect: RULE`` markers sit on the exact line each finding must
anchor to: DON001 anchors at the *read* (or at the donating call for the
loop-carried variant), DON002 at the donating call.
"""
import jax


class Server:
    def __init__(self, step_fn, prefix_cache):
        self._step = jax.jit(step_fn, donate_argnums=(0,))
        self.prefix_cache = prefix_cache

    def refresh(self, state):
        new = self._step(state)
        stale = state + 1                                 # expect: DON001
        return new, stale

    def drain(self, state):
        out = state
        for _ in range(4):
            out = self._step(state)                       # expect: DON001
        return out

    def resume(self, key):
        state = self.prefix_cache.restore(key)
        return self._step(state)                          # expect: DON002
