"""Known-good twin of ``bad_recompile.py``: dispatch shapes that do NOT
churn — constant-width slices, hoisted extents, literal-key kwargs,
shape-metadata coercion. Must produce zero findings from every pass.
"""
import jax
import jax.numpy as jnp
import numpy as np


def _kernel(params, tokens):
    return tokens.sum()


def _sized(params, n):
    return jnp.zeros((n,), jnp.float32)


step = jax.jit(_kernel)
sized = jax.jit(_sized, static_argnums=(1,))


def decode(params, xs, steps):
    # constant-width slices: the position varies, the shape does not
    out = None
    for i in range(steps):
        tok = xs[:, i:i + 1]
        nxt = xs[:, i + 1:i + 2]
        out = step(params, tok)
        out = step(params, nxt)
    return out


def hoisted(params, chunks):
    # extent hoisted out of the loop: one executable total
    width = max(chunks)
    buf = jnp.zeros((1, width), jnp.int32)
    out = []
    for _ in chunks:
        out.append(step(params, buf))
    return out


def carried(params, xs, steps):
    # a jit result does not carry shape churn: its shape is the
    # executable's fixed output shape
    state = step(params, xs)
    for i in range(steps):
        state = step(params, state)
    return state


def stable_static(params, reps):
    out = None
    for _ in range(reps):
        out = sized(params, 8)
    return out


def literal_kwargs(params, x):
    return step(params, **{"tokens": x})


@jax.jit
def shape_math(x):
    # static trace-time metadata: jnp.shape/np.prod never see traced data
    n = int(np.prod(jnp.shape(x)))
    return x.reshape((n,))
