"""Admission-policy registry, priority/deadline scheduling, streamed token
callbacks, and submit-time SamplingParams validation."""
import jax
import numpy as np
import pytest

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.models import model as M
from repro.serving import admission as adm
from repro.serving.engine import (Engine, Request, SamplingParams, Scheduler,
                                  PENDING, RUNNING)


def _req(n=4, new=3, **kw):
    return Request(prompt=np.arange(n, dtype=np.int32), max_new_tokens=new,
                   **kw)


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
def test_builtin_admissions_registered():
    assert {"fifo", "priority", "deadline"} <= set(adm.admission_names())
    for name in ("fifo", "priority", "deadline"):
        p = adm.get_admission(name)
        assert isinstance(p, adm.AdmissionPolicy) and p.name == name
        assert adm.get_admission(p) is p            # object passthrough


def test_unknown_admission_raises():
    with pytest.raises(ValueError, match="unknown admission policy"):
        adm.get_admission("not-a-policy")


def test_register_custom_admission_drives_scheduler():
    class ShortestFirst(adm.AdmissionPolicy):
        name = "test-shortest-first"

        def key(self, req, seq):
            return (req.prompt_len, seq)

    adm.register_admission(ShortestFirst)
    s = Scheduler(2, admission="test-shortest-first")
    long_, short, mid = _req(30), _req(5), _req(12)
    s.submit(long_), s.submit(short), s.submit(mid)
    admitted = [r for _, r in s.admit()]
    assert admitted == [short, mid]
    assert long_.status == PENDING


# --------------------------------------------------------------------------- #
# Scheduler-level ordering
# --------------------------------------------------------------------------- #
def test_priority_high_late_submit_admitted_first():
    """Acceptance: a high-priority request submitted last is admitted
    before earlier low-priority pending requests."""
    s = Scheduler(1, admission="priority")
    lo1, lo2 = _req(priority=0), _req(priority=0)
    hi = _req(priority=5)
    s.submit(lo1), s.submit(lo2), s.submit(hi)
    assert [r for _, r in s.admit()] == [hi]
    assert lo1.status == PENDING and lo2.status == PENDING
    s.retire(0)
    assert [r for _, r in s.admit()] == [lo1]       # ties: FIFO


def test_priority_ties_preserve_fifo():
    s = Scheduler(3, admission="priority")
    reqs = [_req(priority=1) for _ in range(3)]
    for r in reqs:
        s.submit(r)
    assert [r for _, r in s.admit()] == reqs


def test_deadline_orders_earliest_first_none_last():
    s = Scheduler(4, admission="deadline")
    late = _req(deadline=9.0)
    none = _req(deadline=None)
    soon = _req(deadline=1.0)
    mid = _req(deadline=4.0)
    for r in (late, none, soon, mid):
        s.submit(r)
    assert [r for _, r in s.admit()] == [soon, mid, late, none]


def test_fifo_default_unchanged():
    s = Scheduler(2)
    assert s.admission.name == "fifo"
    a, b = _req(priority=9), _req(priority=0)       # priority ignored
    s.submit(a), s.submit(b)
    assert [r for _, r in s.admit()] == [a, b]


def test_pending_requests_reports_admission_order():
    s = Scheduler(1, admission="priority")
    lo, hi = _req(priority=0), _req(priority=3)
    s.submit(lo), s.submit(hi)
    assert s.pending_requests() == [hi, lo]
    assert len(s.pending) == 2                       # non-destructive


def test_conservation_invariant_under_priority_churn():
    rng = np.random.default_rng(0)
    s = Scheduler(3, admission="priority")
    for i in range(9):
        s.submit(_req(priority=int(rng.integers(0, 4))))
    served = 0
    while s.has_work:
        s.admit()
        assert len(s.running) + len(s._free) == s.n_slots
        s.retire(sorted(s.running)[0])
        served += 1
        assert len(s.running) + len(s._free) == s.n_slots
    assert served == 9


# --------------------------------------------------------------------------- #
# Engine level: admission + on_token + validation
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def small_model():
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16, dtype="float32",
        lacache=LaCacheConfig(budget=48, n_sink=2, n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_priority_admission(small_model):
    """Acceptance: with one slot, the late high-priority submit runs while
    the earlier low-priority requests are still pending."""
    cfg, params = small_model
    rng = np.random.default_rng(0)
    eng = Engine(cfg, params, budget=48, max_batch=1, admission="priority")
    lo1 = eng.submit(rng.integers(0, cfg.vocab_size, (10,)), 3, priority=0)
    lo2 = eng.submit(rng.integers(0, cfg.vocab_size, (10,)), 3, priority=0)
    hi = eng.submit(rng.integers(0, cfg.vocab_size, (10,)), 3, priority=7)
    eng.step()
    assert hi.status == RUNNING
    assert lo1.status == PENDING and lo2.status == PENDING
    done = eng.run()
    assert len(done) == 3 and all(len(r.output_tokens) == 3 for r in done)


def test_engine_deadline_admission(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(1)
    eng = Engine(cfg, params, budget=48, max_batch=1, admission="deadline")
    slack = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 2, deadline=50.0)
    urgent = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 2, deadline=1.0)
    eng.step()
    assert urgent.status in (RUNNING, "finished")
    assert slack.status == PENDING
    eng.run()


def test_on_token_streams_every_token_in_order(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(2)
    seen = []
    eng = Engine(cfg, params, budget=48, max_batch=2)
    req = eng.submit(rng.integers(0, cfg.vocab_size, (12,)), 5,
                     on_token=lambda r, t: seen.append((r.request_id, t)))
    eng.submit(rng.integers(0, cfg.vocab_size, (9,)), 3)   # silent batchmate
    eng.run()
    assert [t for _, t in seen] == req.output_tokens
    assert all(rid == req.request_id for rid, _ in seen)


def test_on_token_fires_at_admission_tick(small_model):
    """The first token is sampled from the prefill logits — the callback
    must fire on that same tick, before any decode step."""
    cfg, params = small_model
    seen = []
    eng = Engine(cfg, params, budget=48, max_batch=1)
    eng.submit(np.arange(8), 4, on_token=lambda r, t: seen.append(t))
    eng.step()
    assert len(seen) == 2          # prefill-sampled token + one decode step


def test_submit_rejects_negative_temperature(small_model):
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=1)
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(np.arange(4), 2, SamplingParams(temperature=-0.5))


def test_submit_rejects_non_finite_temperature(small_model):
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=1)
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(np.arange(4), 2, SamplingParams(temperature=float("nan")))
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(np.arange(4), 2, SamplingParams(temperature=float("inf")))


def test_submit_rejects_negative_top_k(small_model):
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=1)
    with pytest.raises(ValueError, match="top_k"):
        eng.submit(np.arange(4), 2, SamplingParams(top_k=-1))


def test_submit_rejects_bad_seed_and_deadline_and_callback(small_model):
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=1)
    with pytest.raises(ValueError, match="seed"):
        eng.submit(np.arange(4), 2, SamplingParams(seed=1.5))
    with pytest.raises(ValueError, match="deadline"):
        eng.submit(np.arange(4), 2, deadline=float("nan"))
    with pytest.raises(ValueError, match="on_token"):
        eng.submit(np.arange(4), 2, on_token="not-callable")
    with pytest.raises(ValueError, match="priority"):
        eng.submit(np.arange(4), 2, priority=0.9)   # would truncate silently


def test_submit_accepts_numpy_scalar_params(small_model):
    """Config-derived numpy scalars are as valid as Python scalars."""
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=1)
    req = eng.submit(np.arange(6), 2,
                     SamplingParams(temperature=np.float32(0.7),
                                    top_k=np.int32(5), seed=np.int64(1)),
                     priority=np.int32(2))
    eng.run()
    assert len(req.output_tokens) == 2


def test_valid_params_still_accepted(small_model):
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=1)
    req = eng.submit(np.arange(6), 2,
                     SamplingParams(temperature=0.7, top_k=10, seed=3),
                     priority=2, deadline=12.5)
    done = eng.run()
    assert done == [req] and len(req.output_tokens) == 2
