"""Integration test of the dry-run machinery on a small host-device mesh.

Runs in a subprocess (device count is locked at first jax init) with 8 host
devices and reduced configs — exercises mesh construction, logical-axis
rules, param/state shardings, lower+compile and the HLO analyses end to end.
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess + 8-device host mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config
from repro.launch import axes as axlib, shapes as shapeslib, sharding as shardlib
from repro.launch.hlo_analysis import analyze_collectives
from repro.models import model as M
from repro.optim import adamw
from repro.train import trainer

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = dict(axlib.SINGLE_POD_RULES)

out = {}
for arch in ["llama3.2-1b", "jamba-1.5-large-398b"]:
    cfg = get_config(arch).reduced()
    with axlib.logical_axis_rules(rules, mesh):
        params_sds, axes_tree = shapeslib.abstract_params(cfg)
        pshard = shardlib.param_shardings(mesh, rules, axes_tree, params_sds)
        # train step lowers + compiles
        step = trainer.make_train_step(cfg, adamw.AdamWConfig())
        opt_sds = jax.eval_shape(adamw.init, params_sds)
        oshard = shardlib.opt_state_shardings(mesh, rules, axes_tree, opt_sds)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 33), jnp.int32)}
        bshard = shardlib.train_batch_shardings(mesh, rules, batch)
        lowered = jax.jit(step, in_shardings=(pshard, oshard, bshard)).lower(
            params_sds, opt_sds, batch)
        compiled = lowered.compile()
        coll = analyze_collectives(compiled.as_text())
        # decode step lowers + compiles (serving rules)
        srules = axlib.serving_rules()
        with axlib.logical_axis_rules(srules, mesh):
            state_sds = jax.eval_shape(
                lambda p: M.init_decode_state(p, cfg, 4, cfg.lacache.budget),
                params_sds)
            sshard = shardlib.decode_state_shardings(mesh, srules, cfg, state_sds)
            pshard2 = shardlib.param_shardings(mesh, srules, axes_tree, params_sds)
            tok = jax.ShapeDtypeStruct((4, 1), jnp.int32)
            tshard = shardlib.train_batch_shardings(mesh, srules, tok)
            dl = jax.jit(lambda p, s, t: M.decode_step(p, cfg, s, t),
                         in_shardings=(pshard2, sshard, tshard)).lower(
                params_sds, state_sds, tok)
            dc = dl.compile()
        out[arch] = {"train_coll_bytes": coll["total_bytes"],
                     "decode_ok": True,
                     "trips": coll["while_trip_counts"]}
print(json.dumps(out))
"""


@pytest.mark.timeout(420)
def test_dryrun_on_8_host_devices():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=400)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for arch, rec in out.items():
        assert rec["decode_ok"]
        assert rec["train_coll_bytes"] > 0   # collectives present & counted
        assert max(rec["trips"], default=1) >= 2  # scan trip counts recovered
