"""Eviction-policy API: registry round-trip, object/string parity,
custom-policy plug-in through the model core."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cachelib
from repro.core import policy as pol
from repro.core.ladder import LadderSpec

ALL_POLICIES = ["lacache", "streaming", "h2o", "tova", "full"]


def spec(**kw):
    d = dict(n_layers=8, span=2, overlap=1, chunk=2, n_sink=2, n_recent=4,
             budget=24)
    d.update(kw)
    return LadderSpec(**d)


def filled_cache(n=24, batch=2, kv=2, hd=8, with_scores=False):
    c = cachelib.init_cache(batch, n, kv, hd, jnp.float32,
                            with_scores=with_scores)
    k = jnp.arange(batch * n * kv * hd, dtype=jnp.float32).reshape(
        batch, n, kv, hd)
    c = cachelib.append(c, k, k + 1.0, jnp.arange(n, dtype=jnp.int32))
    if with_scores:
        c = c._replace(scores=jnp.linspace(0, 1, n))
    return c


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
def test_builtins_registered():
    assert set(ALL_POLICIES) <= set(pol.policy_names())
    for name in ALL_POLICIES:
        p = pol.get_policy(name)
        assert isinstance(p, pol.EvictionPolicy)
        assert p.name == name


def test_get_policy_passthrough_and_roundtrip():
    p = pol.get_policy("lacache")
    assert pol.get_policy(p) is p                  # object passthrough
    assert pol.get_policy("lacache") is p          # singleton


def test_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown eviction policy"):
        pol.get_policy("definitely-not-registered")


def test_register_custom_policy_roundtrip():
    class EvictEverything(pol.EvictionPolicy):
        name = "test-evict-everything"

        def keep_mask(self, spec, cache, layer):
            slot = jnp.arange(cache.n_slots)
            # keep only sinks + the newest slot
            return ((slot < spec.n_sink) | (slot == cache.length - 1)) \
                & (slot < cache.length)

    try:
        pol.register_policy(EvictEverything)
        got = pol.get_policy("test-evict-everything")
        assert isinstance(got, EvictEverything)
        assert "test-evict-everything" in pol.policy_names()
        c2 = cachelib.compact(filled_cache(), spec(), layer=0, policy=got)
        assert int(c2.length) == 3                 # 2 sinks + newest
    finally:
        pol._REGISTRY.pop("test-evict-everything", None)


def test_register_rejects_bad_inputs():
    with pytest.raises(TypeError):
        pol.register_policy(object())
    with pytest.raises(ValueError, match="no name"):
        pol.register_policy(pol.EvictionPolicy())  # nameless


def test_needs_scores_flags():
    assert pol.get_policy("h2o").needs_scores
    assert pol.get_policy("tova").needs_scores
    for name in ("lacache", "streaming", "full"):
        assert not pol.get_policy(name).needs_scores
    assert not pol.get_policy("full").evicts
    for name in ("lacache", "streaming", "h2o", "tova"):
        assert pol.get_policy(name).evicts


# --------------------------------------------------------------------------- #
# Object-vs-string parity (the shim must be semantics-preserving)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ALL_POLICIES)
@pytest.mark.parametrize("layer", [0, 3, 7])
def test_keep_mask_object_string_parity(name, layer):
    s = spec()
    c = filled_cache(with_scores=name in ("h2o", "tova"))
    obj = pol.get_policy(name)
    m_str = np.asarray(cachelib.keep_mask(name, s, c, layer))
    m_obj = np.asarray(obj.keep_mask(s, c, layer))
    np.testing.assert_array_equal(m_str, m_obj)


@pytest.mark.parametrize("name", ["lacache", "streaming", "h2o"])
def test_compact_object_string_parity(name):
    s = spec()
    c = filled_cache(with_scores=name == "h2o")
    c_str = cachelib.compact(c, s, layer=2, policy=name)
    c_obj = cachelib.compact(c, s, layer=2, policy=pol.get_policy(name))
    assert int(c_str.length) == int(c_obj.length)
    np.testing.assert_array_equal(np.asarray(c_str.pos), np.asarray(c_obj.pos))
    np.testing.assert_array_equal(np.asarray(c_str.k), np.asarray(c_obj.k))


def test_observe_matches_legacy_score_shims():
    c = filled_cache(batch=1, with_scores=True)
    probs = jax.random.uniform(jax.random.PRNGKey(0), (1, 2, 1, 24))
    h2o, tova = pol.get_policy("h2o"), pol.get_policy("tova")
    np.testing.assert_array_equal(
        np.asarray(h2o.observe(c, probs).scores),
        np.asarray(cachelib.add_scores(c, probs).scores))
    np.testing.assert_array_equal(
        np.asarray(tova.observe(c, probs).scores),
        np.asarray(cachelib.set_scores(c, probs).scores))
    # score-free policies: observe is a no-op
    assert pol.get_policy("lacache").observe(c, probs) is c


# --------------------------------------------------------------------------- #
# Custom policy end-to-end through the model core (the gateway property)
# --------------------------------------------------------------------------- #
def test_custom_policy_drives_decode_without_model_edits():
    from repro.configs.base import LaCacheConfig, ModelConfig
    from repro.models import model as M

    class KeepHalf(pol.EvictionPolicy):
        name = "test-keep-half"

        def keep_mask(self, spec, cache, layer):
            slot = jnp.arange(cache.n_slots)
            keep = (slot < spec.n_sink) | (slot % 2 == 0) \
                | (slot >= cache.length - spec.n_recent)
            return keep & (slot < cache.length)

    try:
        pol.register_policy(KeepHalf)
        cfg = ModelConfig(
            name="t", arch_type="dense", n_layers=2, d_model=32, n_heads=2,
            n_kv_heads=1, d_ff=64, vocab_size=64, head_dim=16, dtype="float32",
            lacache=LaCacheConfig(budget=16, n_sink=2, n_recent=4, chunk=2,
                                  policy="test-keep-half"))
        params, _ = M.init(cfg, jax.random.PRNGKey(0))
        state = M.init_decode_state(params, cfg, 1, 16)
        tok = jnp.zeros((1, 1), jnp.int32)
        for _ in range(40):                        # >> budget => compactions
            lg, state = M.decode_step(params, cfg, state, tok)
        assert np.isfinite(np.asarray(lg)).all()
        caches = [v for v in jax.tree.leaves(
            state.blocks, is_leaf=lambda x: isinstance(x, cachelib.KVCache))
            if isinstance(v, cachelib.KVCache)]
        lengths = np.concatenate(
            [np.atleast_1d(np.asarray(c.length)) for c in caches])
        assert caches and (lengths <= 16).all()
    finally:
        pol._REGISTRY.pop("test-keep-half", None)
