"""Request-level serving engine: scheduler invariants, slot recycling,
per-request sampling, and uniform-batch parity with lockstep generate."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.models import model as M
from repro.serving.engine import (Engine, Request, SamplingParams, Scheduler,
                                  FINISHED, PENDING, RUNNING)


@pytest.fixture(scope="module")
def small_model():
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16, dtype="float32",
        lacache=LaCacheConfig(budget=48, n_sink=2, n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


# --------------------------------------------------------------------------- #
# Scheduler invariants (no model needed)
# --------------------------------------------------------------------------- #
def _req(n=4, new=3):
    return Request(prompt=np.arange(n, dtype=np.int32), max_new_tokens=new)


def test_bucket_len_clamps_at_model_max(small_model):
    """Satellite-bugfix regression: unbounded power-of-two doubling would
    pad a prompt just over a large bucket far past cfg.max_position (and
    any cache budget). Buckets clamp at the model max; prompts beyond it
    dispatch at exact length."""
    cfg, params = small_model
    c = dataclasses.replace(cfg, max_position=64)
    eng = Engine(c, params, budget=48, bucket_prefill=True, min_bucket=16)
    assert eng._bucket_len(10) == 16          # normal power-of-two bucket
    assert eng._bucket_len(16) == 16
    assert eng._bucket_len(17) == 32
    assert eng._bucket_len(50) == 64          # doubling clamps at the max
    assert eng._bucket_len(64) == 64
    assert eng._bucket_len(65) == 65          # past the max: exact length
    assert eng._bucket_len(200) == 200
    # end-to-end: a prompt just over the largest bucket must not dispatch
    # a padded shape beyond max_position
    prompt = np.random.default_rng(0).integers(0, c.vocab_size, (40,))
    eng.submit(prompt, 2)
    eng.run()
    assert all(shape <= 64 for kind, shape in eng.prefill_shapes
               if kind == "prefill")


def test_scheduler_admits_fifo_into_lowest_slots():
    s = Scheduler(2)
    r1, r2, r3 = _req(), _req(), _req()
    s.submit(r1), s.submit(r2), s.submit(r3)
    admitted = s.admit()
    assert [slot for slot, _ in admitted] == [0, 1]
    assert [r for _, r in admitted] == [r1, r2]
    assert r1.status == RUNNING and r3.status == PENDING
    assert s.free_slots == [] and len(s.pending) == 1


def test_scheduler_retire_frees_slot_for_next_admission():
    s = Scheduler(1)
    r1, r2 = _req(), _req()
    s.submit(r1), s.submit(r2)
    assert s.admit() == [(0, r1)]
    assert s.admit() == []                         # full: nothing admitted
    out = s.retire(0)
    assert out is r1 and r1.status == FINISHED and r1.slot == -1
    assert s.free_slots == [0]
    assert s.admit() == [(0, r2)]                  # recycled slot
    assert len(s.running) + len(s.free_slots) == s.n_slots


def test_scheduler_conservation_under_churn():
    s = Scheduler(3)
    reqs = [_req() for _ in range(7)]
    for r in reqs:
        s.submit(r)
    served = []
    while s.has_work:
        s.admit()
        # retire one arbitrary running request per tick
        slot = sorted(s.running)[0]
        served.append(s.retire(slot))
        assert len(s.running) + len(s._free) == s.n_slots
    assert len(served) == len(reqs)
    assert {id(r) for r in served} == {id(r) for r in reqs}  # each exactly once


# --------------------------------------------------------------------------- #
# Engine request layer
# --------------------------------------------------------------------------- #
def test_submit_validates_inputs(small_model):
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=2)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.arange(4), 0)


def test_uniform_batch_matches_lockstep_generate(small_model):
    """Acceptance: >= 3 requests, identical tokens to lockstep generate."""
    cfg, params = small_model
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (3, 24))
    ref = Engine(cfg, params, budget=48).generate(prompts, 10)

    eng = Engine(cfg, params, budget=48, max_batch=4)
    reqs = [eng.submit(prompts[i], 10) for i in range(3)]
    done = eng.run()
    assert [r.request_id for r in done] == [r.request_id for r in reqs]
    for i, r in enumerate(done):
        assert r.status == FINISHED
        np.testing.assert_array_equal(r.tokens, ref[i])


def test_mixed_lengths_per_request_params_and_recycling(small_model):
    """4 requests through 2 slots: per-request prompt lengths, token budgets
    and sampling params are all honored; finished slots are recycled."""
    cfg, params = small_model
    rng = np.random.default_rng(1)
    eng = Engine(cfg, params, budget=48, max_batch=2)
    specs = [(20, 5, SamplingParams()),
             (37, 8, SamplingParams(temperature=0.8, top_k=16, seed=7)),
             (11, 1, SamplingParams()),
             (29, 6, SamplingParams(temperature=1.1, seed=3))]
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, (plen,)), new, sp)
            for plen, new, sp in specs]
    done = eng.run()
    assert len(done) == 4
    for r, (plen, new, _) in zip(done, specs):
        assert r.status == FINISHED
        assert r.prompt_len == plen
        assert len(r.output_tokens) == new         # per-request length honored
        assert all(0 <= t for t in r.output_tokens)
    assert eng.scheduler.free_slots == [0, 1]      # all slots recycled
    assert not eng.scheduler.has_work


def test_step_returns_finishers_and_frees_their_slots(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(2)
    eng = Engine(cfg, params, budget=48, max_batch=2)
    fast = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 1)
    slow = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 4)
    waiting = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 2)

    first = eng.step()
    # `fast` (max_new=1) finishes at admission; its slot frees the same tick
    assert fast in first and fast.status == FINISHED
    assert slow.status == RUNNING
    rest = eng.run()
    assert {r.request_id for r in rest} == {slow.request_id,
                                            waiting.request_id}


def test_greedy_request_isolated_from_batch_mates(small_model):
    """A greedy request's tokens must not depend on what shares the batch."""
    cfg, params = small_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (18,))

    eng_alone = Engine(cfg, params, budget=48, max_batch=3)
    alone = eng_alone.submit(prompt, 6)
    eng_alone.run()

    eng_crowd = Engine(cfg, params, budget=48, max_batch=3)
    crowded = eng_crowd.submit(prompt, 6)
    eng_crowd.submit(rng.integers(0, cfg.vocab_size, (31,)), 9,
                     SamplingParams(temperature=1.0, seed=11))
    eng_crowd.submit(rng.integers(0, cfg.vocab_size, (5,)), 3)
    eng_crowd.run()

    np.testing.assert_array_equal(alone.tokens, crowded.tokens)


def test_more_requests_than_slots_all_complete(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(4)
    eng = Engine(cfg, params, budget=48, max_batch=3)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, (12,)), 2 + i % 3)
            for i in range(8)]
    done = eng.run()
    assert len(done) == 8
    assert [r.request_id for r in done] == [r.request_id for r in reqs]
    assert all(len(r.output_tokens) == r.max_new_tokens for r in done)
