"""Cross-policy differential harness.

Iterates the *eviction-policy registry* (not a hard-coded list) and asserts
for every registered policy that the engine's alternative execution paths
agree:

(a) ``score_stream_chunked`` matches ``score_stream`` token-for-token
    (chunked and stepwise decode are the same computation whenever no
    compaction fires mid-chunk, so the no-overflow case must be exact for
    *every* policy — a newly registered policy that diverges in the
    chunked path fails here without any new test code),
(b) request-mode ``Engine.run`` on a uniform batch matches lockstep
    ``generate`` token-for-token — both without compaction (batch 3) and
    with compaction firing (single request, prompt > budget; batch 1 keeps
    the batch-uniform score accumulation of score-based policies
    identical between the two paths). Both request-mode tests iterate
    ``kv_backend`` as well: the paged backend must not perturb the base
    decode path,
(c) the *paged* KV backend matches the *dense* backend token-for-token
    when requests actually exercise the paged machinery (shared-prefix
    prompt caching) — with and without compaction firing, for every policy.
    Since the in-model paged decode landed, the paged engine decodes
    *through* the block tables end-to-end: prefix hits splice shared
    blocks into the live state, snapshots are refcount forks, and there is
    no gather-to-dense shim anywhere in the decode path — so (c) is the
    CoW/compaction/attention exactness contract of the whole in-model
    subsystem,
(d) the dedicated in-model leg: for every policy x {compaction on, off},
    paged-in-model serving equals dense serving token-for-token on mixed
    cold + prefix-hit traffic, the engine verifiably decoded through
    ``PagedKVCache`` tables (never a dense ``KVCache`` slot state), and
    the pool's refcounts balance after every request retires,
(e) the in-model leg extended to the newly eligible architecture
    families: ring-window, pure-SSM and hybrid stacks run the same
    per-policy x {compaction on, off} matrix — paged serving equals dense
    token-for-token while provably decoding through block tables
    (``PagedKVCache``/``PagedRingCache``/per-lane ``MambaState`` leaves,
    never a dense ``KVCache``/``RingKVCache`` slot state),
(f) self-speculative decoding: with ``Engine(spec_config=...)`` the
    emitted stream is token-for-token identical to non-speculative greedy
    across {dense, paged} x {global, ring, hybrid} x {compaction on, off}.
    Eligible configs (paged + all-global-attn + score-free policy) must
    actually run draft/verify waves; ineligible ones must transparently
    fall back (zero waves) and still match. The draft's block reservation
    is conserved (refcount accounting balances around it) and the engine
    closes leak-free under the sanitizer with the draft loop enabled.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.core import paged as pagedlib
from repro.core.cache import MambaState
from repro.core.policy import policy_names
from repro.models import layers as L
from repro.models import model as M
from repro.serving.engine import Engine, SamplingParams
from repro.serving.speculative import SpecConfig

# snapshot at collection: the harness must cover every registered policy
POLICIES = policy_names()
BACKENDS = ("dense", "paged")


@pytest.fixture(scope="module")
def small_model():
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16, dtype="float32",
        lacache=LaCacheConfig(budget=48, n_sink=2, n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def with_policy(cfg, policy, budget):
    return dataclasses.replace(cfg, lacache=dataclasses.replace(
        cfg.lacache, policy=policy, budget=budget))


def test_harness_covers_all_builtins():
    assert {"lacache", "streaming", "h2o", "tova", "full"} <= set(POLICIES)


@pytest.mark.parametrize("policy", POLICIES)
def test_chunked_scoring_matches_stepwise(policy, small_model):
    """(a) T < budget => no compaction can fire, so chunked teacher-forced
    NLL must equal stepwise NLL token-for-token under every policy."""
    cfg, params = small_model
    eng = Engine(with_policy(cfg, policy, 64), params, budget=64)
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 40))
    ns = eng.score_stream(toks)
    nc = eng.score_stream_chunked(toks, chunk=16)
    np.testing.assert_allclose(nc, ns, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("policy", POLICIES)
def test_chunked_scoring_overflow_finite(policy, small_model):
    """(a') with the stream overflowing the budget, chunked scoring still
    produces finite per-token NLL of the right shape for every policy
    (exactness is only defined modulo intra-chunk compaction timing)."""
    cfg, params = small_model
    eng = Engine(with_policy(cfg, policy, 32), params, budget=32)
    toks = np.random.default_rng(1).integers(0, cfg.vocab_size, (1, 80))
    nc = eng.score_stream_chunked(toks, chunk=16)
    assert nc.shape == (1, 79)
    assert np.isfinite(nc).all()


@pytest.mark.parametrize("kv_backend", BACKENDS)
@pytest.mark.parametrize("policy", POLICIES)
def test_request_mode_matches_lockstep(policy, kv_backend, small_model):
    """(b) uniform batch of 3 requests == lockstep generate, per policy and
    per KV backend (the backend must not perturb the base decode path)."""
    cfg, params = small_model
    c = with_policy(cfg, policy, 48)
    prompts = np.random.default_rng(2).integers(0, cfg.vocab_size, (3, 20))
    ref = Engine(c, params, budget=48).generate(prompts, 8)
    eng = Engine(c, params, budget=48, max_batch=4, kv_backend=kv_backend)
    reqs = [eng.submit(prompts[i], 8) for i in range(3)]
    done = eng.run()
    assert [r.request_id for r in done] == [r.request_id for r in reqs]
    for i, r in enumerate(done):
        np.testing.assert_array_equal(r.tokens, ref[i])


@pytest.mark.slow   # compaction fires every few tokens: heaviest sweep here
@pytest.mark.parametrize("kv_backend", BACKENDS)
@pytest.mark.parametrize("policy", POLICIES)
def test_request_mode_matches_lockstep_with_compaction(policy, kv_backend,
                                                       small_model):
    """(b') prompt + new tokens overflow the budget, so prefill compaction
    and in-decode compaction both fire; a single request against a batch-1
    lockstep reference must still match token-for-token."""
    cfg, params = small_model
    budget = 32
    c = with_policy(cfg, policy, budget)
    n_slots = 80 if policy == "full" else budget   # full never evicts
    prompt = np.random.default_rng(3).integers(0, cfg.vocab_size, (1, 44))
    ref = Engine(c, params, budget=n_slots).generate(prompt, 6)
    eng = Engine(c, params, budget=n_slots, max_batch=2,
                 kv_backend=kv_backend)
    req = eng.submit(prompt[0], 6)
    eng.run()
    np.testing.assert_array_equal(req.tokens, ref[0])


@pytest.mark.parametrize("policy", POLICIES)
def test_paged_backend_matches_dense_prefix_sharing(policy, small_model):
    """(c) shared-prefix traffic through the prompt cache: every snapshot
    pages into the block pool (structural sharing) and every hit gathers a
    working state back — dense and paged backends must agree
    token-for-token under every policy."""
    cfg, params = small_model
    c = with_policy(cfg, policy, 48)
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab_size, (20,))
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab_size, (4 + i,))])
               for i in range(3)]

    def serve(kv_backend):
        eng = Engine(c, params, budget=48, max_batch=2,
                     kv_backend=kv_backend)
        reqs = [eng.submit(p, 6, cache_prefix=True) for p in prompts]
        eng.run()
        return eng, reqs

    _, dense_reqs = serve("dense")
    paged_eng, paged_reqs = serve("paged")
    for d, p in zip(dense_reqs, paged_reqs):
        np.testing.assert_array_equal(p.tokens, d.tokens)
    assert paged_eng.bytes_shared > 0     # the paged path actually engaged


@pytest.mark.parametrize("compaction", [False, True],
                         ids=["no-compaction", "compaction"])
@pytest.mark.parametrize("policy", POLICIES)
def test_paged_in_model_matches_dense(policy, compaction, small_model):
    """(d) the in-model leg: mixed traffic (two prefix-sharing cached
    requests + one cold request) served by ``kv_backend="paged"`` must
    equal the dense backend token-for-token for every registered policy,
    with and without compaction firing mid-stream — while provably
    decoding through block tables (no dense ``KVCache`` in the slot
    states, so no gather shim can hide in the path) and conserving pool
    refcounts once every request retires."""
    cfg, params = small_model
    budget = 24 if compaction else 48
    c = with_policy(cfg, policy, budget)
    # "full" never evicts: give it room so over-budget prompts still fit
    n_slots = 96 if (compaction and policy == "full") else budget
    rng = np.random.default_rng(6)
    base = 30 if compaction else 12     # > budget => prefill compaction
    shared = rng.integers(0, cfg.vocab_size, (base,))
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size,
                                                    (3 + i,))])
               for i in range(2)]
    prompts.append(rng.integers(0, cfg.vocab_size, (base + 7,)))  # cold

    def serve(kv_backend):
        eng = Engine(c, params, budget=n_slots, max_batch=2,
                     kv_backend=kv_backend)
        reqs = [eng.submit(p, 6, cache_prefix=(i < 2))
                for i, p in enumerate(prompts)]
        eng.run()
        return eng, reqs

    _, dense_reqs = serve("dense")
    eng, paged_reqs = serve("paged")
    for d, p in zip(dense_reqs, paged_reqs):
        np.testing.assert_array_equal(p.tokens, d.tokens)
    # the engine really decoded in-model: every slot-state layer cache is a
    # block table, the shared pool planes ride in the state, and no dense
    # KVCache exists anywhere in the serving state
    assert eng._paged_in_model
    leaves = list(eng._slot_states.blocks.values()) \
        + list(eng._slot_states.tail.values())
    assert leaves and all(isinstance(v, pagedlib.PagedKVCache)
                          for v in leaves)
    assert not any(isinstance(v, M.KVCache) for v in leaves)
    assert eng._slot_states.kv_pool is not None
    # refcount conservation: after all retires only the lanes' permanent
    # reservation and the prefix-cache entries hold pool blocks
    pagedlib.check_invariants(eng.kv_store.pool)
    eng.prefix_cache.clear()
    pagedlib.check_invariants(eng.kv_store.pool)
    assert eng.kv_bytes_in_use == eng.lane_owned_bytes


def test_paged_full_policy_at_capacity_matches_dense(small_model):
    """(d') the non-evicting baseline decoding past its buffer: the dense
    cache's append clamp-overwrites the newest slot; paged must mirror it
    token-for-token while copy-on-write keeps the clamped writes out of
    snapshot-shared blocks."""
    cfg, params = small_model
    c = with_policy(cfg, "full", 24)
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, (20,))    # 20 + 8 > budget 24

    def serve(kv_backend):
        eng = Engine(c, params, budget=24, max_batch=1,
                     kv_backend=kv_backend)
        req = eng.submit(prompt, 8, cache_prefix=True)
        eng.run()
        if kv_backend == "paged":
            pagedlib.check_invariants(eng.kv_store.pool)
        return req.tokens

    np.testing.assert_array_equal(serve("paged"), serve("dense"))


@pytest.mark.slow   # over-budget prompts: chunked prefill compacts per chunk
@pytest.mark.parametrize("policy", POLICIES)
def test_paged_backend_matches_dense_with_compaction(policy, small_model):
    """(c') prompts longer than the budget: snapshots are taken of
    *compacted* states (pos reordering disables block sharing instead of
    corrupting it) — backends must still agree token-for-token."""
    cfg, params = small_model
    budget = 32
    c = with_policy(cfg, policy, budget)
    n_slots = 96 if policy == "full" else budget   # full never evicts
    rng = np.random.default_rng(5)
    pre = rng.integers(0, cfg.vocab_size, (40,))
    prompts = [np.concatenate([pre, rng.integers(0, cfg.vocab_size, (6,))])
               for _ in range(2)]

    def serve(kv_backend):
        eng = Engine(c, params, budget=n_slots, max_batch=2,
                     kv_backend=kv_backend)
        reqs = [eng.submit(p, 5, cache_prefix=True) for p in prompts]
        eng.run()
        return [r.tokens for r in reqs]

    for d, p in zip(serve("dense"), serve("paged")):
        np.testing.assert_array_equal(p, d)


# --------------------------------------------------------------------------- #
# (e) newly eligible architectures: ring-window / pure-SSM / hybrid stacks
# --------------------------------------------------------------------------- #
ARCH_KINDS = ("ring", "ssm", "hybrid")


def arch_config(kind: str) -> ModelConfig:
    """Minimal config per newly-eligible family (CPU-fast, one full period)."""
    base = dict(name=f"t-{kind}", arch_type="dense", n_layers=2, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8,
                dtype="float32",
                lacache=LaCacheConfig(budget=24, n_sink=2, n_recent=4,
                                      chunk=2))
    if kind == "ring":
        base.update(local_global_pattern=1, sliding_window=6)
    elif kind == "ssm":
        base.update(arch_type="ssm", attn_every=-1, d_state=8, d_conv=3)
    else:
        # all three layer kinds in one stack: mamba(0), local-attn(1),
        # mamba(2), global-attn(3)
        base.update(arch_type="hybrid", attn_every=2, n_layers=4,
                    local_global_pattern=3, sliding_window=6,
                    d_state=8, d_conv=3)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def arch_models():
    cache = {}

    def get(kind):
        if kind not in cache:
            cfg = arch_config(kind)
            params, _ = M.init(cfg, jax.random.PRNGKey(0))
            cache[kind] = (cfg, params)
        return cache[kind]

    return get


def _assert_paged_in_model_arch(eng, cfg):
    """Decode verifiably went through block tables: every slot-state layer
    leaf is a paged representation (table or per-lane SSM state) and no
    dense slot cache exists anywhere in the serving state."""
    assert eng._paged_in_model
    leaves = list(eng._slot_states.blocks.values()) \
        + list(eng._slot_states.tail.values())
    assert leaves
    allowed = (pagedlib.PagedKVCache, pagedlib.PagedRingCache, MambaState)
    assert all(isinstance(v, allowed) for v in leaves)
    assert not any(isinstance(v, (M.KVCache, L.RingKVCache)) for v in leaves)
    assert eng._slot_states.kv_pool is not None
    specs = cfg.layer_specs()
    if any(s.attn == "local" for s in specs):
        assert any(isinstance(v, pagedlib.PagedRingCache) for v in leaves)
        # the ring tables really map pool blocks (content lives in-pool)
        ring = next(v for v in leaves
                    if isinstance(v, pagedlib.PagedRingCache))
        assert (np.asarray(ring.blocks) >= 0).any()
    if any(s.kind == "mamba" for s in specs):
        assert any(isinstance(v, MambaState) for v in leaves)
    if any(s.attn == "global" for s in specs):
        assert any(isinstance(v, pagedlib.PagedKVCache) for v in leaves)


@pytest.mark.parametrize(
    "compaction",
    [False,
     # the compaction leg doubles the sweep; the fast CI lane keeps the
     # no-compaction matrix and tier-1 runs both
     pytest.param(True, marks=pytest.mark.slow)],
    ids=["no-compaction", "compaction"])
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("kind", ARCH_KINDS)
def test_paged_in_model_matches_dense_ring_ssm_hybrid(kind, policy,
                                                      compaction,
                                                      arch_models):
    """(e) ring/SSM/hybrid stacks through the in-model paged path: mixed
    traffic (two prefix-sharing cached requests + one cold request) under
    ``kv_backend="paged"`` equals the dense backend token-for-token for
    every registered policy, with and without compaction firing — while
    provably decoding through block tables (ring residue tables, per-lane
    SSM states, budgeted KV tables; no dense slot state anywhere) and
    conserving pool refcounts once every request retires."""
    if kind == "ssm" and compaction:
        pytest.skip("pure-SSM stacks have no KV cache: compaction is "
                    "structurally a no-op (covered by the other leg)")
    cfg, params = arch_models(kind)
    budget = 12 if compaction else 24
    c = with_policy(cfg, policy, budget)
    n_slots = 64 if (compaction and policy == "full") else budget
    rng = np.random.default_rng(7)
    base = 16 if compaction else 8      # > budget => prefill compaction
    shared = rng.integers(0, cfg.vocab_size, (base,))
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size,
                                                    (3 + i,))])
               for i in range(2)]
    prompts.append(rng.integers(0, cfg.vocab_size, (base + 5,)))  # cold

    def serve(kv_backend):
        eng = Engine(c, params, budget=n_slots, max_batch=2,
                     kv_backend=kv_backend)
        reqs = [eng.submit(p, 6, cache_prefix=(i < 2))
                for i, p in enumerate(prompts)]
        eng.run()
        return eng, reqs

    _, dense_reqs = serve("dense")
    eng, paged_reqs = serve("paged")
    for d, p in zip(dense_reqs, paged_reqs):
        np.testing.assert_array_equal(p.tokens, d.tokens)
    _assert_paged_in_model_arch(eng, cfg)
    pagedlib.check_invariants(eng.kv_store.pool)
    eng.prefix_cache.clear()
    pagedlib.check_invariants(eng.kv_store.pool)
    assert eng.kv_bytes_in_use == eng.lane_owned_bytes


# --------------------------------------------------------------------------- #
# Sanitized serving scenarios (REPRO_SANITIZE=1)
#
# The same traffic shapes as the parity tests above, but with the runtime
# pool sanitizer armed: every allocator op re-checks the pool invariants,
# every step audits lane CoW/refcount state, and ``Engine.close()``
# asserts ZERO leaked blocks once lanes retire, parked preemption parcels
# drop and the prefix cache clears. Slow-marked: the per-op invariant
# sweep is O(pool) python work on every allocator call.
# --------------------------------------------------------------------------- #
@pytest.fixture
def _sanitized(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")


def _close_clean(eng):
    assert eng._sanitizer is not None       # the env flag really engaged
    eng.close()                             # raises SanitizerError on leaks
    ref = np.asarray(eng.kv_store.pool.ref)
    live = int((ref > 0).sum())
    reserved = eng.lane_owned_bytes // eng.kv_store.pool.block_bytes
    assert live == reserved                 # only lane reservations remain


@pytest.mark.slow
def test_sanitized_mixed_prefix_traffic_drains_pool(_sanitized, small_model):
    """Prefix-sharing + cold traffic under the sanitizer: paged still
    matches dense token-for-token, and the pool drains at close()."""
    cfg, params = small_model
    rng = np.random.default_rng(31)
    shared = rng.integers(0, cfg.vocab_size, (20,))
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size,
                                                    (4 + i,))])
               for i in range(3)]
    prompts.append(rng.integers(0, cfg.vocab_size, (26,)))      # cold

    def serve(kv_backend):
        eng = Engine(cfg, params, budget=48, max_batch=2,
                     kv_backend=kv_backend)
        reqs = [eng.submit(p, 6, cache_prefix=(i < 3))
                for i, p in enumerate(prompts)]
        eng.run()
        return eng, reqs

    _, dense_reqs = serve("dense")
    eng, paged_reqs = serve("paged")
    for d, p in zip(dense_reqs, paged_reqs):
        np.testing.assert_array_equal(p.tokens, d.tokens)
    _close_clean(eng)


@pytest.mark.slow
def test_sanitized_preempt_resume_drains_pool(_sanitized, small_model):
    """Deadline preemption + resume with the sanitizer armed: the handoff
    (lane -> parcel -> lane) must neither leak nor double-release, the
    resumed request still matches an uninterrupted run, and the pool
    drains at close()."""
    cfg, params = small_model
    rng = np.random.default_rng(32)
    pa = rng.integers(0, cfg.vocab_size, (20,))
    pb = rng.integers(0, cfg.vocab_size, (12,))

    ref = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged",
                 admission="deadline")
    ra = ref.submit(pa, 10, deadline=10.0)
    ref.run()
    _close_clean(ref)

    eng = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged",
                 admission="deadline")
    a = eng.submit(pa, 10, deadline=10.0)
    for _ in range(4):
        eng.step()
    b = eng.submit(pb, 3, deadline=1.0)     # earlier deadline: preempts A
    eng.step()
    assert a.status == "pending" and eng.preemptions == 1
    eng.run()
    np.testing.assert_array_equal(a.tokens, ra.tokens)
    _close_clean(eng)


@pytest.mark.slow
def test_sanitized_close_releases_parked_parcel(_sanitized, small_model):
    """Shutdown with a preempted request still PENDING: close() must
    dispose of the parked parcel's travelling references (and settle any
    prefix-cache charge it carried) — the pool drains without the request
    ever resuming."""
    cfg, params = small_model
    rng = np.random.default_rng(33)
    eng = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged",
                 admission="deadline")
    a = eng.submit(rng.integers(0, cfg.vocab_size, (20,)), 10,
                   deadline=10.0, cache_prefix=True)
    for _ in range(4):
        eng.step()
    eng.submit(rng.integers(0, cfg.vocab_size, (12,)), 8, deadline=1.0)
    eng.step()
    assert a.status == "pending" and a._resume is not None
    _close_clean(eng)                       # parcel dropped, zero leaks


@pytest.mark.slow
def test_sanitized_eviction_churn_drains_pool(_sanitized, small_model):
    """Prefix-cache eviction churn (a byte budget of ~one snapshot, so
    every insert evicts while the lane still reads the blocks) under the
    sanitizer: charges settle at retirement and the pool drains."""
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged",
                 prefix_cache_bytes=40_000)
    rng = np.random.default_rng(34)
    for _ in range(4):
        eng.submit(rng.integers(0, cfg.vocab_size, (40,)), 3,
                   cache_prefix=True)
        eng.run()
    assert eng.prefix_cache.evictions > 0   # the churn actually happened
    _close_clean(eng)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ARCH_KINDS)
def test_sanitized_arch_serving_drains_pool(kind, _sanitized, arch_models):
    """Ring / SSM / hybrid stacks under the sanitizer: paged ring windows
    and per-lane SSM states go through the same lane lifecycle, so their
    pools must drain identically at close()."""
    cfg, params = arch_models(kind)
    rng = np.random.default_rng(35)
    shared = rng.integers(0, cfg.vocab_size, (8,))
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size,
                                                    (3 + i,))])
               for i in range(2)]
    eng = Engine(cfg, params, budget=24, max_batch=2, kv_backend="paged")
    for i, p in enumerate(prompts):
        eng.submit(p, 5, cache_prefix=(i < 2))
    eng.run()
    _close_clean(eng)


# --------------------------------------------------------------------------- #
# (f) self-speculative decoding through a ladder-compacted draft cache
# --------------------------------------------------------------------------- #
SPEC_KINDS = ("global", "ring", "hybrid")


@pytest.mark.parametrize(
    "compaction",
    [False, pytest.param(True, marks=pytest.mark.slow)],
    ids=["no-compaction", "compaction"])
@pytest.mark.parametrize("kv_backend", BACKENDS)
@pytest.mark.parametrize("kind", SPEC_KINDS)
def test_spec_matches_nonspec_greedy(kind, kv_backend, compaction,
                                     small_model, arch_models):
    """(f) spec on == spec off token-for-token on mixed-length greedy
    traffic. All-global paged configs must really run waves (when no
    compaction pressure keeps the headroom gate shut); dense backends and
    ring/hybrid stacks are ineligible and must fall back with zero waves
    while still matching exactly."""
    cfg, params = small_model if kind == "global" else arch_models(kind)
    budget = (24 if compaction else 48) if kind == "global" else \
        (12 if compaction else 24)
    rng = np.random.default_rng(41)
    base = budget + 6 if compaction else budget // 4
    prompts = [rng.integers(0, cfg.vocab_size, (base + 3 * i,))
               for i in range(3)]

    def serve(spec):
        eng = Engine(cfg, params, budget=budget, max_batch=2,
                     kv_backend=kv_backend,
                     spec_config=SpecConfig(k=3) if spec else None)
        reqs = [eng.submit(p, 8) for p in prompts]
        eng.run()
        return eng, [r.tokens for r in reqs]

    _, base_toks = serve(spec=False)
    eng, spec_toks = serve(spec=True)
    for b, s in zip(base_toks, spec_toks):
        np.testing.assert_array_equal(s, b)
    eligible = kv_backend == "paged" and kind == "global"
    assert (eng._spec is not None and eng._spec.enabled) == eligible
    if eligible and not compaction:
        assert eng.spec_stats["waves"] > 0          # waves really ran
    if not eligible:
        assert eng.spec_stats["waves"] == 0         # transparent fallback


def test_spec_draft_refcount_conservation(small_model):
    """(f) the draft view's block reservation is conserved: pool
    invariants hold after every request retires, the byte accounting
    splits exactly into lane reservations + the draft reservation, the
    per-request acceptance telemetry is populated and consistent, and
    ``close()`` returns the pool to lane-reservations-only."""
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=2, kv_backend="paged",
                 spec_config=SpecConfig(k=4))
    rng = np.random.default_rng(42)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, (10 + 2 * i,)), 8,
                       cache_prefix=(i < 2)) for i in range(3)]
    eng.run()
    assert eng.spec_stats["waves"] > 0
    stats = eng.spec_stats
    assert stats["proposed"] >= stats["accepted"] >= 0
    assert sum(r.spec_proposed for r in reqs) == stats["proposed"]
    assert sum(r.spec_accepted for r in reqs) == stats["accepted"]
    for r in reqs:
        assert r.spec_waves > 0
        assert 0.0 <= r.spec_acceptance_rate <= 1.0
    pagedlib.check_invariants(eng.kv_store.pool)
    eng.prefix_cache.clear()
    pagedlib.check_invariants(eng.kv_store.pool)
    assert eng.draft_owned_bytes > 0
    assert eng.kv_bytes_in_use == eng.lane_owned_bytes \
        + eng.draft_owned_bytes
    eng.close()                     # releases the draft reservation
    ref = np.asarray(eng.kv_store.pool.ref)
    lanes = eng.lane_owned_bytes // eng.kv_store.pool.block_bytes
    assert int((ref > 0).sum()) == lanes


def test_spec_stochastic_mix_resumes_waves(small_model):
    """Satellite: one stochastic request among greedy lanes forces the
    whole-batch stepwise fallback only while it is actually RUNNING —
    after it retires, waves resume on the remaining greedy lanes — and
    every stream (including the sampled one) matches the non-spec engine
    token-for-token (the verify gate makes waves semantically invisible;
    the sampled lane always decodes stepwise)."""
    cfg, params = small_model
    rng = np.random.default_rng(47)
    prompts = [rng.integers(0, cfg.vocab_size, (10 + 2 * i,))
               for i in range(3)]

    def serve(spec):
        eng = Engine(cfg, params, budget=48, max_batch=4,
                     kv_backend="paged",
                     spec_config=SpecConfig(k=3) if spec else None)
        reqs = [eng.submit(prompts[0], 4,
                           SamplingParams(temperature=0.9, top_k=16,
                                          seed=9))]
        reqs += [eng.submit(p, 14) for p in prompts[1:]]
        eng.run()
        return eng, [r.tokens for r in reqs]

    _, base_toks = serve(spec=False)
    eng, spec_toks = serve(spec=True)
    for b, s in zip(base_toks, spec_toks):
        np.testing.assert_array_equal(s, b)
    st = eng.spec_stats
    # the stochastic lane forced fallbacks AND waves still ran after it
    # retired — the old whole-batch invalidate permanently taxed this mix
    assert st["fallback_steps"] > 0
    assert st["waves"] > 0


def test_spec_fallback_keeps_draft_fork_alive(small_model):
    """Satellite: a stepwise fallback no longer kills the persistent
    draft. Under compaction pressure the headroom gate flips between
    waves and stepwise ticks; the draft must survive every flip (exactly
    one fork for the whole single-request serve) with the lag replayed
    through catch-up steps — while staying token-for-token with the
    non-spec engine."""
    cfg, params = small_model
    c = with_policy(cfg, "lacache", 24)
    rng = np.random.default_rng(48)
    prompt = rng.integers(0, cfg.vocab_size, (30,))

    def serve(spec):
        eng = Engine(c, params, budget=24, max_batch=1, kv_backend="paged",
                     spec_config=SpecConfig(k=2) if spec else None)
        req = eng.submit(prompt, 12)
        eng.run()
        return eng, req.tokens

    _, base_toks = serve(spec=False)
    eng, spec_toks = serve(spec=True)
    np.testing.assert_array_equal(spec_toks, base_toks)
    st = eng.spec_stats
    assert st["waves"] > 0 and st["fallback_steps"] > 0, \
        "scenario must exercise both wave and fallback ticks"
    assert st["catchup_steps"] > 0      # the lag replay actually ran
    assert st["forks"] == 1, \
        f"draft re-forked {st['forks']}x: a fallback invalidated it"


def test_spec_rng_first_token_regression(small_model):
    """Satellite: stochastic ``generate`` must split the PRNG key before
    the FIRST sample — a 1-token run and a longer run agree on token 0
    (the old unsplit-key draw correlated token 0 with the rest of the
    chain and diverged from the k>1 run's first token)."""
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48)
    prompts = np.random.default_rng(43).integers(0, cfg.vocab_size, (4, 12))
    one = eng.generate(prompts, 1, temperature=0.9, top_k=16, seed=5)
    many = eng.generate(prompts, 6, temperature=0.9, top_k=16, seed=5)
    np.testing.assert_array_equal(one[:, 0], many[:, 0])
    # the discriminating check: every draw (including the first) must come
    # from a fresh subkey of the chain, never from the root key itself
    from repro.serving import sampling
    logits, state = eng.prefill(jnp.asarray(prompts))
    key = jax.random.PRNGKey(5)
    expect = []
    for _ in range(6):
        key, sub = jax.random.split(key)
        tok = sampling.sample(sub, logits, 0.9, 16)[:, None]
        expect.append(np.asarray(tok[:, 0]))
        logits, state = eng._decode(eng.params, state=state, tokens=tok)
    np.testing.assert_array_equal(many, np.stack(expect, axis=1))


def test_prewarm_engine_matches_cold(small_model):
    """Satellite: ``Engine(prewarm=True)`` pre-compiles the batched
    decode/chunk/fork dispatches at construction without perturbing the
    served stream (lane resets erase the warmup garbage)."""
    cfg, params = small_model
    rng = np.random.default_rng(44)
    prompts = [rng.integers(0, cfg.vocab_size, (10 + i,)) for i in range(3)]

    def serve(prewarm):
        eng = Engine(cfg, params, budget=48, max_batch=2,
                     kv_backend="paged", spec_config=SpecConfig(k=3),
                     prewarm=prewarm)
        reqs = [eng.submit(p, 6) for p in prompts]
        eng.run()
        return [r.tokens for r in reqs]

    for c, w in zip(serve(False), serve(True)):
        np.testing.assert_array_equal(w, c)


def test_prewarm_prefill_ladder_matches_cold(small_model):
    """Satellite: with bucketed prefill, ``prewarm=True`` walks the whole
    prefill bucket ladder (plus the page-in splice) at construction — the
    former wave-1 compile soft spot — without perturbing tokens, and
    wave 1 then dispatches only shapes the ladder already compiled."""
    cfg, params = small_model
    rng = np.random.default_rng(49)
    prompts = [rng.integers(0, cfg.vocab_size, (9 + 7 * i,))
               for i in range(3)]

    def serve(prewarm, prewarm_prefill=True):
        eng = Engine(cfg, params, budget=48, max_batch=2,
                     kv_backend="paged", bucket_prefill=True, min_bucket=8,
                     prewarm=prewarm, prewarm_prefill=prewarm_prefill)
        reqs = [eng.submit(p, 6) for p in prompts]
        eng.run()
        return eng, [r.tokens for r in reqs]

    _, cold = serve(False)
    eng, warm = serve(True)
    for c, w in zip(cold, warm):
        np.testing.assert_array_equal(w, c)
    # every wave-1 prefill dispatch landed in a power-of-two bucket the
    # ladder covers (>= min_bucket, <= the warmed top)
    for kind, shape in eng.prefill_shapes:
        if kind == "prefill":
            assert shape >= 8 and (shape & (shape - 1)) == 0
    # prewarm_prefill=False preserves the old decode-only warm scope
    _, noladder = serve(True, prewarm_prefill=False)
    for c, w in zip(cold, noladder):
        np.testing.assert_array_equal(w, c)


@pytest.mark.slow
def test_sanitized_spec_serving_drains_pool(_sanitized, small_model):
    """(f) the draft loop under the sanitizer: every wave's retain/release
    pair balances (the per-op audits would raise on a use-after-free or a
    writable shared block), waves really run, and close() releases the
    draft reservation down to lane-reservations-only."""
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=2, kv_backend="paged",
                 spec_config=SpecConfig(k=3), prewarm=True)
    rng = np.random.default_rng(45)
    shared = rng.integers(0, cfg.vocab_size, (10,))
    for i in range(3):
        eng.submit(np.concatenate(
            [shared, rng.integers(0, cfg.vocab_size, (3 + i,))]), 6,
            cache_prefix=(i < 2))
    eng.run()
    assert eng.spec_stats["waves"] > 0
    _close_clean(eng)


def _spec_churn_ops(ops, small_model):
    """Drive spec + non-spec engines through the same submit/step/drain
    interleaving (tight budget so live compaction fires between waves and
    the headroom gate flips between wave and stepwise fallback) and
    assert token equality plus pool invariants after every op."""
    cfg, params = small_model
    c = with_policy(cfg, "lacache", 24)
    rng = np.random.default_rng(46)
    plan = [rng.integers(0, cfg.vocab_size, (int(rng.integers(8, 30)),))
            for _ in range(6)]

    def serve(spec):
        eng = Engine(c, params, budget=24, max_batch=2, kv_backend="paged",
                     spec_config=SpecConfig(k=2) if spec else None)
        reqs, nxt = [], 0
        for op in ops:
            if op == "submit" and nxt < len(plan):
                reqs.append(eng.submit(plan[nxt], 5))
                nxt += 1
            elif op == "step":
                eng.step()
            elif op == "drain":
                eng.run()
            pagedlib.check_invariants(eng.kv_store.pool)
        while nxt < len(plan):                     # serve the full plan
            reqs.append(eng.submit(plan[nxt], 5))
            nxt += 1
        eng.run()
        pagedlib.check_invariants(eng.kv_store.pool)
        return eng, [r.tokens for r in reqs]

    _, base_toks = serve(spec=False)
    eng, spec_toks = serve(spec=True)
    for b, s in zip(base_toks, spec_toks):
        np.testing.assert_array_equal(s, b)
    eng.close()
    ref = np.asarray(eng.kv_store.pool.ref)
    lanes = eng.lane_owned_bytes // eng.kv_store.pool.block_bytes
    assert int((ref > 0).sum()) == lanes


def test_spec_churn_deterministic(small_model):
    """(f) a fixed branch-covering interleaving (runs without hypothesis):
    waves fire against lanes that compact mid-stream, admissions splice
    lanes while the draft reservation is live, and drains retire lanes
    between waves."""
    _spec_churn_ops(["submit", "step", "step", "submit", "step", "drain",
                     "submit", "submit", "step", "step", "step", "drain"],
                    small_model)


@pytest.mark.slow
def test_spec_churn_property(small_model):
    """(f) hypothesis property: any submit/step/drain interleaving keeps
    spec == non-spec token-for-token while conserving pool refcounts
    around the draft fork/discard churn."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=6, deadline=None)
    @given(st.lists(st.sampled_from(["submit", "step", "drain"]),
                    min_size=2, max_size=10))
    def run(ops):
        _spec_churn_ops(ops, small_model)

    run()
