"""Hypothesis property tests on system invariants (cache state machine,
sharding spec safety, iterative compaction)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import cache as cachelib, ladder
from repro.core.ladder import LadderSpec


@st.composite
def cache_scenario(draw):
    n_layers = draw(st.integers(2, 12))
    span = draw(st.integers(1, n_layers))
    overlap = draw(st.integers(0, max(0, span - 1)))
    chunk = draw(st.integers(1, 4))
    n_sink = draw(st.integers(0, 3))
    n_recent = draw(st.integers(1, 8))
    budget = draw(st.integers(n_sink + n_recent + 4 * chunk, 48))
    layer = draw(st.integers(0, n_layers - 1))
    n_append = draw(st.integers(1, 120))
    spec = LadderSpec(n_layers=n_layers, span=span, overlap=overlap,
                      chunk=chunk, n_sink=n_sink, n_recent=n_recent,
                      budget=budget)
    return spec, layer, n_append


@given(cache_scenario(), st.sampled_from(["lacache", "streaming"]))
@settings(max_examples=25, deadline=None)
def test_cache_state_machine_invariants(scn, policy):
    """Append tokens one at a time with maybe_compact: length never exceeds
    the buffer; positions stay sorted (age order); newest token is present;
    sinks (original first tokens) are never evicted once past warmup."""
    spec, layer, n_append = scn
    c = cachelib.init_cache(1, spec.budget, 1, 4, jnp.float32)
    for t in range(n_append):
        c = cachelib.maybe_compact(c, spec, layer, policy, 1)
        k = jnp.full((1, 1, 1, 4), float(t))
        c = cachelib.append(c, k, k, jnp.asarray([t], jnp.int32))
        assert int(c.length) <= spec.budget
    pos = np.asarray(c.pos[: int(c.length)])
    assert (np.diff(pos) > 0).all()
    assert pos[-1] == n_append - 1
    if n_append > spec.budget and spec.n_sink:
        assert (pos[:spec.n_sink] == np.arange(spec.n_sink)).all()
    # k payloads track positions through gathers
    kvals = np.asarray(c.k[0, : int(c.length), 0, 0]).astype(int)
    np.testing.assert_array_equal(kvals, pos)


@given(cache_scenario())
@settings(max_examples=15, deadline=None)
def test_union_coverage_of_ladder_across_layers(scn):
    """Across layers, retained original positions cover a window at least as
    large as any single layer's (the 'extended span' Fig. 2 claim)."""
    spec, _, _ = scn
    n_append = 4 * spec.budget
    sim = ladder.simulate_stream(spec, n_append, policy="lacache")
    per_layer_max = max(len(set(k)) for k in sim.kept)
    assert sim.union_span() >= per_layer_max


def test_partition_spec_safety():
    from jax.sharding import PartitionSpec as P
    from repro.launch import axes as axlib
    rules = {"batch": ("pod", "data"), "model": "model", "fsdp": "data"}
    spec = axlib.to_partition_spec(("batch", None, "model"), rules)
    assert spec == P(("pod", "data"), None, "model")
    # duplicate mesh axes are dropped (can't use the same axis twice)
    spec2 = axlib.to_partition_spec(("fsdp", "fsdp"), rules)
    assert spec2 == P("data", None)


def test_adamw_decreases_quadratic():
    from repro.optim import adamw
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5
