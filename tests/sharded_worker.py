"""Subprocess worker for the sharded differential leg (g).

``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must be set before
jax initializes, which a pytest process that already imported jax cannot
do — so the differential tests (``tests/test_sharded_differential.py``)
exec this script in a fresh interpreter. It runs BOTH engines of each
case (single-device paged, then mesh-sharded paged) in the same process,
asserts token-for-token parity plus free-list conservation, and prints a
JSON verdict on stdout. Any assertion failure exits non-zero with the
detail on stderr.

Protocol: ``python tests/sharded_worker.py '<json>'`` where the payload is
``{"cases": [{"kind", "admission", "compaction"}...], "mesh": [d, m],
"sanitize": bool, "impl": null | "pallas"}``. When ``sanitize`` is set the
worker also re-execs semantics-wise: REPRO_SANITIZE must already be in the
environment at engine construction (the caller sets it), and the zero-leak
``close()`` audit runs with per-block allocation sites armed.
"""
import json
import os
import sys

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + _FLAG).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import LaCacheConfig, ModelConfig  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.serving.engine import Engine  # noqa: E402

PAGE_SIZE = 8
_MODELS = {}


def build_model(kind: str, budget: int):
    """One miniature per family; n_kv_heads=2 so a model-axis extent of 2
    takes the bitwise-clean kv-head-sharded route (leg (g) asserts exact
    token parity, which the slot-sharded partial-softmax merge — a
    different summation order — does not promise)."""
    key = (kind, budget)
    if key in _MODELS:
        return _MODELS[key]
    base = dict(name=f"t-{kind}", arch_type="dense", n_layers=3, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                head_dim=16, dtype="float32",
                lacache=LaCacheConfig(budget=budget, n_sink=2, n_recent=4,
                                      chunk=2))
    if kind == "ring":
        base.update(n_layers=2, local_global_pattern=1, sliding_window=6)
    elif kind == "hybrid":
        base.update(arch_type="hybrid", attn_every=2, n_layers=4,
                    local_global_pattern=3, sliding_window=6,
                    d_state=8, d_conv=3)
    elif kind != "global":
        raise ValueError(f"unknown kind {kind!r}")
    cfg = ModelConfig(**base)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    _MODELS[key] = (cfg, params)
    return cfg, params


def serve(cfg, params, mesh, admission, budget, prompts, max_new):
    eng = Engine(cfg, params, budget=budget, max_batch=4,
                 kv_backend="paged", page_size=PAGE_SIZE,
                 admission=admission, mesh=mesh)
    for i, p in enumerate(prompts):
        kw = {"deadline": 100.0 + i} if admission == "deadline" else {}
        eng.submit(p, max_new, **kw)
    done = eng.run()
    toks = {r.request_id: r.tokens.tolist() for r in done}
    # free-list conservation: every block is either free or referenced
    # (the refcount array keeps its full size after plane detach — the
    # planes themselves live in the decode state)
    pool = eng.kv_store.pool
    ref = np.asarray(pool.ref)
    assert int(pool.n_free) + int((ref > 0).sum()) == ref.shape[0], \
        f"free-list leak: n_free={int(pool.n_free)} " \
        f"in_use={int((ref > 0).sum())} total={ref.shape[0]}"
    per_dev = eng.kv_pool_bytes_per_device
    eng.close()       # zero-leak shutdown audit (loud under sanitizer)
    return toks, per_dev


def run_case(case, mesh_shape):
    kind = case["kind"]
    admission = case["admission"]
    compaction = case["compaction"]
    # compaction=True: prompt + new tokens overflow the budget so prefill
    # AND in-decode ladder compaction both fire (with the RoPE slot-delta
    # fixup) under sharding
    budget = 24 if compaction else 48
    plen = 30 if compaction else 16
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 128, (plen - 4 * i,)).astype(np.int64)
               for i in range(3)]
    cfg, params = build_model(kind, budget)
    single, single_bytes = serve(cfg, params, None, admission, budget,
                                 prompts, 6)
    mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    sharded, shard_bytes = serve(cfg, params, mesh, admission, budget,
                                 prompts, 6)
    assert sharded == single, \
        f"token mismatch [{kind}/{admission}/compaction={compaction}]: " \
        f"{sharded} != {single}"
    m = mesh_shape[1]
    assert single_bytes == m * shard_bytes, \
        f"per-device plane bytes {shard_bytes} != single {single_bytes}/{m}"
    return {"kind": kind, "admission": admission, "compaction": compaction,
            "tokens_match": True,
            "bytes_per_device": {"single": single_bytes,
                                 "sharded": shard_bytes}}


def main():
    spec = json.loads(sys.argv[1])
    if spec.get("impl"):
        os.environ["REPRO_KERNEL_IMPL"] = spec["impl"]
    assert len(jax.devices()) >= 8, \
        f"forced host device count did not take: {len(jax.devices())}"
    results = [run_case(c, spec.get("mesh", [4, 2]))
               for c in spec["cases"]]
    print(json.dumps({"ok": True, "cases": results}))


if __name__ == "__main__":
    main()
