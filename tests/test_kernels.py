"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rnd(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,tq,h,kv,d", [
    (1, 32, 4, 4, 32), (2, 64, 4, 2, 64), (1, 48, 8, 1, 32), (2, 33, 4, 2, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 12])
def test_flash_attention_vs_ref(b, tq, h, kv, d, dtype, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rnd(ks[0], (b, tq, h, d), dtype)
    k = rnd(ks[1], (b, tq, kv, d), dtype)
    v = rnd(ks[2], (b, tq, kv, d), dtype)
    o_ref = ref.mha_reference(q, k, v, causal=True, window=window)
    o_pl = ops.flash_attention(q, k, v, causal=True, window=window,
                               impl="pallas", block_q=16, block_k=16)
    o_xla = ops.flash_attention(q, k, v, causal=True, window=window,
                                impl="xla", block_k=16)
    np.testing.assert_allclose(np.float32(o_pl), np.float32(o_ref),
                               atol=TOL[dtype], rtol=TOL[dtype])
    np.testing.assert_allclose(np.float32(o_xla), np.float32(o_ref),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("b,h,kv,d,s", [
    (1, 4, 4, 32, 64), (2, 8, 2, 64, 96), (2, 4, 1, 32, 40),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("frac", [0.3, 1.0])
def test_decode_attention_vs_ref(b, h, kv, d, s, dtype, frac):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rnd(ks[0], (b, h, d), dtype)
    k = rnd(ks[1], (b, s, kv, d), dtype)
    v = rnd(ks[2], (b, s, kv, d), dtype)
    length = jnp.asarray(int(s * frac), jnp.int32)
    o_ref = ref.decode_attention_reference(q, k, v, length)
    o_pl = ops.decode_attention(q, k, v, length, impl="pallas", block_s=16)
    np.testing.assert_allclose(np.float32(o_pl), np.float32(o_ref),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("b,s,feat", [(1, 32, (4, 16)), (2, 64, (2, 8)),
                                      (2, 40, (24,))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_gather_compact_vs_ref(b, s, feat, dtype):
    key = jax.random.PRNGKey(2)
    if dtype == jnp.int32:
        x = jax.random.randint(key, (b, s) + feat, 0, 100, jnp.int32)
    else:
        x = rnd(key, (b, s) + feat, dtype)
    perm = jnp.asarray(np.random.default_rng(0).permutation(s), jnp.int32)
    nl = jnp.asarray(s * 2 // 3, jnp.int32)
    g_ref = ref.gather_compact_reference(x, perm, nl)
    g_pl = ops.gather_compact(x, perm, nl, impl="pallas")
    np.testing.assert_array_equal(np.asarray(g_pl), np.asarray(g_ref))


@pytest.mark.parametrize("b,t,d,n", [(1, 16, 32, 4), (2, 40, 64, 16),
                                     (1, 33, 128, 8)])
def test_ssm_scan_vs_ref(b, t, d, n):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(b, t, d)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(b, t, d)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, size=(d, n)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, d, n)), jnp.float32)
    y_ref, h_ref = ref.ssm_scan_reference(x, dt, A, B, C, D, h0)
    from repro.kernels.ssm_scan import ssm_scan
    y_pl, h_pl = ssm_scan(x, dt, A, B, C, D, h0, block_d=32, t_chunk=16)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(h_pl), np.asarray(h_ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_state_continuity_chunked_vs_onepass():
    """Flash q_offset chunked prefill == one-pass attention."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    b, t, h, kv, d = 1, 48, 4, 2, 32
    q = rnd(ks[0], (b, t, h, d), jnp.float32)
    k = rnd(ks[1], (b, t, kv, d), jnp.float32)
    v = rnd(ks[2], (b, t, kv, d), jnp.float32)
    full = ref.mha_reference(q, k, v, causal=True)
    half = t // 2
    o1 = ops.flash_attention(q[:, :half], k[:, :half], v[:, :half],
                             causal=True, impl="pallas", block_q=8, block_k=8)
    o2 = ops.flash_attention(q[:, half:], k, v, causal=True, q_offset=half,
                             impl="pallas", block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(full), atol=2e-5, rtol=2e-5)


def _verify_layout(rng, b, n_slots, bs, kvh, d, totals):
    """Random pool + block tables mapping each lane's first ``totals[i]``
    slots (spare unmapped blocks left in the pool, -1 rows past the end)."""
    n_blocks = b * (n_slots // bs) + 4
    kp = jnp.asarray(rng.normal(size=(n_blocks, bs, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_blocks, bs, kvh, d)), jnp.float32)
    tables = -np.ones((b, n_slots // bs), np.int32)
    perm, idx = rng.permutation(n_blocks), 0
    for i in range(b):
        nb = -(-int(totals[i]) // bs)
        tables[i, :nb] = perm[idx:idx + nb]
        idx += nb
    return kp, vp, jnp.asarray(tables)


@pytest.mark.parametrize("b,h,kv,d,T", [
    (2, 4, 2, 16, 4), (1, 4, 4, 32, 1), (3, 4, 1, 16, 3),
])
def test_paged_verify_attention_matches_stepwise(b, h, kv, d, T):
    """The multi-token verify dispatch == T sequential single-token paged
    decode dispatches: position j of the chunk output must equal a
    single-query call whose occupied length stops at that position (the
    causal+offset masking contract behind spec-decode verification)."""
    rng = np.random.default_rng(8)
    bs, n_slots = 4, 32
    base = rng.integers(1, n_slots - T + 1, size=b)
    totals = base + T                     # chunk K/V already appended
    kp, vp, tables = _verify_layout(rng, b, n_slots, bs, kv, d, totals)
    q = rnd(jax.random.PRNGKey(11), (b, T, h, d), jnp.float32)
    o_chunk = ops.paged_verify_attention(
        q, kp, vp, tables, jnp.asarray(totals, jnp.int32),
        jnp.asarray(base, jnp.int32), n_slots=n_slots)
    assert o_chunk.shape == (b, T, h, d)
    for j in range(T):
        o_j = ops.paged_decode_attention(
            q[:, j], kp, vp, tables, jnp.asarray(base + j + 1, jnp.int32),
            n_slots=n_slots)
        np.testing.assert_allclose(np.asarray(o_chunk[:, j]),
                                   np.asarray(o_j), atol=2e-5, rtol=2e-5)
    # return_probs: same output plus row-stochastic probabilities over the
    # logical view, zero beyond each query's causal frontier
    o_p, probs = ops.paged_verify_attention(
        q, kp, vp, tables, jnp.asarray(totals, jnp.int32),
        jnp.asarray(base, jnp.int32), n_slots=n_slots, return_probs=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_chunk),
                               atol=2e-5, rtol=2e-5)
    assert probs.shape == (b, h, T, n_slots)
    p = np.asarray(probs)
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)
    for i in range(b):
        for j in range(T):
            assert np.all(p[i, :, j, int(base[i]) + j + 1:] == 0.0)
