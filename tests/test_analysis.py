"""The lint passes catch exactly their known-bad fixtures — rule ID *and*
line — report nothing on the known-good twin, honor ``allow`` comments,
and find the shipped tree clean.

Fixtures live in ``tests/analysis_fixtures/`` (parsed, never imported);
every line a pass must flag carries a trailing ``# expect: RULE`` marker,
and the tests assert set equality between markers and findings, so a pass
that goes blind (misses a finding) fails the same as one that goes noisy
(extra findings).
"""
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import run_paths
from repro.analysis.common import RULES

HERE = Path(__file__).parent
FIXTURES = HERE / "analysis_fixtures"
SRC = (HERE.parent / "src" / "repro").resolve()

_EXPECT = re.compile(r"#\s*expect:\s*([A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)")


def expected_markers(path: Path):
    out = set()
    for lineno, text in enumerate(path.read_text().splitlines(), start=1):
        m = _EXPECT.search(text)
        if m:
            for rule in m.group(1).split(","):
                out.add((rule.strip(), lineno))
    return out


def findings(path) -> set:
    return {(f.rule, f.line) for f in run_paths([str(path)])}


# --------------------------------------------------------------------------- #
# known-bad fixtures: exact rule IDs at exact lines, nothing more
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name, rule_prefixes", [
    ("bad_trace.py", {"TRC"}),
    ("bad_donation.py", {"DON"}),
    ("bad_pytree.py", {"PYT"}),
])
def test_known_bad_fixture_exact_rules_and_lines(name, rule_prefixes):
    path = FIXTURES / name
    exp = expected_markers(path)
    assert exp, f"{name} carries no # expect markers"
    # the fixture is dedicated to one pass: its markers only use that
    # pass's rule family (guards against marker typos)
    assert {r[:3] for r, _ in exp} == rule_prefixes
    got = findings(path)
    missing = exp - got
    extra = got - exp
    assert not missing, f"pass went blind, missed: {sorted(missing)}"
    assert not extra, f"pass went noisy, extra: {sorted(extra)}"


def test_all_rule_ids_are_documented_and_exercised():
    exercised = set()
    for name in ("bad_trace.py", "bad_donation.py", "bad_pytree.py"):
        exercised |= {r for r, _ in expected_markers(FIXTURES / name)}
    assert exercised == set(RULES), (
        "every documented rule must have a known-bad fixture line "
        f"(documented {sorted(RULES)} vs exercised {sorted(exercised)})")


def test_known_good_fixture_is_clean():
    assert findings(FIXTURES / "good.py") == set()


# --------------------------------------------------------------------------- #
# suppression comments
# --------------------------------------------------------------------------- #
_SUPPRESSIBLE = """\
import numpy as np
import jax


@jax.jit
def f(x):
    {comment_above}
    y = np.asarray(x)  {trailing}
    return x + y.sum()
"""


def _write(tmp_path, comment_above="", trailing=""):
    p = tmp_path / "snippet.py"
    p.write_text(_SUPPRESSIBLE.format(comment_above=comment_above,
                                      trailing=trailing))
    return p


def test_unsuppressed_violation_is_reported(tmp_path):
    assert findings(_write(tmp_path)) == {("TRC002", 8)}


def test_trailing_allow_suppresses(tmp_path):
    p = _write(tmp_path, trailing="# analysis: allow(TRC002)")
    assert findings(p) == set()


def test_comment_above_allow_suppresses(tmp_path):
    p = _write(tmp_path, comment_above="# analysis: allow(TRC002)")
    assert findings(p) == set()


def test_allow_star_suppresses_any_rule(tmp_path):
    p = _write(tmp_path, trailing="# analysis: allow(*)")
    assert findings(p) == set()


def test_allow_for_other_rule_does_not_suppress(tmp_path):
    p = _write(tmp_path, trailing="# analysis: allow(DON001)")
    assert findings(p) == {("TRC002", 8)}


# --------------------------------------------------------------------------- #
# rules filter + CLI contract
# --------------------------------------------------------------------------- #
def test_rules_prefix_filter():
    only_don = run_paths([str(FIXTURES / "bad_donation.py"),
                          str(FIXTURES / "bad_trace.py")], rules=["DON"])
    assert only_don and all(f.rule.startswith("DON") for f in only_don)


def test_cli_fail_on_warn_exit_codes(tmp_path):
    env_paths = str(SRC.parent)

    def run(*args):
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            capture_output=True, text=True,
            env={"PYTHONPATH": env_paths, "PATH": "/usr/bin:/bin"})

    bad = run("--fail-on-warn", str(FIXTURES / "bad_trace.py"))
    assert bad.returncode == 1
    assert "TRC001" in bad.stdout
    good = run("--fail-on-warn", str(FIXTURES / "good.py"))
    assert good.returncode == 0
    # without --fail-on-warn findings are reported but the exit is clean
    soft = run(str(FIXTURES / "bad_trace.py"))
    assert soft.returncode == 0 and "TRC001" in soft.stdout


# --------------------------------------------------------------------------- #
# self-check: the shipped tree holds the invariants it lints for
# --------------------------------------------------------------------------- #
def test_src_repro_is_clean():
    offenders = run_paths([str(SRC)])
    assert offenders == [], "\n".join(f.render() for f in offenders)
