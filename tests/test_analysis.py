"""The lint passes catch exactly their known-bad fixtures — rule ID *and*
line — report nothing on the known-good twin, honor ``allow`` comments,
and find the shipped tree clean.

Fixtures live in ``tests/analysis_fixtures/`` (parsed, never imported);
every line a pass must flag carries a trailing ``# expect: RULE`` marker,
and the tests assert set equality between markers and findings, so a pass
that goes blind (misses a finding) fails the same as one that goes noisy
(extra findings).
"""
import json
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import run_paths
from repro.analysis.common import RULES

HERE = Path(__file__).parent
FIXTURES = HERE / "analysis_fixtures"
SRC = (HERE.parent / "src" / "repro").resolve()

_EXPECT = re.compile(r"#\s*expect:\s*([A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)")


def expected_markers(path: Path):
    out = set()
    for lineno, text in enumerate(path.read_text().splitlines(), start=1):
        m = _EXPECT.search(text)
        if m:
            for rule in m.group(1).split(","):
                out.add((rule.strip(), lineno))
    return out


def findings(path) -> set:
    return {(f.rule, f.line) for f in run_paths([str(path)])}


# --------------------------------------------------------------------------- #
# known-bad fixtures: exact rule IDs at exact lines, nothing more
# --------------------------------------------------------------------------- #
_BAD_FIXTURES = [
    ("bad_trace.py", {"TRC"}),
    ("bad_donation.py", {"DON"}),
    ("bad_pytree.py", {"PYT"}),
    ("bad_sharding.py", {"SHD"}),
    ("bad_recompile.py", {"CMP"}),
    ("bad_obs.py", {"OBS"}),
]


@pytest.mark.parametrize("name, rule_prefixes", _BAD_FIXTURES)
def test_known_bad_fixture_exact_rules_and_lines(name, rule_prefixes):
    path = FIXTURES / name
    exp = expected_markers(path)
    assert exp, f"{name} carries no # expect markers"
    # the fixture is dedicated to one pass: its markers only use that
    # pass's rule family (guards against marker typos)
    assert {r[:3] for r, _ in exp} == rule_prefixes
    got = findings(path)
    missing = exp - got
    extra = got - exp
    assert not missing, f"pass went blind, missed: {sorted(missing)}"
    assert not extra, f"pass went noisy, extra: {sorted(extra)}"


def test_all_rule_ids_are_documented_and_exercised():
    exercised = set()
    for name, _ in _BAD_FIXTURES:
        exercised |= {r for r, _ in expected_markers(FIXTURES / name)}
    assert exercised == set(RULES), (
        "every documented rule must have a known-bad fixture line "
        f"(documented {sorted(RULES)} vs exercised {sorted(exercised)})")


@pytest.mark.parametrize("name", [
    "good.py", "good_sharding.py", "good_recompile.py", "good_obs.py",
])
def test_known_good_fixture_is_clean(name):
    assert findings(FIXTURES / name) == set()


# --------------------------------------------------------------------------- #
# suppression comments
# --------------------------------------------------------------------------- #
_SUPPRESSIBLE = """\
import numpy as np
import jax


@jax.jit
def f(x):
    {comment_above}
    y = np.asarray(x)  {trailing}
    return x + y.sum()
"""


def _write(tmp_path, comment_above="", trailing=""):
    p = tmp_path / "snippet.py"
    p.write_text(_SUPPRESSIBLE.format(comment_above=comment_above,
                                      trailing=trailing))
    return p


def test_unsuppressed_violation_is_reported(tmp_path):
    assert findings(_write(tmp_path)) == {("TRC002", 8)}


def test_trailing_allow_suppresses(tmp_path):
    p = _write(tmp_path, trailing="# analysis: allow(TRC002)")
    assert findings(p) == set()


def test_comment_above_allow_suppresses(tmp_path):
    p = _write(tmp_path, comment_above="# analysis: allow(TRC002)")
    assert findings(p) == set()


def test_allow_star_suppresses_any_rule(tmp_path):
    p = _write(tmp_path, trailing="# analysis: allow(*)")
    assert findings(p) == set()


def test_allow_for_other_rule_does_not_suppress(tmp_path):
    p = _write(tmp_path, trailing="# analysis: allow(DON001)")
    assert findings(p) == {("TRC002", 8)}


# one representative suppressible finding per new rule family: the same
# snippet must fire bare and fall silent under a trailing allow
_FAMILY_MATRIX = [
    ("SHD002",
     "import threading\n\n_TLS = threading.local()\n\n\n"
     "def install(spec):\n"
     "    _TLS.spec = spec  {trailing}\n", 7),
    ("CMP002",
     "import jax\n\nstep = jax.jit(lambda params: params)\n\n\n"
     "def go(params, opts):\n"
     "    return step(**opts)  {trailing}\n", 7),
    ("OBS002",
     "def submit(tracer, rid):\n"
     "    tracer.begin(('queued', rid))  {trailing}\n", 2),
]


@pytest.mark.parametrize("rule, template, line", _FAMILY_MATRIX)
def test_suppression_matrix_new_families(tmp_path, rule, template, line):
    p = tmp_path / "snippet.py"
    p.write_text(template.format(trailing=""))
    assert findings(p) == {(rule, line)}
    p.write_text(template.format(
        trailing=f"# analysis: allow({rule})"))
    assert findings(p) == set()


# --------------------------------------------------------------------------- #
# rules filter + CLI contract
# --------------------------------------------------------------------------- #
def test_rules_prefix_filter():
    only_don = run_paths([str(FIXTURES / "bad_donation.py"),
                          str(FIXTURES / "bad_trace.py")], rules=["DON"])
    assert only_don and all(f.rule.startswith("DON") for f in only_don)


def test_cli_fail_on_warn_exit_codes(tmp_path):
    env_paths = str(SRC.parent)

    def run(*args):
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            capture_output=True, text=True,
            env={"PYTHONPATH": env_paths, "PATH": "/usr/bin:/bin"})

    bad = run("--fail-on-warn", str(FIXTURES / "bad_trace.py"))
    assert bad.returncode == 1
    assert "TRC001" in bad.stdout
    good = run("--fail-on-warn", str(FIXTURES / "good.py"))
    assert good.returncode == 0
    # without --fail-on-warn findings are reported but the exit is clean
    soft = run(str(FIXTURES / "bad_trace.py"))
    assert soft.returncode == 0 and "TRC001" in soft.stdout


def _cli(*args, cwd=None):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd,
        env={"PYTHONPATH": str(SRC.parent), "PATH": "/usr/bin:/bin"})


def test_cli_summary_reports_family_counts():
    n = len(expected_markers(FIXTURES / "bad_trace.py"))
    out = _cli(str(FIXTURES / "bad_trace.py")).stdout
    assert f"repro.analysis: {n} findings (TRC {n})" in out
    # --rules restricts the summary too
    k = sum(1 for r, _ in expected_markers(FIXTURES / "bad_trace.py")
            if r == "TRC002")
    out = _cli("--rules", "TRC002", str(FIXTURES / "bad_trace.py")).stdout
    assert f"repro.analysis: {k} findings (TRC {k})" in out


def test_cli_list_rules_respects_rules_filter():
    full = _cli("--list-rules").stdout
    assert all(rule in full for rule in RULES)
    filtered = _cli("--list-rules", "--rules", "SHD").stdout
    assert "SHD001" in filtered and "SHD003" in filtered
    assert "TRC001" not in filtered and "CMP001" not in filtered
    assert "3 rules (SHD 3)" in filtered


def test_cli_json_format():
    r = _cli("--format", "json", str(FIXTURES / "bad_obs.py"))
    doc = json.loads(r.stdout)          # stdout is pure JSON
    assert doc["tool"] == "repro.analysis"
    assert doc["counts"] == {"OBS": 4}
    assert {f["rule"] for f in doc["findings"]} == {"OBS001", "OBS002"}
    assert all(f["line"] > 0 and f["path"] for f in doc["findings"])
    # the summary moved to stderr so the document stays parseable
    assert "repro.analysis:" in r.stderr


def test_cli_sarif_validates_against_schema():
    jsonschema = pytest.importorskip("jsonschema")
    r = _cli("--format", "sarif", str(FIXTURES / "bad_sharding.py"))
    doc = json.loads(r.stdout)
    schema = json.loads(
        (FIXTURES / "sarif-2.1.0-subset.schema.json").read_text())
    jsonschema.validate(doc, schema)
    assert doc["version"] == "2.1.0"
    run = doc["runs"][0]
    assert run["tool"]["driver"]["name"] == "repro.analysis"
    assert {r_["id"] for r_ in run["tool"]["driver"]["rules"]} \
        == set(RULES)
    got = {(res["ruleId"],
            res["locations"][0]["physicalLocation"]["region"]["startLine"])
           for res in run["results"]}
    assert got == expected_markers(FIXTURES / "bad_sharding.py")


def test_cli_sarif_rule_catalogue_respects_rules_filter():
    r = _cli("--format", "sarif", "--rules", "OBS",
             str(FIXTURES / "bad_obs.py"))
    run = json.loads(r.stdout)["runs"][0]
    assert {r_["id"] for r_ in run["tool"]["driver"]["rules"]} \
        == {"OBS001", "OBS002"}
    assert {res["ruleId"][:3] for res in run["results"]} == {"OBS"}


def test_baseline_roundtrip(tmp_path):
    bad = FIXTURES / "bad_recompile.py"
    base = tmp_path / "analysis-baseline.json"
    wrote = _cli("--baseline", str(base), "--write-baseline", str(bad))
    assert wrote.returncode == 0 and base.exists()
    data = json.loads(base.read_text())
    assert data["tool"] == "repro.analysis"
    assert len(data["fingerprints"]) == len(expected_markers(bad))
    # with the baseline applied the same tree gates clean
    gated = _cli("--fail-on-warn", "--baseline", str(base), str(bad))
    assert gated.returncode == 0
    assert "repro.analysis: 0 findings" in gated.stdout
    # a NEW finding (same rule, new line text) is not masked
    snippet = tmp_path / "fresh.py"
    snippet.write_text(
        "import jax\nimport jax.numpy as jnp\n\n"
        "step = jax.jit(lambda params, t: t)\n\n\n"
        "def go(params, chunks):\n"
        "    for c in chunks:\n"
        "        out = step(params, jnp.zeros((1, c)))\n"
        "    return out\n")
    fresh = _cli("--fail-on-warn", "--baseline", str(base), str(snippet))
    assert fresh.returncode == 1 and "CMP001" in fresh.stdout


def test_baseline_fingerprint_survives_line_drift(tmp_path):
    src = (FIXTURES / "bad_obs.py").read_text()
    moved = tmp_path / "moved.py"
    moved.write_text(src)
    base = tmp_path / "base.json"
    _cli("--baseline", str(base), "--write-baseline", str(moved))
    # prepend a comment block: every finding shifts lines but keeps its
    # (rule, line-text) fingerprint
    moved.write_text("# drift\n# drift\n" + src)
    gated = _cli("--fail-on-warn", "--baseline", str(base), str(moved))
    assert gated.returncode == 0, gated.stdout


# --------------------------------------------------------------------------- #
# self-check: the shipped tree holds the invariants it lints for
# --------------------------------------------------------------------------- #
def test_src_repro_is_clean():
    offenders = run_paths([str(SRC)])
    assert offenders == [], "\n".join(f.render() for f in offenders)
