"""Shared-prefix prompt cache + bucketed prefill.

Unit level: longest-match lookup, hash-collision safety, LRU eviction
under a byte budget. Engine level (the acceptance tests): two requests
sharing a long prompt prefix produce tokens/logits identical to cold
prefill while the second request's prefill processes only the suffix
(asserted via dispatch/token counts and ``prefix_hit_rate``); bucketed
prefill is exact and collapses distinct prompt lengths onto shared
power-of-two executables.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.prefix import PrefixCache, tree_bytes


# --------------------------------------------------------------------------- #
# PrefixCache unit tests (no model)
# --------------------------------------------------------------------------- #
def _payload(n=8):
    """A dummy 'state' pytree of a known byte size."""
    return {"x": jnp.zeros((n,), jnp.float32)}


def _logits():
    return jnp.zeros((1, 4), jnp.float32)


def test_lookup_returns_longest_matching_prefix():
    pc = PrefixCache()
    toks = np.arange(16, dtype=np.int32)
    pc.insert(toks[:4], _payload(), _logits())
    pc.insert(toks[:12], _payload(), _logits())
    pc.insert(toks[:8], _payload(), _logits())
    hit = pc.lookup(toks)
    assert hit is not None and hit.length == 12
    # a shorter prompt can only match shorter prefixes
    hit = pc.lookup(toks[:9])
    assert hit is not None and hit.length == 8


def test_lookup_miss_and_same_length_different_tokens():
    pc = PrefixCache()
    pc.insert(np.arange(8, dtype=np.int32), _payload(), _logits())
    assert pc.lookup(np.arange(100, 108, dtype=np.int32)) is None
    assert pc.lookup(np.arange(4, dtype=np.int32)) is None
    assert pc.hits == 0 and pc.lookups == 2 and pc.hit_rate == 0.0


def test_exact_match_is_a_hit():
    pc = PrefixCache()
    toks = np.arange(8, dtype=np.int32)
    pc.insert(toks, _payload(), _logits())
    hit = pc.lookup(toks)
    assert hit is not None and hit.length == 8
    assert pc.hit_rate == 1.0


def test_lru_eviction_under_byte_budget():
    entry_bytes = tree_bytes(_payload()) + tree_bytes(_logits())
    pc = PrefixCache(max_bytes=2 * entry_bytes)
    a, b, c = (np.arange(4) + 10 * i for i in range(3))
    pc.insert(a, _payload(), _logits())
    pc.insert(b, _payload(), _logits())
    assert pc.lookup(a) is not None          # refresh a => b becomes LRU
    pc.insert(c, _payload(), _logits())      # evicts b
    assert pc.lookup(b) is None
    assert pc.lookup(a) is not None and pc.lookup(c) is not None
    assert pc.evictions == 1 and len(pc) == 2
    assert pc.nbytes <= pc.max_bytes


def test_insert_replaces_same_tokens_without_growth():
    pc = PrefixCache()
    toks = np.arange(6, dtype=np.int32)
    pc.insert(toks, _payload(), _logits())
    n0 = pc.nbytes
    pc.insert(toks, _payload(), _logits())
    assert len(pc) == 1 and pc.nbytes == n0


def test_oversized_entry_refused():
    pc = PrefixCache(max_bytes=8)
    assert not pc.insert(np.arange(4), _payload(1024), _logits())
    assert len(pc) == 0 and pc.nbytes == 0


# --------------------------------------------------------------------------- #
# Engine-level prefix reuse
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def small_model():
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16, dtype="float32",
        lacache=LaCacheConfig(budget=64, n_sink=2, n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_prefix_reuse_identical_to_cold_and_prefills_only_suffix(small_model):
    """Acceptance: request B extends request A's prompt by 8 tokens. Warm
    engine must (1) generate exactly the cold engine's tokens, (2) prefill
    only A's prompt + B's suffix, (3) report the hit in prefix_hit_rate,
    and (4) hold post-prefill logits identical to a cold prefill of B."""
    cfg, params = small_model
    rng = np.random.default_rng(0)
    pre = rng.integers(0, cfg.vocab_size, (24,))
    full = np.concatenate([pre, rng.integers(0, cfg.vocab_size, (8,))])

    cold = Engine(cfg, params, budget=64, max_batch=2)
    ca, cb = cold.submit(pre, 6), cold.submit(full, 6)
    cold.run()
    assert cold.prefill_tokens == 24 + 32
    assert cold.prefix_hit_rate == 0.0        # nobody opted in, no lookups

    warm = Engine(cfg, params, budget=64, max_batch=2)
    wa = warm.submit(pre, 6, cache_prefix=True)
    wb = warm.submit(full, 6, cache_prefix=True)
    warm.run()
    np.testing.assert_array_equal(wa.tokens, ca.tokens)
    np.testing.assert_array_equal(wb.tokens, cb.tokens)
    assert warm.prefill_tokens == 24 + 8      # B prefilled only its suffix
    assert warm.prefix_hit_rate == 0.5        # 2 lookups, 1 hit
    assert warm.prefix_tokens_reused == 24

    # logits-level: the snapshot stored for B's full prompt must match a
    # cold dense prefill of the same prompt
    entry = warm.prefix_cache.lookup(full)
    assert entry is not None and entry.length == 32
    cold_logits, _ = M.prefill(params, cfg, jnp.asarray(full)[None],
                               n_slots=64)
    np.testing.assert_allclose(np.asarray(entry.logits),
                               np.asarray(cold_logits), atol=1e-4, rtol=1e-4)


def test_exact_prefix_hit_costs_zero_prefill(small_model):
    cfg, params = small_model
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size, (20,))
    eng = Engine(cfg, params, budget=64, max_batch=1)
    a = eng.submit(prompt, 4, cache_prefix=True)
    eng.run()
    d0, t0 = eng.prefill_dispatches, eng.prefill_tokens
    b = eng.submit(prompt, 4, cache_prefix=True)
    eng.run()
    assert eng.prefill_dispatches == d0 and eng.prefill_tokens == t0
    np.testing.assert_array_equal(b.tokens, a.tokens)
    assert eng.prefix_hit_rate == 0.5         # miss then exact hit


def test_prefix_reuse_across_sibling_requests(small_model):
    """One shared system prompt, N different tails — no prompt is a full
    prefix of another, but block-boundary snapshots make siblings hit the
    block-aligned part of the shared prefix (hit rate (N-1)/N)."""
    cfg, params = small_model
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab_size, (30,))
    eng = Engine(cfg, params, budget=64, max_batch=2, prefix_block=16)
    n = 5
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size, (4,))
        eng.submit(np.concatenate([shared, tail]), 3, cache_prefix=True)
    eng.run()
    assert eng.prefix_hit_rate == (n - 1) / n
    # first request prefills all 34 tokens; siblings reuse the 16-token
    # block snapshot (30 rounded down to the block) and prefill the rest
    assert eng.prefill_tokens == 34 + (n - 1) * 18
    assert eng.prefix_tokens_reused == (n - 1) * 16


def test_prefix_reuse_with_compaction_still_serves(small_model):
    """Prompt exceeds the budget: snapshots are taken of *compacted* states
    (position-exact because pos is stored per slot); reuse must keep
    serving correct-length outputs."""
    cfg, params = small_model
    rng = np.random.default_rng(3)
    pre = rng.integers(0, cfg.vocab_size, (50,))
    full = np.concatenate([pre, rng.integers(0, cfg.vocab_size, (10,))])
    eng = Engine(cfg, params, budget=32, max_batch=2)
    a = eng.submit(pre, 4, cache_prefix=True)
    b = eng.submit(full, 4, cache_prefix=True)
    eng.run()
    assert len(a.output_tokens) == 4 and len(b.output_tokens) == 4
    assert eng.prefix_hit_rate == 0.5
    assert eng.prefill_tokens == 50 + 10


def test_prefix_opt_in_with_full_policy_long_prompt_falls_back(small_model):
    """Regression: a non-evicting policy cannot stream a prompt longer than
    the slot buffer through decode_chunk (append would clobber live slots).
    Such requests must fall back to dense prefill and produce exactly the
    non-cached tokens; prompts that fit still use the prefix cache."""
    import dataclasses
    cfg, params = small_model
    cfg = dataclasses.replace(cfg, lacache=dataclasses.replace(
        cfg.lacache, policy="full"))
    rng = np.random.default_rng(8)
    long_prompt = rng.integers(0, cfg.vocab_size, (50,))   # > budget 32
    short_prompt = rng.integers(0, cfg.vocab_size, (20,))  # fits

    ref = Engine(cfg, params, budget=32, max_batch=1)
    r1, r2 = ref.submit(long_prompt, 4), ref.submit(short_prompt, 4)
    ref.run()

    eng = Engine(cfg, params, budget=32, max_batch=1)
    w1 = eng.submit(long_prompt, 4, cache_prefix=True)
    w2 = eng.submit(short_prompt, 4, cache_prefix=True)
    eng.run()
    np.testing.assert_array_equal(w1.tokens, r1.tokens)
    np.testing.assert_array_equal(w2.tokens, r2.tokens)
    # the long prompt bypassed the cache; the short one was snapshotted
    # within the buffer limit
    entry = eng.prefix_cache.lookup(short_prompt)
    assert entry is not None and int(entry.state.pos) == 20
    assert eng.prefix_cache.lookup(long_prompt) is None


def test_no_opt_in_means_no_lookups(small_model):
    cfg, params = small_model
    prompt = np.random.default_rng(4).integers(0, cfg.vocab_size, (12,))
    eng = Engine(cfg, params, budget=64, max_batch=1)
    eng.submit(prompt, 2)
    eng.submit(prompt, 2)
    eng.run()
    assert eng.prefix_cache.lookups == 0 and len(eng.prefix_cache) == 0
    assert eng.prefill_tokens == 24


# --------------------------------------------------------------------------- #
# Bucketed prefill
# --------------------------------------------------------------------------- #
def test_bucketed_prefill_matches_exact_dense(small_model):
    """Padded-to-bucket prefill with traced true_len == exact prefill: same
    last-token logits, and identical logits over 5 further decode steps."""
    cfg, params = small_model
    toks = np.random.default_rng(5).integers(0, cfg.vocab_size, (23,))
    l_exact, s_exact = M.prefill(params, cfg, jnp.asarray(toks)[None],
                                 n_slots=64)
    padded = np.zeros((32,), np.int32)
    padded[:23] = toks
    l_buck, s_buck = M.prefill(params, cfg, jnp.asarray(padded)[None],
                               n_slots=64, true_len=jnp.asarray(23, jnp.int32))
    np.testing.assert_allclose(np.asarray(l_exact), np.asarray(l_buck),
                               atol=1e-4, rtol=1e-4)
    assert int(s_buck.pos) == 23
    nxt = np.random.default_rng(6).integers(0, cfg.vocab_size, (5,))
    for i in range(5):
        t = jnp.asarray(nxt[i:i + 1])[None]
        a, s_exact = M.decode_step(params, cfg, s_exact, t)
        b, s_buck = M.decode_step(params, cfg, s_buck, t)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_bucketed_prefill_matches_exact_localglobal():
    """The ring-cache (sliding window) rebuild path under traced true_len."""
    cfg = ModelConfig(
        name="g", arch_type="dense", n_layers=6, local_global_pattern=2,
        sliding_window=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=97, head_dim=16, dtype="float32",
        lacache=LaCacheConfig(budget=64, policy="lacache", n_sink=2,
                              n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    toks = np.random.default_rng(7).integers(0, 97, (21,))
    l_exact, s_exact = M.prefill(params, cfg, jnp.asarray(toks)[None],
                                 n_slots=64)
    padded = np.zeros((32,), np.int32)
    padded[:21] = toks
    l_buck, s_buck = M.prefill(params, cfg, jnp.asarray(padded)[None],
                               n_slots=64, true_len=jnp.asarray(21, jnp.int32))
    np.testing.assert_allclose(np.asarray(l_exact), np.asarray(l_buck),
                               atol=1e-4, rtol=1e-4)
    nxt = np.random.default_rng(8).integers(0, 97, (4,))
    for i in range(4):
        t = jnp.asarray(nxt[i:i + 1])[None]
        a, s_exact = M.decode_step(params, cfg, s_exact, t)
        b, s_buck = M.decode_step(params, cfg, s_buck, t)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_bucketed_prefill_accepts_mamba_pad_masked():
    """Bucketing is no longer attention-only: SSM layers run the pad-masked
    scan, so hybrid configs accept a traced true_len (exactness is pinned
    down by tests/test_ring_paged.py) and the engine keeps bucketing on."""
    cfg = ModelConfig(
        name="m", arch_type="hybrid", n_layers=8, attn_every=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=97, head_dim=16,
        dtype="float32", lacache=LaCacheConfig(budget=64, policy="full"))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    logits, state = M.prefill(params, cfg, jnp.zeros((1, 16), jnp.int32),
                              n_slots=64, true_len=jnp.asarray(9, jnp.int32))
    assert int(state.pos) == 9
    eng = Engine(cfg, params, budget=64, bucket_prefill=True)
    assert eng.bucket_prefill
    # frames (encoder) inputs are the remaining exclusion
    with pytest.raises(ValueError, match="patches/frames"):
        M.prefill(params, cfg, jnp.zeros((1, 16), jnp.int32), n_slots=64,
                  true_len=jnp.asarray(9, jnp.int32),
                  frames=jnp.zeros((1, 4, 128)))


def test_engine_bucketing_shares_executables_and_matches(small_model):
    """7 distinct prompt lengths in (16, 32] -> ONE prefill shape; tokens
    must equal the exact-length engine's."""
    cfg, params = small_model
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, (n,))
               for n in (17, 19, 21, 23, 25, 29, 32)]
    exact = Engine(cfg, params, budget=64, max_batch=2)
    ref = [exact.submit(p, 3) for p in prompts]
    exact.run()
    bucketed = Engine(cfg, params, budget=64, max_batch=2,
                      bucket_prefill=True)
    out = [bucketed.submit(p, 3) for p in prompts]
    bucketed.run()
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(o.tokens, r.tokens)
    assert bucketed.prefill_shapes == {("prefill", 32)}
    assert len(exact.prefill_shapes) == len(prompts)
    # true token counts are tracked, not padded counts
    assert bucketed.prefill_tokens == sum(p.shape[0] for p in prompts)
