"""Paged KV memory subsystem: allocator, CoW, kernel, store, engine, preempt.

Four layers of coverage, bottom-up:

* **allocator** — refcount / free-list invariants under random churn
  (hypothesis property test; skips cleanly without hypothesis),
* **dense-API shims** — gather/from_dense roundtrip, CoW isolation between
  two tables sharing a prefix, truncate block release, and ``compact``
  parity against the dense ladder compaction for every registered policy,
* **kernel** — the Pallas paged-decode kernel (interpret mode), the
  pure-JAX paged reference and the dense decode kernel agree to <= 1e-5,
* **serving** — the acceptance criteria: two requests with a shared prefix
  physically share blocks (refcounts > 1, ``bytes_shared`` > 0) while
  matching the dense backend token-for-token, unique-bytes LRU accounting,
  and a preempted RUNNING request resuming with identical continuation
  tokens.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LaCacheConfig, ModelConfig
from repro.core import cache as cachelib
from repro.core import ladder, paged
from repro.core.policy import policy_names
from repro.kernels import decode_attention as dense_kernel
from repro.kernels import ops as kops
from repro.kernels import paged_attention as paged_kernel
from repro.kernels import ref as kref
from repro.models import model as M
from repro.serving.engine import Engine

KVH, HD = 2, 8


def rand_cache(rng, n_slots, length, with_scores=False):
    pos = np.full((n_slots,), -1, np.int32)
    pos[:length] = np.arange(length)
    return cachelib.KVCache(
        k=jnp.asarray(rng.normal(size=(1, n_slots, KVH, HD)), jnp.float32),
        v=jnp.asarray(rng.normal(size=(1, n_slots, KVH, HD)), jnp.float32),
        pos=jnp.asarray(pos),
        length=jnp.asarray(length, jnp.int32),
        scores=jnp.asarray(rng.random(n_slots), jnp.float32)
        if with_scores else None)


# --------------------------------------------------------------------------- #
# Dense-API shims: roundtrip, CoW, truncate, compact parity
# --------------------------------------------------------------------------- #
def test_from_dense_gather_roundtrip_exact():
    rng = np.random.default_rng(0)
    pool = paged.init_pool(16, 4, KVH, HD, jnp.float32)
    c = rand_cache(rng, 10, 7, with_scores=True)
    pool, t = paged.from_dense(pool, c)
    paged.check_invariants(pool)
    # only blocks covering the occupied prefix are mapped
    assert np.asarray(t.blocks >= 0).sum() == 2
    g = paged.gather(pool, t)
    np.testing.assert_array_equal(np.asarray(g.k[0, :7]),
                                  np.asarray(c.k[0, :7]))
    np.testing.assert_array_equal(np.asarray(g.v[0, :7]),
                                  np.asarray(c.v[0, :7]))
    np.testing.assert_array_equal(np.asarray(g.pos), np.asarray(c.pos))
    np.testing.assert_array_equal(np.asarray(g.scores), np.asarray(c.scores))
    assert int(g.length) == 7


def test_copy_on_write_isolates_forked_tables():
    """Acceptance: a fork shares every block; appending through one table
    CoWs the straddled shared block and never perturbs the other."""
    rng = np.random.default_rng(1)
    pool = paged.init_pool(16, 4, KVH, HD, jnp.float32)
    c = rand_cache(rng, 12, 7)
    pool, ta = paged.from_dense(pool, c)
    pool, tb = paged.fork(pool, ta)
    assert paged.bytes_shared(pool) == 2 * pool.block_bytes
    kn = jnp.asarray(rng.normal(size=(1, 3, KVH, HD)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(1, 3, KVH, HD)), jnp.float32)
    pool, tb = paged.append(pool, tb, kn, vn, jnp.arange(7, 10))
    paged.check_invariants(pool)
    ga, gb = paged.gather(pool, ta), paged.gather(pool, tb)
    np.testing.assert_array_equal(np.asarray(ga.k[0, :7]),
                                  np.asarray(c.k[0, :7]))   # A untouched
    np.testing.assert_array_equal(np.asarray(gb.k[0, 7:10]), np.asarray(kn[0]))
    np.testing.assert_array_equal(np.asarray(gb.k[0, :7]),
                                  np.asarray(c.k[0, :7]))   # B kept prefix
    # the fully-shared first block stays shared; the straddled one was CoW'd
    assert int(np.asarray(ta.blocks)[0]) == int(np.asarray(tb.blocks)[0])
    assert int(np.asarray(ta.blocks)[1]) != int(np.asarray(tb.blocks)[1])
    pool = paged.release(pool, ta)
    pool = paged.release(pool, tb)
    paged.check_invariants(pool)
    assert paged.blocks_in_use(pool) == 0


def test_truncate_releases_dead_blocks():
    rng = np.random.default_rng(2)
    pool = paged.init_pool(16, 4, KVH, HD, jnp.float32)
    pool, t = paged.from_dense(pool, rand_cache(rng, 12, 11))
    assert paged.blocks_in_use(pool) == 3
    pool, t = paged.truncate(pool, t, 5)
    paged.check_invariants(pool)
    assert paged.blocks_in_use(pool) == 2   # block covering slots 8..11 freed
    assert int(t.length) == 5
    assert (np.asarray(t.pos)[5:] == -1).all()


@pytest.mark.parametrize("policy", policy_names())
def test_compact_parity_with_dense(policy):
    """paged.compact == dense cachelib.compact through the block table, for
    every registered eviction policy (scores ride in the table)."""
    from repro.core.policy import get_policy
    if not get_policy(policy).evicts:
        pytest.skip("non-evicting policy never compacts")
    rng = np.random.default_rng(3)
    lspec = ladder.make_spec(
        LaCacheConfig(budget=16, n_sink=2, n_recent=4, chunk=2).resolve(4), 4)
    needs_scores = get_policy(policy).needs_scores
    c = rand_cache(rng, 16, 16, with_scores=needs_scores)
    pool = paged.init_pool(32, 4, KVH, HD, jnp.float32)
    pool, t = paged.from_dense(pool, c)
    ref = cachelib.compact(c, lspec, 1, policy)
    pool, t2 = paged.compact(pool, t, lspec, 1, policy)
    paged.check_invariants(pool)
    g = paged.gather(pool, t2)
    L = int(ref.length)
    assert int(g.length) == L
    np.testing.assert_array_equal(np.asarray(g.k[0, :L]),
                                  np.asarray(ref.k[0, :L]))
    np.testing.assert_array_equal(np.asarray(g.pos), np.asarray(ref.pos))
    if needs_scores:
        np.testing.assert_array_equal(np.asarray(g.scores),
                                      np.asarray(ref.scores))


def test_pool_exhaustion_raises_eagerly():
    rng = np.random.default_rng(4)
    pool = paged.init_pool(2, 4, KVH, HD, jnp.float32)
    with pytest.raises(paged.PoolExhausted):
        paged.from_dense(pool, rand_cache(rng, 16, 12))


# --------------------------------------------------------------------------- #
# Allocator invariants under churn (hypothesis)
# --------------------------------------------------------------------------- #
def test_allocator_invariants_random_churn():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    op = st.tuples(st.sampled_from(["new", "fork", "append", "release",
                                    "truncate", "compact"]),
                   st.integers(0, 15), st.integers(1, 12))

    lspec = ladder.make_spec(
        LaCacheConfig(budget=12, n_sink=1, n_recent=3, chunk=2).resolve(3), 3)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(op, min_size=1, max_size=20))
    def run(ops):
        rng = np.random.default_rng(5)
        pool = paged.init_pool(24, 4, KVH, HD, jnp.float32)
        tables = []
        for name, sel, arg in ops:
            try:
                if name == "new":
                    pool, t = paged.from_dense(pool, rand_cache(rng, 12, arg))
                    tables.append(t)
                elif tables:
                    i = sel % len(tables)
                    if name == "fork":
                        pool, t = paged.fork(pool, tables[i])
                        tables.append(t)
                    elif name == "append":
                        t = tables[i]
                        room = 12 - int(t.length)
                        n = min(arg, room)
                        if n > 0:
                            kn = jnp.asarray(
                                rng.normal(size=(1, n, KVH, HD)), jnp.float32)
                            pool, tables[i] = paged.append(
                                pool, t, kn, kn,
                                jnp.arange(int(t.length),
                                           int(t.length) + n))
                    elif name == "release":
                        pool = paged.release(pool, tables.pop(i))
                    elif name == "truncate":
                        pool, tables[i] = paged.truncate(
                            pool, tables[i], arg)
                    elif name == "compact":
                        pool, tables[i] = paged.compact(
                            pool, tables[i], lspec, 0, "lacache")
            except paged.PoolExhausted:
                pass   # legal outcome; pool must still be consistent
            paged.check_invariants(pool)
        for t in tables:
            pool = paged.release(pool, t)
        paged.check_invariants(pool)
        assert paged.blocks_in_use(pool) == 0

    run()


# --------------------------------------------------------------------------- #
# In-model live tables: append/compact/truncate/fork/splice churn vs the
# dense oracle, with engine-style host refcount bookkeeping (hypothesis)
# --------------------------------------------------------------------------- #
IM_SLOTS, IM_BS = 12, 4
IM_SPEC = ladder.make_spec(
    LaCacheConfig(budget=IM_SLOTS, n_sink=2, n_recent=3, chunk=2).resolve(3), 3)


def _run_inmodel_ops(ops):
    """Drive one lane's live in-model table through a random op interleaving
    while mirroring every mutation on a dense KVCache oracle and the
    engine's host-side refcount protocol (owned reserve set, shared splice
    holds, snapshot forks). Invariants checked after every op:

    * pool refcounts conserve blocks (no double-free, no leak),
    * the gathered table view equals the dense oracle bit-for-bit,
    * snapshots forked earlier are never corrupted by later lane writes
      (copy-on-write isolation).
    """
    rng = np.random.default_rng(17)
    mb = paged.blocks_for(IM_SLOTS, IM_BS)
    store = paged.PagedStateStore(64, IM_BS, KVH, HD, jnp.float32)
    owned = store.alloc_blocks(mb)
    kv = paged.PoolKV(k=store.pool.k, v=store.pool.v)
    st = paged.PagedKVCache(
        blocks=jnp.full((1, mb), -1, jnp.int32),
        owned=jnp.asarray(owned, jnp.int32)[None],
        pos=jnp.full((1, IM_SLOTS), -1, jnp.int32),
        length=jnp.zeros((1,), jnp.int32), scores=None)
    oracle = cachelib.init_cache(1, IM_SLOTS, KVH, HD, jnp.float32)
    lane_shared = np.zeros((0,), np.int64)
    snaps = []          # (blocks np, pos np, length, oracle copy)
    next_pos = 0

    def check_oracle():
        paged.check_invariants(store.pool)
        gk, gv = paged.paged_gather_view(kv, st, IM_SLOTS)
        L = int(oracle.length)
        assert int(st.length[0]) == L
        np.testing.assert_array_equal(np.asarray(gk[0, :L]),
                                      np.asarray(oracle.k[0, :L]))
        np.testing.assert_array_equal(np.asarray(gv[0, :L]),
                                      np.asarray(oracle.v[0, :L]))
        np.testing.assert_array_equal(np.asarray(st.pos[0]),
                                      np.asarray(oracle.pos))

    for name, arg in ops:
        if name == "append":
            room = IM_SLOTS - int(st.length[0])
            n = min(max(1, arg), room)
            if n <= 0:
                continue
            kn = jnp.asarray(rng.normal(size=(1, n, KVH, HD)), jnp.float32)
            vn = jnp.asarray(rng.normal(size=(1, n, KVH, HD)), jnp.float32)
            pn = (next_pos + jnp.arange(n, dtype=jnp.int32))
            next_pos += n
            kv, st = paged.paged_append(kv, st, kn, vn, pn[None])
            oracle = cachelib.append(oracle, kn, vn, pn)
        elif name == "compact":
            n_inc = max(1, arg % 4)
            kv, st = paged.paged_maybe_compact(
                kv, st, IM_SPEC, 1, "lacache", n_inc, rope_theta=1e4)
            oracle = cachelib.maybe_compact(
                oracle, IM_SPEC, 1, "lacache", n_inc, rope_theta=1e4)
        elif name == "truncate":
            t = arg % (IM_SLOTS + 1)
            st = paged.paged_truncate(st, jnp.asarray([t], jnp.int32), IM_BS)
            oracle = cachelib.truncate(oracle, t)
        elif name == "fork":
            # engine-style refcount fork: snapshot holds every mapped
            # block; the lane's owned mapped blocks are swapped for fresh
            # reserves so later writes CoW away from the forked content
            blocks = np.asarray(st.blocks[0])
            ownd = np.asarray(st.owned[0])
            mapped = blocks >= 0
            swap = mapped & (blocks == ownd)
            try:
                fresh = store.alloc_blocks(int(swap.sum()))
            except paged.PoolExhausted:
                continue
            new_owned = ownd.copy()
            new_owned[swap] = fresh
            store.retain_blocks(blocks[mapped])
            lane_shared = np.concatenate([lane_shared, blocks[swap]])
            st = st._replace(owned=jnp.asarray(new_owned, jnp.int32)[None])
            gk, gv = paged.paged_gather_view(kv, st, IM_SLOTS)
            snaps.append((blocks.copy(), np.asarray(st.pos[0]).copy(),
                          int(st.length[0]), np.asarray(gk[0]).copy(),
                          np.asarray(gv[0]).copy()))
        elif name == "splice" and snaps:
            # retire the lane's occupant and splice a snapshot in shared
            sblocks, spos, slen, sk, sv = snaps[arg % len(snaps)]
            store.release_blocks(lane_shared)
            ids = sblocks[sblocks >= 0]
            store.retain_blocks(ids)
            lane_shared = ids.astype(np.int64).copy()
            st = st._replace(blocks=jnp.asarray(sblocks, jnp.int32)[None],
                             pos=jnp.asarray(spos, jnp.int32)[None],
                             length=jnp.asarray([slen], jnp.int32))
            oracle = cachelib.KVCache(
                k=jnp.asarray(sk, jnp.float32)[None],
                v=jnp.asarray(sv, jnp.float32)[None],
                pos=jnp.asarray(spos, jnp.int32),
                length=jnp.asarray(slen, jnp.int32), scores=None)
            next_pos = max(next_pos, slen)
        check_oracle()

    # CoW isolation: every snapshot's view is intact despite later writes
    for sblocks, spos, slen, sk, sv in snaps:
        view = paged.PagedKVCache(
            blocks=jnp.asarray(sblocks, jnp.int32)[None],
            owned=st.owned, pos=jnp.asarray(spos, jnp.int32)[None],
            length=jnp.asarray([slen], jnp.int32), scores=None)
        gk, gv = paged.paged_gather_view(kv, view, IM_SLOTS)
        np.testing.assert_array_equal(np.asarray(gk[0, :slen]), sk[:slen])
        np.testing.assert_array_equal(np.asarray(gv[0, :slen]), sv[:slen])

    # conservation: release every hold -> only the free list owns blocks
    store.release_blocks(lane_shared)
    store.release_blocks(np.asarray(st.owned[0]))
    for sblocks, *_ in snaps:
        store.release_blocks(sblocks[sblocks >= 0])
    paged.check_invariants(store.pool)
    assert paged.blocks_in_use(store.pool) == 0


def test_inmodel_overflow_append_clamps_like_dense_without_corruption():
    """An append at ``length == n_slots`` (a never-evicting policy at
    capacity, or a retired lane still ticking) must mirror the dense
    twin's dynamic_update_slice clamp — the newest K/V overwrites the last
    slot — while the copy-on-write redirect keeps the clamped write inside
    the lane's reserved blocks, never in a block a snapshot shares."""
    rng = np.random.default_rng(23)
    mb = paged.blocks_for(IM_SLOTS, IM_BS)
    store = paged.PagedStateStore(32, IM_BS, KVH, HD, jnp.float32)
    owned = store.alloc_blocks(mb)
    shared = store.alloc_blocks(mb)       # a "snapshot's" blocks
    kv = paged.PoolKV(k=store.pool.k, v=store.pool.v)
    marker = np.asarray(rng.normal(size=(IM_SLOTS, KVH, HD)), np.float32)
    rows = shared[np.arange(IM_SLOTS) // IM_BS] * IM_BS \
        + np.arange(IM_SLOTS) % IM_BS
    kv = paged.PoolKV(k=kv.k.reshape(-1, KVH, HD)
                      .at[rows].set(jnp.asarray(marker))
                      .reshape(kv.k.shape), v=kv.v)
    # lane spliced to the full shared table (length == n_slots exactly)
    st = paged.PagedKVCache(
        blocks=jnp.asarray(shared, jnp.int32)[None],
        owned=jnp.asarray(owned, jnp.int32)[None],
        pos=jnp.arange(IM_SLOTS, dtype=jnp.int32)[None],
        length=jnp.asarray([IM_SLOTS], jnp.int32), scores=None)
    kn = jnp.ones((1, 1, KVH, HD), jnp.float32) * 777.0
    kv2, st2 = paged.paged_append(kv, st, kn, kn,
                                  jnp.asarray([[IM_SLOTS]], jnp.int32))
    # the snapshot's view of its own blocks is bit-identical (CoW'd away)
    got_snap = paged.paged_gather_view(kv2, st, IM_SLOTS)[0][0]
    np.testing.assert_array_equal(np.asarray(got_snap), marker)
    # the lane's view matches the dense oracle's clamped append exactly
    dense = cachelib.KVCache(
        k=jnp.asarray(marker)[None], v=jnp.zeros((1, IM_SLOTS, KVH, HD)),
        pos=jnp.arange(IM_SLOTS, dtype=jnp.int32),
        length=jnp.asarray(IM_SLOTS, jnp.int32))
    dref = cachelib.append(dense, kn, kn, jnp.asarray([IM_SLOTS], jnp.int32))
    got_lane = paged.paged_gather_view(kv2, st2, IM_SLOTS)[0][0]
    np.testing.assert_array_equal(np.asarray(got_lane),
                                  np.asarray(dref.k[0]))
    np.testing.assert_array_equal(np.asarray(st2.pos[0]),
                                  np.asarray(dref.pos))
    assert int(st2.length[0]) == int(dref.length)


def test_inmodel_table_churn_deterministic():
    """A fixed, branch-covering interleaving (runs without hypothesis):
    append -> fork -> CoW append -> overflow compaction -> truncate ->
    splice back -> append over the spliced (shared) table."""
    _run_inmodel_ops([
        ("append", 7), ("fork", 0), ("append", 3), ("compact", 1),
        ("append", 6), ("compact", 2), ("truncate", 5), ("fork", 1),
        ("splice", 0), ("append", 4), ("compact", 1), ("splice", 1),
        ("append", 2),
    ])


def test_inmodel_table_invariants_random_churn():
    """Hypothesis: random interleavings of append/compact/truncate/fork/
    prefix-splice on a live in-model table never double-free, never leak
    (pool refcount conservation), and always match the dense oracle after
    gather."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st_

    op = st_.tuples(
        st_.sampled_from(["append", "compact", "truncate", "fork",
                          "splice"]),
        st_.integers(0, 11))

    @settings(max_examples=30, deadline=None)
    @given(st_.lists(op, min_size=1, max_size=24))
    def run(ops):
        _run_inmodel_ops(ops)

    run()


# --------------------------------------------------------------------------- #
# Kernel: Pallas paged decode vs paged reference vs dense decode
# --------------------------------------------------------------------------- #
def _paged_layout(rng, b, n_slots, bs, kvh, d, lengths):
    """Scatter per-sequence dense KV rows into a shuffled physical pool."""
    mb = n_slots // bs
    kd = jnp.asarray(rng.normal(size=(b, n_slots, kvh, d)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(b, n_slots, kvh, d)), jnp.float32)
    n_blocks = b * mb + 3
    pool_k = jnp.zeros((n_blocks, bs, kvh, d), jnp.float32)
    pool_v = jnp.zeros((n_blocks, bs, kvh, d), jnp.float32)
    perm = rng.permutation(n_blocks)
    tables = np.full((b, mb), -1, np.int32)
    pi = 0
    for bi in range(b):
        for j in range(-(-int(lengths[bi]) // bs)):
            pid = int(perm[pi]); pi += 1
            tables[bi, j] = pid
            pool_k = pool_k.at[pid].set(kd[bi, j * bs:(j + 1) * bs])
            pool_v = pool_v.at[pid].set(vd[bi, j * bs:(j + 1) * bs])
    return kd, vd, pool_k, pool_v, jnp.asarray(tables)


def test_paged_kernel_matches_reference_and_dense():
    """Acceptance: Pallas paged decode (interpret), the pure-JAX paged
    reference and the dense decode kernel agree to <= 1e-5."""
    rng = np.random.default_rng(6)
    b, h, kvh, d, bs, n_slots = 3, 4, 2, 16, 8, 32
    lengths = jnp.asarray([32, 13, 27], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    kd, vd, pk, pv, tables = _paged_layout(rng, b, n_slots, bs, kvh, d,
                                           lengths)
    ref = kref.paged_decode_attention_reference(q, pk, pv, tables, lengths)
    pal = paged_kernel.paged_decode_attention(q, pk, pv, tables, lengths,
                                              interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    # per-sequence, the paged output equals the dense kernel on the same KV
    for bi in range(b):
        dense = dense_kernel.decode_attention(
            q[bi:bi + 1], kd[bi:bi + 1], vd[bi:bi + 1], lengths[bi],
            interpret=True)
        np.testing.assert_allclose(np.asarray(pal[bi:bi + 1]),
                                   np.asarray(dense), atol=1e-5, rtol=1e-5)
        dref = kref.decode_attention_reference(
            q[bi:bi + 1], kd[bi:bi + 1], vd[bi:bi + 1], lengths[bi])
        np.testing.assert_allclose(np.asarray(pal[bi:bi + 1]),
                                   np.asarray(dref), atol=1e-5, rtol=1e-5)


def test_paged_kernel_dispatch_and_gqa():
    """ops dispatcher: xla path == pallas path; MQA-style grouping works."""
    rng = np.random.default_rng(7)
    b, h, kvh, d, bs, n_slots = 2, 8, 1, 8, 4, 16
    lengths = jnp.asarray([9, 16], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    _, _, pk, pv, tables = _paged_layout(rng, b, n_slots, bs, kvh, d, lengths)
    a = kops.paged_decode_attention(q, pk, pv, tables, lengths, impl="xla")
    p = kops.paged_decode_attention(q, pk, pv, tables, lengths, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(p),
                               atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------- #
# Serving: store + engine acceptance
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def small_model():
    cfg = ModelConfig(
        name="t", arch_type="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16, dtype="float32",
        lacache=LaCacheConfig(budget=48, n_sink=2, n_recent=8, chunk=2))
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_store_roundtrip_and_lineage_sharing(small_model):
    """DecodeState pages in and gathers back bit-exactly (identical next
    logits); a child snapshot extending a parent shares whole blocks."""
    cfg, params = small_model
    toks = jnp.asarray(
        np.random.default_rng(8).integers(0, cfg.vocab_size, (1, 20)))
    _, state = M.prefill(params, cfg, toks, n_slots=48)
    store = paged.PagedStateStore(64, 16, cfg.n_kv_heads, cfg.head_dim_,
                                  jnp.float32)
    snap, owned = store.put(state)
    assert owned > 0 and store.bytes_shared == 0
    t = jnp.asarray([[5]])
    a, _ = M.decode_step(params, cfg, state, t)
    b, _ = M.decode_step(params, cfg, store.get(snap), t)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    more = jnp.asarray(
        np.random.default_rng(9).integers(0, cfg.vocab_size, (1, 8)))
    _, state2 = M.decode_chunk(params, cfg, state, more)
    snap2, owned2 = store.put(state2, parent=snap)
    assert store.bytes_shared > 0
    assert (store.snapshot_refcounts(snap2) > 1).any()
    assert owned2 < owned          # the shared block prefix was not re-paid
    c, _ = M.decode_step(params, cfg, state2, t)
    d, _ = M.decode_step(params, cfg, store.get(snap2), t)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(d))
    store.release(snap)
    store.release(snap2)
    paged.check_invariants(store.pool)
    assert store.bytes_in_use == 0


def test_engine_shared_prefix_blocks_and_accounting(small_model):
    """Acceptance: two paged requests with a shared prefix physically share
    blocks (refcounts > 1, bytes_shared > 0), match the dense backend
    token-for-token, and the LRU budget charges only unique bytes."""
    cfg, params = small_model
    rng = np.random.default_rng(10)
    shared = rng.integers(0, cfg.vocab_size, (24,))
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab_size, (6,))])
               for _ in range(2)]

    def serve(backend):
        eng = Engine(cfg, params, budget=48, max_batch=2, kv_backend=backend)
        reqs = [eng.submit(p, 5, cache_prefix=True) for p in prompts]
        eng.run()
        return eng, reqs

    dense_eng, dense_reqs = serve("dense")
    paged_eng, paged_reqs = serve("paged")
    for a, b in zip(dense_reqs, paged_reqs):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert paged_eng.bytes_shared > 0
    assert (np.asarray(paged_eng.kv_store.pool.ref) > 1).any()
    assert dense_eng.bytes_shared == 0
    # unique-bytes accounting: the paged budget charge must be well below
    # the dense full-copy charge for the same snapshot set
    assert paged_eng.prefix_cache.nbytes < dense_eng.prefix_cache.nbytes
    assert paged_eng.prefix_cache.peak_bytes <= dense_eng.prefix_cache.peak_bytes
    paged.check_invariants(paged_eng.kv_store.pool)


def test_paged_accounting_tracks_residency_under_eviction(small_model):
    """Evicting an ancestor snapshot must not uncharge blocks a descendant
    still holds: the cache's nbytes tracks resident pool bytes plus dense
    overhead exactly, through any eviction order (ownership transfers to
    survivors instead of vanishing). Under in-model paged decode the batch
    lanes hold a constant reserved block set (``lane_owned_bytes``) that is
    never charged to the prefix cache, so the attributable basis excludes
    it — and after every entry evicts, only that reservation remains."""
    from repro.serving.prefix import tree_bytes
    cfg, params = small_model
    eng = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged")
    prompt = np.random.default_rng(13).integers(0, cfg.vocab_size, (40,))
    eng.submit(prompt, 2, cache_prefix=True)   # snapshots at 16, 32, 40
    eng.run()
    pc, store = eng.prefix_cache, eng.kv_store
    assert len(pc) == 3

    def attributable():
        return store.bytes_in_use - eng.lane_owned_bytes + sum(
            e.snap.dense_bytes + tree_bytes(e.logits)
            for e in pc._entries.values())

    assert pc.nbytes == attributable()
    while len(pc) > 0:           # LRU evicts the shared ancestors first
        assert pc.evict_lru()
        assert pc.nbytes == attributable()
        paged.check_invariants(store.pool)
    assert pc.nbytes == 0
    assert store.bytes_in_use == eng.lane_owned_bytes


def test_midrun_entry_eviction_settles_charge_at_retirement(small_model):
    """Evicting snapshot entries while the forking request still RUNS frees
    no blocks (the lane keeps reading them), so the cache's byte charge
    must wait — and then settle exactly when the lane retires. Without
    settlement the charge leaks, the effective LRU budget shrinks to
    nothing, and the eviction loop eventually underflows the entry map."""
    from repro.serving.prefix import tree_bytes
    cfg, params = small_model
    # a byte budget only big enough for ~one snapshot: every insert evicts
    # the previous entry while its blocks are still lane-held
    eng = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged",
                 prefix_cache_bytes=40_000)
    rng = np.random.default_rng(21)
    for w in range(3):
        prompt = rng.integers(0, cfg.vocab_size, (40,))
        eng.submit(prompt, 3, cache_prefix=True)
        eng.run()                        # retires inside; charge settles
        pc, store = eng.prefix_cache, eng.kv_store
        assert eng.prefix_cache.evictions > 0 or w == 0
        attributable = store.bytes_in_use - eng.lane_owned_bytes + sum(
            e.snap.dense_bytes + tree_bytes(e.logits)
            for e in pc._entries.values())
        assert pc.nbytes == attributable, (w, pc.nbytes, attributable)
        paged.check_invariants(store.pool)
    eng.prefix_cache.clear()
    assert eng.prefix_cache.nbytes == 0
    assert eng.kv_bytes_in_use == eng.lane_owned_bytes


def test_preemption_resumes_exactly(small_model):
    """Acceptance: a RUNNING request preempted under deadline pressure
    resumes with continuation tokens identical to an uninterrupted run."""
    cfg, params = small_model
    rng = np.random.default_rng(11)
    pa, pb = rng.integers(0, cfg.vocab_size, (20,)), \
        rng.integers(0, cfg.vocab_size, (12,))

    ref = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged",
                 admission="deadline")
    ra = ref.submit(pa, 10, deadline=10.0)
    ref.run()

    eng = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged",
                 admission="deadline")
    a = eng.submit(pa, 10, deadline=10.0)
    for _ in range(4):
        eng.step()
    n_before = len(a.output_tokens)
    assert a.status == "running" and 0 < n_before < 10
    b = eng.submit(pb, 3, deadline=1.0)     # earlier deadline -> preempts A
    eng.step()
    assert a.status == "pending" and b.status == "running"
    assert eng.preemptions == 1
    eng.run()
    np.testing.assert_array_equal(a.tokens, ra.tokens)
    assert b.status == "finished" and len(b.output_tokens) == 3
    paged.check_invariants(eng.kv_store.pool)


def test_preempted_deadline_request_not_starved_by_later_arrivals(small_model):
    """Requeue-fairness regression: a preempted request re-enters admission
    at its *original* submit order. With a fresh sequence number, a later
    arrival with the same deadline would tie-break ahead of it at every
    admission round and starve it indefinitely."""
    cfg, params = small_model
    rng = np.random.default_rng(14)
    eng = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged",
                 admission="deadline")
    a = eng.submit(rng.integers(0, cfg.vocab_size, (12,)), 8, deadline=10.0)
    for _ in range(3):
        eng.step()
    assert a.status == "running"
    b = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 4, deadline=1.0)
    # C arrives AFTER A was submitted, with A's deadline: once B preempts A,
    # the pending heap holds {A (requeued), C} at the same deadline
    c = eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 2, deadline=10.0)
    eng.step()
    assert a.status == "pending" and b.status == "running"
    assert eng.preemptions == 1
    # drive until B retires, then one more tick for the freed slot's
    # admission: A must win the deadline tie against C by original
    # submission order
    while b.status != "finished":
        eng.step()
    eng.step()
    assert a.status == "running", (a.status, c.status)
    assert c.status == "pending"
    eng.run()
    assert a.status == "finished" and c.status == "finished"
    paged.check_invariants(eng.kv_store.pool)


def test_fifo_never_preempts_and_dense_preempt_rejected(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(12)
    eng = Engine(cfg, params, budget=48, max_batch=1, kv_backend="paged")
    eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 6)
    eng.step()
    eng.submit(rng.integers(0, cfg.vocab_size, (8,)), 2)
    eng.step()
    assert eng.preemptions == 0             # FIFO: incumbents always win
    eng.run()

    dense = Engine(cfg, params, budget=48, max_batch=1)
    dense.submit(rng.integers(0, cfg.vocab_size, (8,)), 4)
    dense.step()
    with pytest.raises(RuntimeError, match="paged"):
        dense.preempt(0)
    dense.run()


def test_bad_backend_rejected(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="kv_backend"):
        Engine(cfg, params, budget=48, kv_backend="virtual")
