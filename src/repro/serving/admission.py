"""First-class admission policies for the request scheduler.

Mirrors the eviction-policy registry (:mod:`repro.core.policy`): an
:class:`AdmissionPolicy` decides the order in which pending requests are
admitted into free batch slots. The scheduler keeps its pending queue as a
heap ordered by :meth:`AdmissionPolicy.key`, so a policy is just a sort key
over (request, submission sequence number) — submission order is always the
final tie-break, keeping every policy deterministic and starvation-visible.

Built-ins:

* ``fifo``     — strict submission order (the PR-1 behaviour),
* ``priority`` — higher ``Request.priority`` first (ties: FIFO),
* ``deadline`` — earliest ``Request.deadline`` first (requests without a
  deadline sort last; ties: FIFO) — the SLO-aware ordering.

New policies plug in via :func:`register_admission` without touching the
scheduler or the engine::

    @register_admission
    class ShortestFirst(AdmissionPolicy):
        name = "shortest"
        def key(self, req, seq):
            return (req.prompt_len, seq)

CLI choices (``repro.launch.serve --admission``) derive from the registry.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple, Union


class AdmissionPolicy:
    """Base class / protocol for scheduler admission policies.

    Subclasses set ``name`` and implement :meth:`key`. Policy instances are
    stateless and shared (singletons in the registry).
    """

    name: str = ""

    def key(self, req, seq: int) -> Tuple:
        """Heap sort key for one pending request; smaller is admitted first.

        ``seq`` is the monotonically increasing submission sequence number —
        include it (last) so equal-keyed requests admit in FIFO order.
        """
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
_REGISTRY: Dict[str, AdmissionPolicy] = {}

AdmissionLike = Union[str, AdmissionPolicy]


def register_admission(policy) -> AdmissionPolicy:
    """Register an admission policy instance (or class, instantiated).

    Usable as a decorator; re-registering a name overwrites (latest wins).
    """
    obj = policy() if isinstance(policy, type) else policy
    if not isinstance(obj, AdmissionPolicy):
        raise TypeError(f"not an AdmissionPolicy: {policy!r}")
    if not obj.name:
        raise ValueError(f"admission policy {policy!r} has no name")
    _REGISTRY[obj.name] = obj
    return policy


def get_admission(policy: AdmissionLike) -> AdmissionPolicy:
    """Resolve an admission-policy name (or pass through an instance)."""
    if isinstance(policy, AdmissionPolicy):
        return policy
    try:
        return _REGISTRY[policy]
    except KeyError:
        raise ValueError(
            f"unknown admission policy {policy!r}; "
            f"registered: {sorted(_REGISTRY)}") from None


def admission_names() -> List[str]:
    """Registered admission-policy names (CLI choices derive from this)."""
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------- #
# Built-in policies
# --------------------------------------------------------------------------- #
@register_admission
class FIFOAdmission(AdmissionPolicy):
    """Strict submission order."""

    name = "fifo"

    def key(self, req, seq):
        return (seq,)


@register_admission
class PriorityAdmission(AdmissionPolicy):
    """Higher ``Request.priority`` admitted first; ties in FIFO order."""

    name = "priority"

    def key(self, req, seq):
        return (-req.priority, seq)


@register_admission
class DeadlineAdmission(AdmissionPolicy):
    """Earliest ``Request.deadline`` first (SLO-aware EDF); requests
    without a deadline sort after all deadlined ones; ties FIFO."""

    name = "deadline"

    def key(self, req, seq):
        d = req.deadline if req.deadline is not None else math.inf
        return (d, seq)


def deadline_slack(req, now: float) -> float:
    """Seconds of headroom before ``req``'s deadline at time ``now``
    (``inf`` for requests without one; negative once missed). Shared by
    the engine's SLO metrics and the traffic harness's goodput accounting
    so "met the deadline" means the same thing everywhere."""
    return math.inf if req.deadline is None else req.deadline - now
