"""Batched serving engine with LaCache iterative compaction.

Wraps the model's prefill / decode_step into jitted drivers:

* :meth:`generate` — batched autoregressive generation under any eviction
  policy (lacache / streaming / h2o / full),
* :meth:`score_stream` — token-by-token teacher-forced scoring through the
  *decode* path (the paper's Wikitext/PG19 evaluation semantics: each
  prediction only sees the compacted cache), with O(1) memory,
* :meth:`generate_stream` — unbounded continuous generation (paper §3.3's
  infinite-length claim): memory never grows past the budget.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving import sampling


class Engine:
    def __init__(self, cfg: ModelConfig, params, budget: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.budget = budget if budget is not None else cfg.lacache.budget
        self._decode = jax.jit(functools.partial(M.decode_step, cfg=cfg))
        self._decode_score = jax.jit(self._decode_and_score)
        self._prefill = jax.jit(functools.partial(M.prefill, cfg=cfg),
                                static_argnames=("n_slots",))

    # ------------------------------------------------------------------ #
    def _decode_and_score(self, params, state, token, next_token):
        logits, state = M.decode_step(params, self.cfg, state, token)
        lp = sampling.log_prob_of(logits, next_token[:, 0])
        return lp, logits, state

    def new_state(self, batch: int, frames=None):
        return M.init_decode_state(self.params, self.cfg, batch,
                                   self.budget, frames=frames)

    # ------------------------------------------------------------------ #
    def prefill(self, tokens, patches=None, frames=None):
        return self._prefill(self.params, tokens=tokens, n_slots=self.budget,
                             patches=patches, frames=frames)

    def generate(self, prompt_tokens, max_new_tokens: int, *,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 patches=None, frames=None) -> np.ndarray:
        """prompt_tokens [b, t] -> generated [b, max_new_tokens]."""
        logits, state = self.prefill(prompt_tokens, patches=patches,
                                     frames=frames)
        key = jax.random.PRNGKey(seed)
        outs = []
        tok = (sampling.greedy(logits) if temperature == 0.0 else
               sampling.sample(key, logits, temperature, top_k))[:, None]
        for i in range(max_new_tokens):
            outs.append(np.asarray(tok[:, 0]))
            logits, state = self._decode(self.params, state=state, tokens=tok)
            if temperature == 0.0:
                tok = sampling.greedy(logits)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = sampling.sample(sub, logits, temperature, top_k)[:, None]
        return np.stack(outs, axis=1)

    # ------------------------------------------------------------------ #
    def score_stream(self, tokens, *, frames=None, prime: int = 1,
                     collect_every: int = 1) -> np.ndarray:
        """Teacher-forced token-by-token NLL through the decode path.

        tokens [b, T]: feeds tokens[:, i] and scores tokens[:, i+1] under the
        policy-restricted cache — the paper's language-modeling evaluation.
        Returns per-position NLL [b, T-prime].
        """
        tokens = jnp.asarray(tokens)
        b, T = tokens.shape
        state = self.new_state(b, frames=frames)
        # prime the cache with the first `prime` tokens (BOS etc.)
        nlls = []
        for i in range(T - 1):
            lp, _, state = self._decode_score(
                self.params, state, tokens[:, i:i + 1], tokens[:, i + 1:i + 2])
            if i >= prime - 1:
                nlls.append(np.asarray(-lp))
        return np.stack(nlls, axis=1)

    def cache_bytes(self, state) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(state["blocks"])) + \
               sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(state["tail"]))


# --------------------------------------------------------------------------- #
# Chunked streaming APIs (added with model.decode_chunk)
# --------------------------------------------------------------------------- #
def _chunked_score(engine: "Engine", tokens, chunk: int = 64, frames=None):
    """Teacher-forced NLL via decode_chunk: O(budget*T), ~chunk x fewer
    dispatches than score_stream. Same streaming semantics (every prediction
    sees only the compacted cache + chunk prefix)."""
    import functools as _ft
    from repro.models import model as _M
    from repro.serving import sampling as _s
    tokens = jnp.asarray(tokens)
    b, T = tokens.shape
    # a chunk must fit in the slot buffer alongside the compacted past
    chunk = max(1, min(chunk, engine.budget // 2))
    state = engine.new_state(b, frames=frames)
    if not hasattr(engine, "_decode_chunk"):
        engine._decode_chunk = jax.jit(
            _ft.partial(_M.decode_chunk, cfg=engine.cfg))
    nll = []
    n_chunks = (T - 1) // chunk
    for ci in range(n_chunks + (1 if (T - 1) % chunk else 0)):
        s, e = ci * chunk, min((ci + 1) * chunk, T - 1)
        if e <= s:
            break
        if e - s != chunk:  # ragged tail: pad to the jitted chunk size
            pad = chunk - (e - s)
            seg = jnp.pad(tokens[:, s:e], ((0, 0), (0, pad)))
        else:
            seg = tokens[:, s:e]
        logits, state = engine._decode_chunk(engine.params, state=state,
                                             tokens=seg)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        gold = tokens[:, s + 1:e + 1]
        g = jnp.take_along_axis(lp[:, :e - s], gold[..., None], axis=-1)[..., 0]
        nll.append(np.asarray(-g))
    return np.concatenate(nll, axis=1)


Engine.score_stream_chunked = _chunked_score
