"""Request-level serving engine with LaCache iterative compaction.

Two API layers over the model's jitted prefill / decode:

**Lockstep (batch) layer** — the paper's evaluation drivers:

* :meth:`Engine.generate` — batched autoregressive generation under any
  registered eviction policy,
* :meth:`Engine.score_stream` / :meth:`Engine.score_stream_chunked` —
  token-by-token (or chunk-amortized) teacher-forced scoring through the
  *decode* path (the paper's Wikitext/PG19 evaluation semantics: each
  prediction only sees the compacted cache), with O(1) memory.

**Request layer** — continuous batching for serving traffic:

* :meth:`Engine.submit` enqueues a :class:`Request` (own prompt length,
  ``max_new_tokens``, :class:`SamplingParams`),
* :meth:`Engine.step` admits pending requests into free batch slots
  (prefill), advances every active slot one decode step, samples
  per-request, and retires finished requests (their slot is immediately
  recyclable),
* :meth:`Engine.run` drives :meth:`step` until the queue drains.

Slots are independent: the slot axis is a ``jax.vmap`` over the same jitted
``decode_step`` the lockstep layer uses, so each slot carries its own
absolute position and cache occupancy — requests of different lengths
coexist in one batch, and per-slot compaction fires independently. With a
uniform batch the per-slot computation is identical to lockstep
:meth:`generate` (asserted by tests).
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving import sampling


# --------------------------------------------------------------------------- #
# Requests
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration (temperature 0 => greedy)."""

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


PENDING, RUNNING, FINISHED = "pending", "running", "finished"


@dataclasses.dataclass(eq=False)   # identity equality: holds ndarrays
class Request:
    """One generation request moving through pending -> running -> finished."""

    prompt: np.ndarray                  # [prompt_len] int32
    max_new_tokens: int
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    request_id: int = -1
    status: str = PENDING
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1                      # batch slot while RUNNING, else -1
    _key: Any = None                    # per-request PRNG chain (runtime)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def tokens(self) -> np.ndarray:
        """Generated tokens so far, [<= max_new_tokens] int32."""
        return np.asarray(self.output_tokens, np.int32)

    @property
    def done(self) -> bool:
        return len(self.output_tokens) >= self.max_new_tokens


class Scheduler:
    """FIFO admission of requests into a fixed pool of batch slots.

    Invariants (tested): a request occupies exactly one slot while RUNNING;
    retiring frees the slot for the next admission; pending order is
    preserved; ``n_running + n_free == n_slots`` always.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("scheduler needs at least one slot")
        self.n_slots = n_slots
        self.pending: deque = deque()
        self.running: Dict[int, Request] = {}
        self._free: List[int] = list(range(n_slots))

    @property
    def has_work(self) -> bool:
        return bool(self.pending) or bool(self.running)

    @property
    def free_slots(self) -> List[int]:
        return sorted(self._free)

    def submit(self, req: Request) -> Request:
        req.status = PENDING
        self.pending.append(req)
        return req

    def admit(self) -> List[Tuple[int, Request]]:
        """Move pending requests into free slots (FIFO, lowest slot first)."""
        admitted = []
        while self.pending and self._free:
            self._free.sort()
            slot = self._free.pop(0)
            req = self.pending.popleft()
            req.status, req.slot = RUNNING, slot
            self.running[slot] = req
            admitted.append((slot, req))
        return admitted

    def retire(self, slot: int) -> Request:
        req = self.running.pop(slot)
        req.status, req.slot = FINISHED, -1
        self._free.append(slot)
        return req


# --------------------------------------------------------------------------- #
# Engine
# --------------------------------------------------------------------------- #
class Engine:
    def __init__(self, cfg: ModelConfig, params, budget: Optional[int] = None,
                 max_batch: int = 8):
        self.cfg = cfg
        self.params = params
        self.budget = budget if budget is not None else cfg.lacache.budget
        self.max_batch = max_batch
        self._decode = jax.jit(functools.partial(M.decode_step, cfg=cfg))
        self._decode_score = jax.jit(self._decode_and_score)
        self._decode_chunk = jax.jit(functools.partial(M.decode_chunk, cfg=cfg))
        self._prefill = jax.jit(functools.partial(M.prefill, cfg=cfg),
                                static_argnames=("n_slots",))
        # slot axis = vmap over the SAME decode_step the lockstep path jits:
        # each slot has its own pos / cache occupancy / compaction schedule.
        self._slot_step = jax.jit(jax.vmap(
            lambda p, s, t: M.decode_step(p, cfg, s, t),
            in_axes=(None, 0, 0)))
        # one fused dispatch per admission; donation lets XLA splice the
        # request's prefill state into the slot stack in place instead of
        # copying every [max_batch, ...] cache buffer per leaf.
        self._splice = jax.jit(
            lambda full, one, slot: jax.tree.map(
                lambda F, o: jax.lax.dynamic_update_index_in_dim(
                    F, o.astype(F.dtype), slot, 0), full, one),
            donate_argnums=(0,))
        self.scheduler = Scheduler(max_batch)
        self._slot_states = None            # stacked DecodeState [max_batch, ...]
        self._slot_tokens = np.zeros((max_batch,), np.int64)
        self._next_id = 0

    # ------------------------------------------------------------------ #
    # Lockstep (batch) layer
    # ------------------------------------------------------------------ #
    def _decode_and_score(self, params, state, token, next_token):
        logits, state = M.decode_step(params, self.cfg, state, token)
        lp = sampling.log_prob_of(logits, next_token[:, 0])
        return lp, logits, state

    def new_state(self, batch: int, frames=None) -> M.DecodeState:
        return M.init_decode_state(self.params, self.cfg, batch,
                                   self.budget, frames=frames)

    def prefill(self, tokens, patches=None, frames=None):
        return self._prefill(self.params, tokens=tokens, n_slots=self.budget,
                             patches=patches, frames=frames)

    def generate(self, prompt_tokens, max_new_tokens: int, *,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 patches=None, frames=None) -> np.ndarray:
        """Lockstep: prompt_tokens [b, t] -> generated [b, max_new_tokens]."""
        logits, state = self.prefill(prompt_tokens, patches=patches,
                                     frames=frames)
        key = jax.random.PRNGKey(seed)
        outs = []
        tok = (sampling.greedy(logits) if temperature == 0.0 else
               sampling.sample(key, logits, temperature, top_k))[:, None]
        for i in range(max_new_tokens):
            outs.append(np.asarray(tok[:, 0]))
            logits, state = self._decode(self.params, state=state, tokens=tok)
            if temperature == 0.0:
                tok = sampling.greedy(logits)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = sampling.sample(sub, logits, temperature, top_k)[:, None]
        return np.stack(outs, axis=1)

    def score_stream(self, tokens, *, frames=None, prime: int = 1,
                     collect_every: int = 1) -> np.ndarray:
        """Teacher-forced token-by-token NLL through the decode path.

        tokens [b, T]: feeds tokens[:, i] and scores tokens[:, i+1] under the
        policy-restricted cache — the paper's language-modeling evaluation.
        Returns per-position NLL [b, T-prime].
        """
        tokens = jnp.asarray(tokens)
        b, T = tokens.shape
        state = self.new_state(b, frames=frames)
        # prime the cache with the first `prime` tokens (BOS etc.)
        nlls = []
        for i in range(T - 1):
            lp, _, state = self._decode_score(
                self.params, state, tokens[:, i:i + 1], tokens[:, i + 1:i + 2])
            if i >= prime - 1:
                nlls.append(np.asarray(-lp))
        return np.stack(nlls, axis=1)

    def score_stream_chunked(self, tokens, chunk: int = 64,
                             frames=None) -> np.ndarray:
        """Teacher-forced NLL via decode_chunk: O(budget*T), ~chunk x fewer
        dispatches than score_stream. Same streaming semantics (every
        prediction sees only the compacted cache + chunk prefix)."""
        tokens = jnp.asarray(tokens)
        b, T = tokens.shape
        # a chunk must fit in the slot buffer alongside the compacted past
        chunk = max(1, min(chunk, self.budget // 2))
        state = self.new_state(b, frames=frames)
        nll = []
        n_chunks = (T - 1) // chunk
        for ci in range(n_chunks + (1 if (T - 1) % chunk else 0)):
            s, e = ci * chunk, min((ci + 1) * chunk, T - 1)
            if e <= s:
                break
            # the ragged tail dispatches at its own size (one extra compile)
            # rather than padding: padded appends can overflow the slot
            # buffer under a non-evicting policy and corrupt live slots.
            seg = tokens[:, s:e]
            logits, state = self._decode_chunk(self.params, state=state,
                                               tokens=seg)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            gold = tokens[:, s + 1:e + 1]
            g = jnp.take_along_axis(lp[:, :e - s], gold[..., None],
                                    axis=-1)[..., 0]
            nll.append(np.asarray(-g))
        return np.concatenate(nll, axis=1)

    def cache_bytes(self, state: M.DecodeState) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(state.blocks)) + \
               sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(state.tail))

    # ------------------------------------------------------------------ #
    # Request layer (continuous batching)
    # ------------------------------------------------------------------ #
    def submit(self, prompt, max_new_tokens: int,
               sampling_params: Optional[SamplingParams] = None) -> Request:
        """Enqueue one request. prompt: [t] int tokens (1-D)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        sp = sampling_params or SamplingParams()
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens,
                      sampling=sp, request_id=self._next_id,
                      _key=jax.random.PRNGKey(sp.seed))
        self._next_id += 1
        return self.scheduler.submit(req)

    def _ensure_slot_states(self):
        if self._slot_states is None:
            one = self.new_state(1)
            self._slot_states = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (self.max_batch,) + x.shape).copy(), one)

    def _sample_next(self, req: Request, logits_row) -> int:
        """Sample one token for a request from its [1, V] logits row."""
        sp = req.sampling
        if sp.temperature == 0.0:
            tok = sampling.greedy(logits_row)
        else:
            req._key, sub = jax.random.split(req._key)
            tok = sampling.sample(sub, logits_row, sp.temperature, sp.top_k)
        return int(tok[0])

    def _record(self, req: Request, tok: int) -> None:
        req.output_tokens.append(tok)
        self._slot_tokens[req.slot] = tok

    def step(self) -> List[Request]:
        """One engine tick. Returns the requests that finished this tick.

        1. Admit pending requests into free slots: per-request prefill
           (jitted; distinct prompt lengths compile once each), sample the
           first token, splice the request's decode state into its slot.
        2. vmap-decode every slot one step (inactive slots are masked out of
           all bookkeeping — their lanes compute but are never read).
        3. Per-request sampling of the next token; requests reaching
           ``max_new_tokens`` retire and free their slot immediately.
        """
        self._ensure_slot_states()
        finished: List[Request] = []

        for slot, req in self.scheduler.admit():
            logits, state1 = self.prefill(jnp.asarray(req.prompt)[None])
            self._slot_states = self._splice(self._slot_states, state1,
                                             jnp.asarray(slot, jnp.int32))
            self._record(req, self._sample_next(req, logits))
            if req.done:
                finished.append(self.scheduler.retire(slot))

        if self.scheduler.running:
            toks = jnp.asarray(self._slot_tokens, jnp.int32)[:, None, None]
            logits, self._slot_states = self._slot_step(
                self.params, self._slot_states, toks)
            logits = np.asarray(logits)          # [max_batch, 1, V]
            for slot in sorted(self.scheduler.running):
                req = self.scheduler.running[slot]
                self._record(req, self._sample_next(req, logits[slot]))
                if req.done:
                    finished.append(self.scheduler.retire(slot))
        return finished

    def run(self) -> List[Request]:
        """Drive :meth:`step` until the queue drains; returns the finished
        requests in submission order."""
        done: List[Request] = []
        while self.scheduler.has_work:
            done.extend(self.step())
        return sorted(done, key=lambda r: r.request_id)
