"""Request-level serving engine with LaCache iterative compaction.

Two API layers over the model's jitted prefill / decode:

**Lockstep (batch) layer** — the paper's evaluation drivers:

* :meth:`Engine.generate` — batched autoregressive generation under any
  registered eviction policy,
* :meth:`Engine.score_stream` / :meth:`Engine.score_stream_chunked` —
  token-by-token (or chunk-amortized) teacher-forced scoring through the
  *decode* path (the paper's Wikitext/PG19 evaluation semantics: each
  prediction only sees the compacted cache), with O(1) memory.

**Request layer** — continuous batching for serving traffic:

* :meth:`Engine.submit` enqueues a :class:`Request` (own prompt length,
  ``max_new_tokens``, :class:`SamplingParams`, plus ``priority`` /
  ``deadline`` for the admission policy, ``cache_prefix`` to opt into the
  shared-prefix prompt cache, and ``on_token`` for streamed token
  callbacks),
* :meth:`Engine.step` admits pending requests into free batch slots
  (prefill — reusing the longest cached prompt prefix, so only the suffix
  is computed), advances every active slot one decode step, samples
  per-request, and retires finished requests (their slot is immediately
  recyclable),
* :meth:`Engine.run` drives :meth:`step` until the queue drains.

Admission order is pluggable (:mod:`repro.serving.admission`: ``fifo``,
``priority``, ``deadline``, mirroring the eviction-policy registry); the
scheduler's pending queue is a heap over the admission policy's sort key.

Prefill is optionally *bucketed* (``bucket_prefill=True``): prompts are
right-padded to power-of-two lengths and dispatched with a traced
``true_len``, so mixed-length traffic compiles one executable per bucket
instead of one per distinct prompt length. Attention layers are exact by
causality; SSM/hybrid stacks run the pad-masked scan (``dt`` zeroed at
pads, conv window dynamic-sliced) so their states freeze at ``true_len``
exactly — only encoder (frames) inputs fall back to exact-length
prefill. Buckets clamp at ``cfg.max_position``; longer prompts dispatch
at exact length.

Slots are independent: the slot axis is a ``jax.vmap`` over the same jitted
``decode_step`` the lockstep layer uses, so each slot carries its own
absolute position and cache occupancy — requests of different lengths
coexist in one batch, and per-slot compaction fires independently. With a
uniform batch the per-slot computation is identical to lockstep
:meth:`generate` (asserted per registered policy by the differential
harness, ``tests/test_differential.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import math
import time
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.analysis import sanitizer as sanlib
from repro.configs.base import ModelConfig
from repro.core import paged as pagedlib
from repro.core.cache import MambaState
from repro.kernels import pool_mesh as pool_mesh_lib
from repro.models import model as M
from repro.obs.metrics import DEFAULT_SLACK_BUCKETS, NULL_REGISTRY
from repro.obs.trace import NULL_TRACER
from repro.serving import sampling
from repro.serving.admission import AdmissionLike, get_admission
from repro.serving.prefix import PrefixCache


# --------------------------------------------------------------------------- #
# In-model paged helpers (pure; jitted once per engine)
# --------------------------------------------------------------------------- #
def _lane_take(state: M.DecodeState, slot):
    """Extract one lane of a batched in-model paged state as a batch-1 sub-
    state. The pool planes *move* into the sub (the batched remainder comes
    back poolless) so the take/chunk-prefill/put chain keeps a single owner
    for the big buffers and every jit in the chain can donate them."""
    blocks = jax.tree.map(
        lambda x: jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=1),
        state.blocks)
    tail = jax.tree.map(
        lambda x: jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=0),
        state.tail)
    pos = jax.lax.dynamic_slice_in_dim(state.pos, slot, 1, axis=0)
    return (state._replace(kv_pool=None),
            state._replace(pos=pos, blocks=blocks, tail=tail))


def _lane_put(state: M.DecodeState, sub: M.DecodeState, slot) -> M.DecodeState:
    """Write a batch-1 sub-state back into its lane; the sub's pool planes
    (advanced by prefill) replace the batched state's wholesale."""
    blocks = jax.tree.map(
        lambda F, o: jax.lax.dynamic_update_slice_in_dim(
            F, o.astype(F.dtype), slot, axis=1), state.blocks, sub.blocks)
    tail = jax.tree.map(
        lambda F, o: jax.lax.dynamic_update_slice_in_dim(
            F, o.astype(F.dtype), slot, axis=0), state.tail, sub.tail)
    pos = jax.lax.dynamic_update_slice_in_dim(state.pos, sub.pos, slot, axis=0)
    return state._replace(pos=pos, blocks=blocks, tail=tail,
                          kv_pool=sub.kv_pool)


def _lane_reset(sub: M.DecodeState) -> M.DecodeState:
    """Empty a lane's logical state (tables unmapped, metadata cleared,
    ring next_pos and SSM states zeroed) while keeping its reserved
    ``owned`` block set intact."""
    def rp(leaf):
        if isinstance(leaf, pagedlib.PagedKVCache):
            return leaf._replace(
                blocks=jnp.full_like(leaf.blocks, -1),
                pos=jnp.full_like(leaf.pos, -1),
                length=jnp.zeros_like(leaf.length),
                scores=None if leaf.scores is None
                else jnp.zeros_like(leaf.scores))
        if isinstance(leaf, pagedlib.PagedRingCache):
            return leaf._replace(
                blocks=jnp.full_like(leaf.blocks, -1),
                pos=jnp.full_like(leaf.pos, -1),
                next_pos=jnp.zeros_like(leaf.next_pos))
        return jax.tree.map(jnp.zeros_like, leaf)   # SSM state

    return sub._replace(
        pos=jnp.zeros_like(sub.pos),
        blocks={k: rp(v) for k, v in sub.blocks.items()},
        tail={k: rp(v) for k, v in sub.tail.items()})


@dataclasses.dataclass(eq=False)
class _LaneParcel:
    """A preempted request's parked state: the table fork plus every pool
    reference the request holds (transferred from its former lane)."""

    snap: pagedlib.TableSnapshot
    held: np.ndarray           # block ids whose references travel with it
    held_charged: np.ndarray   # the subset charged to prefix-cache entries


# --------------------------------------------------------------------------- #
# Requests
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration (temperature 0 => greedy)."""

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def validate(self) -> "SamplingParams":
        """Reject nonsense at the API boundary (``Engine.submit``) instead
        of failing later inside a jitted sampler (or worse, silently)."""
        t = self.temperature
        if not isinstance(t, (int, float, np.floating, np.integer)) \
                or isinstance(t, bool) or not math.isfinite(t) or t < 0.0:
            raise ValueError(
                f"temperature must be a finite float >= 0, got {t!r}")
        k = self.top_k
        if not isinstance(k, (int, np.integer)) or isinstance(k, bool) or k < 0:
            raise ValueError(f"top_k must be an int >= 0, got {k!r}")
        if not isinstance(self.seed, (int, np.integer)) \
                or isinstance(self.seed, bool):
            raise ValueError(f"seed must be an int, got {self.seed!r}")
        return self


PENDING, RUNNING, FINISHED = "pending", "running", "finished"
FAILED = "failed"       # terminal: the request's on_token callback raised


@dataclasses.dataclass(eq=False)   # identity equality: holds ndarrays
class Request:
    """One generation request moving through pending -> running -> finished."""

    prompt: np.ndarray                  # [prompt_len] int32
    max_new_tokens: int
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    request_id: int = -1
    status: str = PENDING
    output_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1                      # batch slot while RUNNING, else -1
    priority: int = 0                   # higher admits first ("priority")
    deadline: Optional[float] = None    # earlier admits first ("deadline")
    cache_prefix: bool = False          # opt into the shared-prefix cache
    on_token: Optional[Callable[["Request", int], None]] = None
    spec_waves: int = 0                 # draft/verify waves on this lane
    spec_proposed: int = 0              # draft tokens proposed for it
    spec_accepted: int = 0              # draft tokens the target accepted
    error: Optional[BaseException] = None   # set when on_token raised: the
    #                                     request retires FAILED instead of
    #                                     unwinding mid-step()
    n_preempts: int = 0                 # times swapped out of a slot
    # lifecycle timestamps on the engine's clock (None until reached);
    # latency histograms (queue wait / TTFT / TPOT) derive from these
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None     # first admission only
    t_first: Optional[float] = None     # first token sampled
    t_finish: Optional[float] = None    # retirement
    _key: Any = None                    # per-request PRNG chain (runtime)
    _resume: Any = None                 # (PagedSnapshot, last token) while
    #                                     preempted; None otherwise
    _submit_seq: int = -1               # original scheduler sequence number
    #                                     (requeue fairness: preemption does
    #                                     not reset admission order)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def tokens(self) -> np.ndarray:
        """Generated tokens so far, [<= max_new_tokens] int32."""
        return np.asarray(self.output_tokens, np.int32)

    @property
    def done(self) -> bool:
        return (self.error is not None
                or len(self.output_tokens) >= self.max_new_tokens)

    @property
    def spec_acceptance_rate(self) -> float:
        """Fraction of this request's proposed draft tokens the verifier
        accepted (0.0 until the first wave touches its lane)."""
        return self.spec_accepted / max(1, self.spec_proposed)


class Scheduler:
    """Policy-ordered admission of requests into a fixed pool of batch slots.

    The pending queue is a heap over the admission policy's sort key
    (:mod:`repro.serving.admission`; default ``fifo`` preserves submission
    order exactly). Invariants (tested): a request occupies exactly one
    slot while RUNNING; retiring frees the slot for the next admission;
    ``n_running + n_free == n_slots`` always.
    """

    def __init__(self, n_slots: int, admission: AdmissionLike = "fifo"):
        if n_slots < 1:
            raise ValueError("scheduler needs at least one slot")
        self.n_slots = n_slots
        self.admission = get_admission(admission)
        self.pending: List[Tuple[Tuple, int, Request]] = []   # heap
        self.running: Dict[int, Request] = {}
        self._free: List[int] = list(range(n_slots))
        self._seq = 0

    @property
    def has_work(self) -> bool:
        return bool(self.pending) or bool(self.running)

    @property
    def free_slots(self) -> List[int]:
        return sorted(self._free)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def pending_requests(self) -> List[Request]:
        """Pending requests in admission order (non-destructive)."""
        return [r for _, _, r in sorted(self.pending)]

    def submit(self, req: Request) -> Request:
        req.status = PENDING
        req._submit_seq = self._seq     # admission identity: survives requeue
        heapq.heappush(self.pending,
                       (self.admission.key(req, self._seq), self._seq, req))
        self._seq += 1
        return req

    def admit(self) -> List[Tuple[int, Request]]:
        """Move pending requests into free slots (admission-policy order,
        lowest slot first)."""
        admitted = []
        while self.pending and self._free:
            self._free.sort()
            slot = self._free.pop(0)
            _, _, req = heapq.heappop(self.pending)
            req.status, req.slot = RUNNING, slot
            self.running[slot] = req
            admitted.append((slot, req))
        return admitted

    def retire(self, slot: int) -> Request:
        req = self.running.pop(slot)
        req.status, req.slot = FINISHED, -1
        self._free.append(slot)
        return req

    def requeue(self, slot: int) -> Request:
        """Preemption: move a RUNNING request back to the pending heap and
        free its slot. The request re-enters admission under its *original*
        submission sequence number — preemption is an implementation detail
        of slot pressure, not a new arrival, so deadline/priority ties must
        resolve against the pending heap at the request's original submit
        order. Requeueing at a fresh sequence number would let every later
        arrival with an equal admission key starve the preempted request
        indefinitely."""
        req = self.running.pop(slot)
        req.status, req.slot = PENDING, -1
        self._free.append(slot)
        seq = req._submit_seq
        heapq.heappush(self.pending,
                       (self.admission.key(req, seq), seq, req))
        return req


# --------------------------------------------------------------------------- #
# Engine
# --------------------------------------------------------------------------- #
class _EngineInstruments:
    """The engine's metric handles, resolved once at construction so the hot
    path increments plain floats (or, under the default null registry, hits
    shared no-op methods) without any per-event registry lookup. The metric
    catalogue here is documented in docs/API.md ("Observability")."""

    def __init__(self, m):
        self.submitted = m.counter(
            "engine_submitted_total", "requests submitted")
        self.admitted = m.counter(
            "engine_admissions_total",
            "admissions into a batch slot (resumes included)")
        self.resumed = m.counter(
            "engine_resumes_total", "preempted requests readmitted")
        self.preempted = m.counter(
            "engine_preemptions_total",
            "RUNNING requests swapped out under admission pressure")
        self.retired = m.counter(
            "engine_retired_total", "requests retired, by terminal status",
            labels=("status",))
        self.callback_errors = m.counter(
            "engine_callback_errors_total",
            "on_token callbacks that raised (request FAILED)")
        self.tokens = m.counter(
            "engine_tokens_total", "tokens emitted to requests")
        self.steps = m.counter("engine_steps_total", "engine ticks")
        self.decode_dispatches = m.counter(
            "engine_decode_dispatches_total",
            "batched decode dispatches (spec waves excluded)")
        self.prefill_dispatches = m.counter(
            "engine_prefill_dispatches_total",
            "prefill / chunk-prefill dispatches")
        self.prefill_tokens = m.counter(
            "engine_prefill_tokens_total",
            "prompt tokens by origin: computed vs prefix-cache reused",
            labels=("kind",))
        self.compactions = m.counter(
            "engine_compaction_events_total",
            "lane decode appends whose KV occupancy did not grow "
            "(ladder compaction fired; or a saturated non-evicting buffer)")
        self.queue_wait = m.histogram(
            "engine_queue_wait_seconds", "submit -> first admission")
        self.ttft = m.histogram(
            "engine_ttft_seconds", "submit -> first token")
        self.tpot = m.histogram(
            "engine_tpot_seconds",
            "mean inter-token interval per retired request")
        self.deadline_slack = m.histogram(
            "engine_deadline_slack_seconds",
            "deadline - finish time at retirement (negative = missed)",
            buckets=DEFAULT_SLACK_BUCKETS)
        self.deadline = m.counter(
            "engine_deadline_outcomes_total",
            "retired requests that carried a deadline, met vs missed",
            labels=("outcome",))
        # hot-path label children, resolved once
        self.prefill_computed = self.prefill_tokens.labels("computed")
        self.prefill_reused = self.prefill_tokens.labels("reused")
        self.retired_finished = self.retired.labels(FINISHED)
        self.retired_failed = self.retired.labels(FAILED)
        self.deadline_met = self.deadline.labels("met")
        self.deadline_missed = self.deadline.labels("missed")


class Engine:
    def __init__(self, cfg: ModelConfig, params, budget: Optional[int] = None,
                 max_batch: int = 8, *, admission: AdmissionLike = "fifo",
                 prefix_cache_bytes: int = 256 << 20, prefix_block: int = 16,
                 bucket_prefill: bool = False, min_bucket: int = 16,
                 kv_backend: str = "dense", page_size: int = 16,
                 pool_blocks: Optional[int] = None,
                 preempt: Optional[bool] = None,
                 spec_config: Optional["SpecConfig"] = None,
                 prewarm: bool = False, prewarm_prefill: bool = True,
                 mesh=None,
                 metrics=None, tracer=None,
                 clock: Optional[Callable[[], float]] = None):
        if kv_backend not in ("dense", "paged"):
            raise ValueError(
                f"kv_backend must be 'dense' or 'paged', got {kv_backend!r}")
        if mesh is not None and kv_backend != "paged":
            raise ValueError(
                "Engine(mesh=...) shards the paged pool planes; it requires "
                "kv_backend='paged' (dense decode states shard through the "
                "launch-layer dry-run path instead)")
        # observability: both default to shared no-op sinks, so metrics-off
        # serving pays only no-op method calls (and anything costlier — the
        # compaction probe's device reads — is gated on metrics.enabled).
        # ``clock`` (seconds; monotonic or simulated — the traffic harness
        # injects virtual time) stamps request lifecycle timestamps.
        self.metrics = metrics if metrics is not None else NULL_REGISTRY
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.clock = clock if clock is not None else time.perf_counter
        self._inst = _EngineInstruments(self.metrics)
        self._tick = 0
        self.tracer.thread_name(0, "engine")
        self.cfg = cfg
        self.params = params
        self.budget = budget if budget is not None else cfg.lacache.budget
        self.max_batch = max_batch
        self.kv_backend = kv_backend
        self._decode = jax.jit(functools.partial(M.decode_step, cfg=cfg))
        self._decode_score = jax.jit(self._decode_and_score)
        self._decode_chunk = jax.jit(functools.partial(M.decode_chunk, cfg=cfg))
        self._prefill = jax.jit(functools.partial(M.prefill, cfg=cfg),
                                static_argnames=("n_slots",))
        # slot axis = vmap over the SAME decode_step the lockstep path jits:
        # each slot has its own pos / cache occupancy / compaction schedule.
        self._slot_step = jax.jit(jax.vmap(
            lambda p, s, t: M.decode_step(p, cfg, s, t),
            in_axes=(None, 0, 0)))
        # one fused dispatch per admission; donation lets XLA splice the
        # request's prefill state into the slot stack in place instead of
        # copying every [max_batch, ...] cache buffer per leaf.
        self._splice = jax.jit(
            lambda full, one, slot: jax.tree.map(
                lambda F, o: jax.lax.dynamic_update_index_in_dim(
                    F, o.astype(F.dtype), slot, 0), full, one),
            donate_argnums=(0,))
        self.scheduler = Scheduler(max_batch, admission=admission)
        # paged backend: one global physical block pool. Eligible
        # architectures decode *through* the pool (in-model paged decode:
        # RUNNING requests' KV lives in block tables end-to-end — budgeted
        # slots AND ring windows; SSM states ride dense per-lane — prefix
        # hits splice shared blocks, snapshots are refcount forks and
        # preemption is a table handoff); only cross-attention / M-RoPE
        # architectures fall back to the store-backed mode where the pool
        # holds snapshots/preemptions and the decode loop stays dense.
        self.kv_store = None
        self._paged_in_model = False
        self.page_size = page_size
        # sharded paged serving: the pool planes live across `mesh` (kv-head
        # axis over "model" when it divides, else in-block slots — resolved
        # loudly at construction, never by silent replication), lanes over
        # "data" when max_batch divides it. The ALLOCATOR — refcounts, free
        # list, lane reservations, block-table bookkeeping — stays host-side
        # and global: sharding changes where KV bytes live, never who owns
        # them, so fork/splice/preempt/compaction semantics are untouched.
        self.mesh = mesh
        self._pool_mesh = None
        if mesh is not None:
            if not M.paged_decode_eligible(cfg):
                raise ValueError(
                    "Engine(mesh=...) requires the in-model paged decode "
                    "path; cross-attention / M-RoPE architectures run the "
                    "store-backed fallback, which is single-device")
            from repro.launch import sharding as shardlib
            # loud ValueError here (not at first decode) when neither
            # kv_heads nor page_size divides the model axis
            self._pool_mesh = shardlib.paged_pool_mesh_spec(
                mesh, cfg, page_size=page_size, max_batch=max_batch)
        if kv_backend == "paged":
            specs = cfg.layer_specs()
            n_kv_layers = sum(1 for s in specs
                              if s.kind == "attn" and s.attn == "global")
            n_ring_layers = sum(1 for s in specs if s.attn == "local")
            per_seq = pagedlib.blocks_for(self.budget, page_size)
            per_ring = pagedlib.blocks_for(max(1, cfg.sliding_window),
                                           page_size)
            if pool_blocks is None:
                # room for every batch slot plus a healthy prefix
                # working set; the prefix cache evicts LRU under pool
                # pressure, so this is a soft ceiling, not a failure mode.
                # Ring layers page their windows too; pure-SSM stacks keep
                # a nominal pool (their states ride dense).
                lane_blocks = max(1, n_kv_layers * per_seq
                                  + n_ring_layers * per_ring)
                pool_blocks = lane_blocks * max(8, 4 * max_batch)
            self.kv_store = pagedlib.PagedStateStore(
                pool_blocks, page_size, cfg.n_kv_heads, cfg.head_dim_,
                jnp.dtype(cfg.dtype))
            self.kv_store.bind_metrics(self.metrics)
            self._paged_in_model = M.paged_decode_eligible(cfg)
            self._lane_shared = [np.zeros((0,), np.int64)
                                 for _ in range(max_batch)]
            # the subset of _lane_shared charged to prefix-cache entries:
            # when the lane's release is the one that actually frees such a
            # block (its entry was evicted while the lane kept reading it),
            # the cache's byte charge is settled at retirement.
            self._lane_charged = [np.zeros((0,), np.int64)
                                  for _ in range(max_batch)]
            self._lane_owned_blocks = 0
            # the in-model hot path donates its state so XLA updates the
            # pool planes in place instead of copying them every dispatch
            # (the engine holds the only live reference: snapshots are
            # refcount forks of *tables*, never of pool buffers)
            self._paged_step = self._mesh_dispatch(jax.jit(
                functools.partial(M.decode_step, cfg=cfg),
                donate_argnames=("state",)))
            self._paged_chunk = self._mesh_dispatch(jax.jit(
                functools.partial(M.decode_chunk, cfg=cfg),
                donate_argnames=("state",)))
            self._lane_take = self._mesh_dispatch(
                jax.jit(_lane_take, donate_argnums=(0,)))
            self._lane_put = self._mesh_dispatch(
                jax.jit(_lane_put, donate_argnums=(0, 1)))
            self._lane_reset = self._mesh_dispatch(
                jax.jit(_lane_reset, donate_argnums=(0,)))
            self._page_in = self._mesh_dispatch(jax.jit(functools.partial(
                M.page_in_dense_state, page_size=page_size),
                donate_argnums=(0,)))
        self.preempt_enabled = (preempt if preempt is not None
                                else kv_backend == "paged")
        self.preemptions = 0
        self.prefix_cache = PrefixCache(max_bytes=prefix_cache_bytes,
                                        store=self.kv_store)
        self.prefix_cache.bind_metrics(self.metrics)
        if self.metrics.enabled:
            # sampled at snapshot time only — zero per-step cost
            self.metrics.gauge_fn(
                "engine_queue_depth", lambda: len(self.scheduler.pending),
                "requests pending admission")
            self.metrics.gauge_fn(
                "engine_running", lambda: len(self.scheduler.running),
                "requests occupying batch slots")
        self._sanitizer = getattr(self.kv_store, "_sanitizer", None)
        if self.kv_store is not None:
            # actionable PoolExhausted: the store can't see the cache, so
            # the engine attributes "held by prefix cache" block counts
            self.kv_store.pressure_context = self._prefix_cache_blocks
        self.prefix_block = max(1, prefix_block)
        self._policy_evicts = M.eviction_policy(cfg).evicts
        # bucketing pads the prompt; exact for attention layers (causality)
        # AND for SSM/hybrid stacks (the pad-masked scan freezes SSM state
        # at true_len) — only encoder inputs (frames) remain excluded.
        self._can_bucket = not cfg.cross_attention
        self.bucket_prefill = bucket_prefill and self._can_bucket
        self.min_bucket = max(1, min_bucket)
        self._slot_states = None            # stacked DecodeState [max_batch, ...]
        self._slot_tokens = np.zeros((max_batch,), np.int64)
        self._next_id = 0
        # prefill telemetry: dispatch count, REAL prompt tokens prefilled
        # (pad lanes of bucketed dispatches are excluded — compare
        # prefill_shapes for the padded dispatch sizes), distinct dispatch
        # shapes (buckets compile once each), prefix-reuse counters
        self.prefill_dispatches = 0
        self.prefill_tokens = 0
        self.prefill_shapes: Set[Tuple[str, int]] = set()
        self.prefix_tokens_reused = 0
        # self-speculative decoding: a draft/verify loop over a ladder-
        # compacted fork of the live tables. Constructing the decoder on
        # any backend keeps the API uniform; it only *runs* on eligible
        # paged configs and otherwise falls back to stepwise decode.
        self._spec = None
        if spec_config is not None:
            from repro.serving.speculative import SpecDecoder
            self._spec = SpecDecoder(self, spec_config)
        # compile-inclusive cold start: optionally execute the decode-side
        # executables once at construction so the first serving wave runs
        # compile-free (benchmarks report both numbers).
        self.prewarm = bool(prewarm)
        self.prewarm_prefill = bool(prewarm_prefill)
        # the warm ladders _prewarm walked (empty on cold engines) —
        # benchmarks surface these next to the compile-inclusive numbers
        self.prewarmed_chunk_widths: list = []
        self.prewarmed_prefill_buckets: list = []
        if self.prewarm and self._paged_in_model:
            self._prewarm()

    def _mesh_dispatch(self, fn):
        """Run a paged jit with this engine's pool-mesh spec installed.

        The spec is read at *trace* time by the kernel dispatcher (the
        Pallas route needs ``shard_map``; the XLA route partitions through
        GSPMD from placement alone), so the wrapper makes each engine's
        executables see exactly its own mesh — two engines in one process
        (the differential harness's sharded-vs-single-device pair) never
        leak routing into each other's traces."""
        if self._pool_mesh is None:
            return fn

        @functools.wraps(fn)
        def call(*args, **kwargs):
            with pool_mesh_lib.use_pool_mesh(self._pool_mesh):
                return fn(*args, **kwargs)
        return call

    @property
    def kv_pool_bytes_per_device(self) -> int:
        """Physical pool-plane bytes resident per device (k + v).

        Single-device serving returns the full plane footprint; under
        ``Engine(mesh=...)`` with kv-head- (or slot-) sharded planes this
        is the per-chip share — the number the sharded-serving benchmark
        asserts scales as ~1/model-axis."""
        if not self._paged_in_model or self.kv_store is None:
            return 0
        self._ensure_slot_states()
        kvp = self._slot_states.kv_pool
        total = 0
        for plane in (kvp.k, kvp.v):
            shape = plane.shape
            sharding = getattr(plane, "sharding", None)
            if sharding is not None and hasattr(sharding, "shard_shape"):
                shape = sharding.shard_shape(plane.shape)
            total += int(np.prod(shape, dtype=np.int64)) * plane.dtype.itemsize
        return total

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prefix-cache lookups that found a reusable prefix."""
        return self.prefix_cache.hit_rate

    @property
    def bytes_shared(self) -> int:
        """Physical KV bytes deduplicated by block sharing (paged backend;
        0 under the dense backend)."""
        return self.prefix_cache.bytes_shared

    @property
    def kv_bytes_in_use(self) -> int:
        """Physical bytes of live pool blocks (paged backend)."""
        return self.kv_store.bytes_in_use if self.kv_store is not None else 0

    @property
    def draft_owned_bytes(self) -> int:
        """Physical pool bytes reserved for the speculative draft view
        (0 when speculation is off or the first wave hasn't run)."""
        if self._spec is None or self.kv_store is None:
            return 0
        return self._spec.owned_blocks * self.kv_store.pool.block_bytes

    @property
    def spec_stats(self) -> Dict[str, float]:
        """Aggregate speculative-decoding telemetry: waves run, draft
        (re-)forks, stepwise fallbacks, draft tokens proposed/accepted and
        the acceptance rate."""
        s = self._spec
        if s is None:
            return {"waves": 0, "forks": 0, "fallback_steps": 0,
                    "catchup_steps": 0,
                    "proposed": 0, "accepted": 0, "acceptance_rate": 0.0}
        return {"waves": s.waves, "forks": s.forks,
                "fallback_steps": s.fallback_steps,
                "catchup_steps": s.catchup_steps,
                "proposed": s.proposed, "accepted": s.accepted,
                "acceptance_rate": s.acceptance_rate}

    def _prefix_cache_blocks(self) -> int:
        """Distinct pool blocks currently mapped by prefix-cache entries
        (PoolExhausted attribution; snapshots share blocks, so this is a
        set size, not a sum of per-entry counts)."""
        ids: Set[int] = set()
        for entry in self.prefix_cache._entries.values():
            snap = entry.snap
            if snap is None:
                continue
            if isinstance(snap, pagedlib.TableSnapshot):
                ids.update(int(b) for b in snap.block_ids().tolist())
            else:
                for leaf in snap.leaves:
                    if isinstance(leaf, pagedlib._TableSet):
                        for t in leaf.tables:
                            b = np.asarray(t.blocks)
                            ids.update(int(x) for x in b[b >= 0].tolist())
        return len(ids)

    def close(self) -> None:
        """Shut the serving state down and verify the pool drains.

        Releases every running lane's travelling references, drops parked
        preemption parcels, clears the prefix cache, then audits the pool:
        the only references left must be the lanes' permanent reserved
        ``owned`` sets (engine-lifetime allocations). A violation raises
        :class:`repro.analysis.sanitizer.SanitizerError` — with per-block
        allocation sites when ``REPRO_SANITIZE=1`` was set at engine
        construction. Dense-backend engines hold no pool state; close is
        a no-op for them."""
        if self.kv_store is None:
            return
        if self._paged_in_model:
            for slot in list(self.scheduler.running):
                self._release_lane(slot)
        for req in self.scheduler.pending_requests():
            parked = getattr(req, "_resume", None)
            if parked is None:
                continue
            parcel = parked[0]
            req._resume = None
            if isinstance(parcel, _LaneParcel):
                held, charged = parcel.held, parcel.held_charged
                if held.size:
                    if charged.size:
                        ref = np.asarray(self.kv_store.pool.ref)[held]
                        n = int(np.isin(held[ref == 1], charged).sum())
                        if n:
                            self.prefix_cache.settle(
                                n * self.kv_store.pool.block_bytes)
                    self.kv_store.release_blocks(held)
            else:
                self.kv_store.release(parcel)
        if self._spec is not None:
            self._spec.release()
        self.prefix_cache.clear()
        sanlib.check_shutdown(self)

    # ------------------------------------------------------------------ #
    # Lockstep (batch) layer
    # ------------------------------------------------------------------ #
    def _decode_and_score(self, params, state, token, next_token):
        logits, state = M.decode_step(params, self.cfg, state, token)
        lp = sampling.log_prob_of(logits, next_token[:, 0])
        return lp, logits, state

    def new_state(self, batch: int, frames=None) -> M.DecodeState:
        return M.init_decode_state(self.params, self.cfg, batch,
                                   self.budget, frames=frames)

    def prefill(self, tokens, patches=None, frames=None):
        return self._prefill(self.params, tokens=tokens, n_slots=self.budget,
                             patches=patches, frames=frames)

    def generate(self, prompt_tokens, max_new_tokens: int, *,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 patches=None, frames=None) -> np.ndarray:
        """Lockstep: prompt_tokens [b, t] -> generated [b, max_new_tokens]."""
        logits, state = self.prefill(prompt_tokens, patches=patches,
                                     frames=frames)
        key = jax.random.PRNGKey(seed)
        outs = []
        # split before first use: sampling with the unsplit root key and
        # then splitting the SAME key for later tokens reuses randomness
        # (token 0's draw correlates with the whole downstream chain)
        if temperature == 0.0:
            tok = sampling.greedy(logits)[:, None]
        else:
            key, sub = jax.random.split(key)
            tok = sampling.sample(sub, logits, temperature, top_k)[:, None]
        for i in range(max_new_tokens):
            outs.append(np.asarray(tok[:, 0]))
            logits, state = self._decode(self.params, state=state, tokens=tok)
            if temperature == 0.0:
                tok = sampling.greedy(logits)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = sampling.sample(sub, logits, temperature, top_k)[:, None]
        return np.stack(outs, axis=1)

    def score_stream(self, tokens, *, frames=None, prime: int = 1,
                     collect_every: int = 1) -> np.ndarray:
        """Teacher-forced token-by-token NLL through the decode path.

        tokens [b, T]: feeds tokens[:, i] and scores tokens[:, i+1] under the
        policy-restricted cache — the paper's language-modeling evaluation.
        Returns per-position NLL [b, T-prime].
        """
        tokens = jnp.asarray(tokens)
        b, T = tokens.shape
        state = self.new_state(b, frames=frames)
        # prime the cache with the first `prime` tokens (BOS etc.)
        nlls = []
        for i in range(T - 1):
            lp, _, state = self._decode_score(
                self.params, state, tokens[:, i:i + 1], tokens[:, i + 1:i + 2])
            if i >= prime - 1:
                nlls.append(np.asarray(-lp))
        return np.stack(nlls, axis=1)

    def score_stream_chunked(self, tokens, chunk: int = 64,
                             frames=None) -> np.ndarray:
        """Teacher-forced NLL via decode_chunk: O(budget*T), ~chunk x fewer
        dispatches than score_stream. Same streaming semantics (every
        prediction sees only the compacted cache + chunk prefix)."""
        tokens = jnp.asarray(tokens)
        b, T = tokens.shape
        # a chunk must fit in the slot buffer alongside the compacted past
        chunk = max(1, min(chunk, self.budget // 2))
        state = self.new_state(b, frames=frames)
        nll = []
        n_chunks = (T - 1) // chunk
        for ci in range(n_chunks + (1 if (T - 1) % chunk else 0)):
            s, e = ci * chunk, min((ci + 1) * chunk, T - 1)
            if e <= s:
                break
            # the ragged tail dispatches at its own size (one extra compile)
            # rather than padding: padded appends can overflow the slot
            # buffer under a non-evicting policy and corrupt live slots.
            seg = tokens[:, s:e]
            # one extra compile for the tail, by choice (see above)
            # analysis: allow(CMP001)
            logits, state = self._decode_chunk(self.params, state=state,
                                               tokens=seg)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            gold = tokens[:, s + 1:e + 1]
            g = jnp.take_along_axis(lp[:, :e - s], gold[..., None],
                                    axis=-1)[..., 0]
            nll.append(np.asarray(-g))
        return np.concatenate(nll, axis=1)

    def cache_bytes(self, state: M.DecodeState) -> int:
        """Per-layer decode-state bytes, counting every state kind: budgeted
        KV slot buffers, ring windows and SSM states alike (nothing assumes
        attention-only leaves). For in-model paged states the KV content
        lives in the pool, so table leaves are charged as their *mapped*
        physical blocks plus metadata instead of the raw int32 tables."""
        if state.kv_pool is None:
            return sum(x.size * x.dtype.itemsize
                       for x in jax.tree.leaves(state.blocks)) + \
                   sum(x.size * x.dtype.itemsize
                       for x in jax.tree.leaves(state.tail))
        kvp = state.kv_pool
        block_bytes = 2 * kvp.block_size * int(np.prod(kvp.k.shape[2:])) \
            * kvp.k.dtype.itemsize
        total = 0
        for leaf in list(state.blocks.values()) + list(state.tail.values()):
            if isinstance(leaf, (pagedlib.PagedKVCache,
                                 pagedlib.PagedRingCache)):
                total += int((np.asarray(leaf.blocks) >= 0).sum()) \
                    * block_bytes
                meta = [x for name, x in zip(leaf._fields, leaf)
                        if name not in ("blocks", "owned") and x is not None]
                total += sum(int(x.size) * x.dtype.itemsize for x in meta)
            else:
                total += sum(int(x.size) * x.dtype.itemsize
                             for x in jax.tree.leaves(leaf))
        return total

    # ------------------------------------------------------------------ #
    # Request layer (continuous batching)
    # ------------------------------------------------------------------ #
    def submit(self, prompt, max_new_tokens: int,
               sampling_params: Optional[SamplingParams] = None, *,
               priority: int = 0, deadline: Optional[float] = None,
               cache_prefix: bool = False,
               on_token: Optional[Callable[[Request, int], None]] = None
               ) -> Request:
        """Enqueue one request. prompt: [t] int tokens (1-D).

        ``priority``/``deadline`` feed the scheduler's admission policy;
        ``cache_prefix`` opts the request into the shared-prefix prompt
        cache (reuse the longest cached prefix, snapshot its own post-
        prefill state); ``on_token(request, token)`` is invoked once per
        generated token, on the tick it is sampled.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        sp = (sampling_params or SamplingParams()).validate()
        if not isinstance(priority, (int, np.integer)) \
                or isinstance(priority, bool):
            raise ValueError(f"priority must be an int, got {priority!r}")
        if deadline is not None and (
                not isinstance(deadline,
                               (int, float, np.floating, np.integer))
                or isinstance(deadline, bool) or not math.isfinite(deadline)):
            raise ValueError(
                f"deadline must be a finite number, got {deadline!r}")
        if on_token is not None and not callable(on_token):
            raise ValueError("on_token must be callable")
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens,
                      sampling=sp, request_id=self._next_id,
                      priority=int(priority), deadline=deadline,
                      cache_prefix=cache_prefix, on_token=on_token,
                      t_submit=self.clock(),
                      _key=jax.random.PRNGKey(sp.seed))
        self._next_id += 1
        self._inst.submitted.inc()
        tr = self.tracer
        if tr.enabled:
            tid = req.request_id + 1
            tr.thread_name(tid, f"req {req.request_id}")
            tr.begin(("queued", req.request_id), "queued", tid=tid,
                     prompt_len=req.prompt_len,
                     max_new_tokens=max_new_tokens)
        return self.scheduler.submit(req)

    @property
    def lane_owned_bytes(self) -> int:
        """Permanent pool bytes reserved for the batch lanes' CoW destination
        sets (in-model paged backend); constant for the engine's lifetime."""
        if not self._paged_in_model or self.kv_store is None:
            return 0
        return self._lane_owned_blocks * self.kv_store.pool.block_bytes

    def _ensure_slot_states(self):
        if self._slot_states is not None:
            return
        if self._paged_in_model:
            # the serving state takes sole ownership of the pool's K/V
            # planes (the store keeps a stub + the allocator: refcounts and
            # the free list) so the donating hot path can update them in
            # place without invalidating store-held references — and
            # without keeping a dead second copy of the system's largest
            # allocation alive.
            plane_sharding = None
            if self.mesh is not None:
                from repro.launch import sharding as shardlib
                plane_sharding = NamedSharding(
                    self.mesh, shardlib.pool_plane_spec(
                        self.mesh, self.cfg, page_size=self.page_size))
            kvp = self.kv_store.detach_planes(plane_sharding)
            allocated = [0]

            def alloc(n):
                allocated[0] += n
                return self.kv_store.alloc_blocks(n)

            self._slot_states = M.init_paged_decode_state(
                self.cfg, self.max_batch, self.budget, self.page_size,
                kvp, alloc)
            if self.mesh is not None:
                # tables/lengths/SSM leaves get their lane-axis placement
                # here; the planes already carry theirs from the detach, so
                # this device_put moves KBs of metadata, not the pool
                from repro.launch import sharding as shardlib
                self._slot_states = jax.device_put(
                    self._slot_states, shardlib.paged_state_shardings(
                        self.mesh, self.cfg, self._slot_states,
                        page_size=self.page_size, max_batch=self.max_batch))
            self._lane_owned_blocks = allocated[0]
            return
        one = self.new_state(1)
        self._slot_states = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (self.max_batch,) + x.shape).copy(), one)

    def _prewarm(self) -> None:
        """Execute the paged decode-side executables once at construction.

        The paged cold start is dominated by the first batched step/chunk
        compiles (ROADMAP: paged 4.3 vs dense 18.4 tok/s incl. compile);
        real warm dispatches here move that cost out of the first serving
        wave. The jits already key their caches on static aux only (the
        batched state's shapes are fixed at construction; slot index and
        true_len are traced), so the warm executables are exactly the ones
        live traffic hits. The garbage tokens the warm step appends are
        harmless: every lane is ``_lane_reset`` at admission, and inactive
        lanes are never read.

        With ``prewarm_prefill`` (default) and bucketed prefill, the
        prefill side warms too: bucketing makes prompt-side shapes
        enumerable (one executable per power-of-two bucket, traced
        true_len), so the engine can walk the ladder up front instead of
        paying one compile per distinct bucket inside wave 1 — previously
        the dominant residual cold-start cost (paged ~4.7 vs dense ~22.5
        tok/s compile-inclusive; ``benchmarks/throughput.py`` reports the
        delta). Unbucketed engines still leave prefill cold — their shapes
        depend on prompt lengths the engine cannot know yet.
        """
        self._ensure_slot_states()
        zero = jnp.asarray(0, jnp.int32)
        # lane splice chain (admission path)
        rest, sub = self._lane_take(self._slot_states, zero)
        sub = self._lane_reset(sub)
        # chunk-prefill executables: the greedy splitter emits power-of-two
        # widths up to the batch-1 cap, so warm each one (unbucketed
        # engines dispatch at the cap width only). The lane resets between
        # widths so occupancy restarts from zero each time.
        cap = max(1, self.budget // 2)
        if self.bucket_prefill:
            # the greedy splitter emits EVERY power of two down to 1 for
            # ragged tails (rem=13 -> 8, 4, 1), not just widths >=
            # min_bucket — warm the full ladder or each sub-min_bucket
            # tail width compiles inside wave 1
            top = 1 << (cap.bit_length() - 1)
            widths, w = [], 1
            while w <= top:
                widths.append(w)
                w *= 2
        else:
            widths = [cap]
        self.prewarmed_chunk_widths = list(widths)
        for w in widths:
            # deliberate warm ladder: one dispatch per width the
            # splitter can emit  # analysis: allow(CMP001)
            _, sub = self._paged_chunk(self.params, state=sub,
                                       tokens=jnp.zeros((1, w), jnp.int32))
            sub = self._lane_reset(sub)
        if self.prewarm_prefill and self.bucket_prefill:
            # prefill bucket ladder: every bucket a prompt up to ~2x the
            # slot budget would land in (longer prompts are compacted down
            # to the budget anyway, and their buckets clamp at max_position)
            top_b = self._bucket_len(min(int(self.cfg.max_position),
                                         max(2 * self.budget,
                                             self.min_bucket)))
            dense, b = None, max(1, self.min_bucket)
            while b <= top_b:
                self.prewarmed_prefill_buckets.append(b)
                # deliberate warm ladder  # analysis: allow(CMP001)
                _, dense = self._prefill(
                    self.params, tokens=jnp.zeros((1, b), jnp.int32),
                    n_slots=self.budget,
                    true_len=jnp.asarray(1, jnp.int32))
                b *= 2
            if dense is not None:
                # page-in executable: cold-prefill admission splices the
                # dense prefill state into the reserved pool lane
                sub = self._page_in(sub, dense)
        self._slot_states = self._lane_put(rest, sub, zero)
        # the batched decode step (the hot path)
        _, self._slot_states = self._paged_step(
            self.params, state=self._slot_states,
            tokens=jnp.zeros((self.max_batch, 1), jnp.int32))
        if self._spec is not None and self._spec.enabled:
            # fork / draft-step / verify-chunk / rollback executables (the
            # draft state is trimmed, so its step and rollback compile
            # separately from the live-shaped ones); the k+1-wide live
            # rollback also erases the warm chunk's garbage appends
            sp = self._spec
            state = self._slot_states
            sp.ensure_reserved(state)
            planes = state.kv_pool
            live = state._replace(kv_pool=None)
            draft = sp._fork(live, planes, dict(sp._owned))
            _, draft = self._paged_step(
                self.params, state=draft,
                tokens=jnp.zeros((self.max_batch, 1), jnp.int32))
            live = live._replace(kv_pool=draft.kv_pool)
            draft = draft._replace(kv_pool=None)
            sp._rollback(draft, jnp.ones((self.max_batch,), jnp.int32))
            _, live = self._paged_chunk(
                self.params, state=live,
                tokens=jnp.zeros((self.max_batch, sp.k + 1), jnp.int32))
            self._slot_states = sp._rollback(
                live, jnp.full((self.max_batch,), sp.k + 1, jnp.int32))

    # -- prefill paths (cold / bucketed / prefix-reusing) ---------------- #
    def _bucket_len(self, n: int) -> int:
        """Smallest power-of-two bucket (>= min_bucket) covering ``n``,
        clamped at the model's max sequence length.

        Unbounded doubling would pad a prompt just over a large bucket far
        past ``cfg.max_position`` (dead compute, and a padded dispatch the
        model was never meant to see). Buckets clamp at the model max, and
        prompts longer than it dispatch at their exact length — oversized
        prompts are rare enough that a per-length compile beats padding."""
        cap = max(1, int(self.cfg.max_position))
        if n > cap:
            return n                    # exact-length dispatch
        b = max(1, self.min_bucket)
        while b < n:
            b *= 2
        return min(b, cap)

    def _note_prefill(self, kind: str, shape: int, n_tokens: int) -> None:
        self.prefill_dispatches += 1
        self.prefill_tokens += n_tokens
        self.prefill_shapes.add((kind, shape))
        self._inst.prefill_dispatches.inc()
        self._inst.prefill_computed.inc(n_tokens)

    def _cold_prefill(self, prompt: np.ndarray):
        """Full-prompt prefill; bucketed (padded to a power-of-two length,
        traced true_len) when enabled, so mixed-length traffic shares one
        executable per bucket instead of compiling per distinct length."""
        t = int(prompt.shape[0])
        if self.bucket_prefill:
            b = self._bucket_len(t)
            padded = np.zeros((b,), np.int32)
            padded[:t] = prompt
            logits, state = self._prefill(
                self.params, tokens=jnp.asarray(padded)[None],
                n_slots=self.budget, true_len=jnp.asarray(t, jnp.int32))
            self._note_prefill("prefill", b, t)
        else:
            logits, state = self.prefill(jnp.asarray(prompt)[None])
            self._note_prefill("prefill", t, t)
        return logits, state

    def _chunk_prefill(self, state: M.DecodeState, suffix: np.ndarray):
        """Prefill only ``suffix`` on top of a restored prefix snapshot via
        decode_chunk. Chunks are capped at budget // 2 (a chunk must fit in
        the slot buffer alongside the compacted past); with bucketing the
        suffix is split greedily into power-of-two chunks so suffix lengths
        share executables too."""
        cap = max(1, self.budget // 2)
        rem, off = int(suffix.shape[0]), 0
        logits = None
        # paged sub-states go through the donating chunk jit (the pool
        # planes update in place); dense states must NOT be donated — a
        # prefix-cache hit hands us the cached pytree by reference.
        chunk_fn = (self._paged_chunk if state.kv_pool is not None
                    else self._decode_chunk)
        while rem:
            if self.bucket_prefill:
                size = 1 << (min(rem, cap).bit_length() - 1)
            else:
                size = min(rem, cap)
            seg = jnp.asarray(suffix[off:off + size])[None]
            # bucketing bounds the executable set to the power-of-two
            # ladder, which _prewarm walks  # analysis: allow(CMP001)
            lseq, state = chunk_fn(self.params, state=state, tokens=seg)
            logits = lseq[:, -1]
            self._note_prefill("chunk", size, size)
            off, rem = off + size, rem - size
        return logits, state

    def _prefill_request(self, req: Request):
        """Prefill one admitted request. Requests that opted out take the
        dense one-dispatch prefill; ``cache_prefix`` requests restore the
        longest cached prefix snapshot and stream the remainder through
        decode_chunk in ``prefix_block``-aligned chunks, snapshotting at
        every block boundary — so two prompts sharing a system prefix hit
        each other's block snapshots even when neither is a full prefix of
        the other.

        Non-evicting policies (``full``) cannot stream a prompt longer than
        the slot buffer through decode_chunk (maybe_compact is a no-op, so
        the append would silently clobber live slots); such requests fall
        back to dense prefill, whose compact_to_budget hard-truncates."""
        if not req.cache_prefix or (not self._policy_evicts
                                    and req.prompt_len > self.budget):
            return self._cold_prefill(req.prompt)
        entry = self.prefix_cache.lookup(req.prompt)
        if entry is not None:
            self.prefix_tokens_reused += entry.length
            self._inst.prefill_reused.inc(entry.length)
            if entry.length == req.prompt_len:
                # zero prefill compute; paged entries gather a fresh
                # working state, the stored blocks stay shared
                return self.prefix_cache.restore(entry)
        start = entry.length if entry is not None else 0
        if entry is not None:
            _, state = self.prefix_cache.restore(entry)
        else:
            state = self.new_state(1)
        prompt, t = req.prompt, req.prompt_len
        block = self.prefix_block
        logits, off = None, start
        parent = entry   # each snapshot extends the previous one: under the
        #                  paged backend the store shares their whole-block
        #                  prefix instead of copying it
        while off < t:
            nxt = min(t, (off // block + 1) * block)
            logits, state = self._chunk_prefill(state, prompt[off:nxt])
            off = nxt
            new_entry = self.prefix_cache.insert(prompt[:off], state, logits,
                                                 parent=parent)
            if new_entry is not None:
                parent = new_entry
        return logits, state

    # -- in-model paged prefill / snapshot / splice ----------------------- #
    def _lane_layers(self, sub: M.DecodeState):
        """Canonical (section, key, leaf) walk of a sub-state's paged layer
        caches — the order snapshots and parcels serialize tables in."""
        for key in sorted(sub.blocks):
            yield "blocks", key, sub.blocks[key]
        for key in sorted(sub.tail):
            yield "tail", key, sub.tail[key]

    def _set_lane_tables(self, sub: M.DecodeState,
                         snap: pagedlib.TableSnapshot) -> M.DecodeState:
        """Point a lane's tables at a snapshot's blocks (pure splice — no
        refcount bookkeeping; callers manage holds). Every write through the
        spliced table copy-on-writes into the lane's reserved blocks because
        the spliced ids are not in its ``owned`` set. Ring layers splice
        their residue-class tables the same way; SSM layers copy their
        (small) dense state back verbatim.

        All per-layer fields are packed into one flat host staging buffer
        per dtype and shipped in a single host->device transfer each — the
        previous per-layer-per-field uploads were the per-admission host
        round-trips that kept hybrid paged splices behind dense. The
        per-field views below are device-side static slices."""
        parts: Dict[str, List[np.ndarray]] = {}
        sizes: Dict[str, int] = {}

        def stage(arr, dtype):
            d = np.dtype(dtype)
            a = np.asarray(arr).reshape(-1).astype(d, copy=False)
            name = d.name
            start = sizes.get(name, 0)
            parts.setdefault(name, []).append(a)
            sizes[name] = start + a.size
            return name, start, a.size

        plan = []
        for section, key, leaf in self._lane_layers(sub):
            layer = snap.tables[section][key]
            if isinstance(leaf, pagedlib.PagedKVCache):
                fields = {"blocks": stage(layer["blocks"], np.int32),
                          "pos": stage(layer["pos"], np.int32),
                          "length": stage(layer["length"], np.int32)}
                if leaf.scores is not None:
                    fields["scores"] = stage(layer["scores"], np.float32)
            elif isinstance(leaf, pagedlib.PagedRingCache):
                fields = {"blocks": stage(layer["blocks"], np.int32),
                          "pos": stage(layer["pos"], np.int32),
                          "next_pos": stage(layer["next_pos"], np.int32)}
            else:                                   # SSM state
                fields = {"conv": stage(layer["conv"], leaf.conv.dtype),
                          "ssm": stage(layer["ssm"], leaf.ssm.dtype)}
            plan.append((section, key, leaf, fields))
        pos_h = stage(snap.state_pos, np.int32)
        staged = {name: jnp.asarray(np.concatenate(bufs))
                  for name, bufs in parts.items()}

        def view(handle, shape):
            name, start, size = handle
            return staged[name][start:start + size].reshape(shape)

        sections = {"blocks": dict(sub.blocks), "tail": dict(sub.tail)}
        for section, key, leaf, fields in plan:
            if isinstance(leaf, pagedlib.PagedKVCache):
                sections[section][key] = leaf._replace(
                    blocks=view(fields["blocks"], leaf.blocks.shape),
                    pos=view(fields["pos"], leaf.pos.shape),
                    length=view(fields["length"], leaf.length.shape),
                    scores=None if leaf.scores is None
                    else view(fields["scores"], leaf.scores.shape))
            elif isinstance(leaf, pagedlib.PagedRingCache):
                sections[section][key] = leaf._replace(
                    blocks=view(fields["blocks"], leaf.blocks.shape),
                    pos=view(fields["pos"], leaf.pos.shape),
                    next_pos=view(fields["next_pos"], leaf.next_pos.shape))
            else:                                   # SSM state
                sections[section][key] = MambaState(
                    conv=view(fields["conv"], leaf.conv.shape),
                    ssm=view(fields["ssm"], leaf.ssm.shape))
        return sub._replace(pos=view(pos_h, sub.pos.shape),
                            blocks=sections["blocks"],
                            tail=sections["tail"])

    def _fork_lane_tables(self, sub: M.DecodeState, slot: int,
                          retain: bool = True):
        """Refcount-fork a lane's live tables (zero K/V copies).

        Mapped blocks the lane *owns* are handed to the fork: the fork (and
        the lane, which keeps reading them) each hold a reference, and the
        lane's reserved set is refilled with fresh blocks so its next write
        copy-on-writes away from the forked content. Returns
        (TableSnapshot, newly_owned_block_bytes, updated sub) or None when
        the pool cannot supply replacements even after evicting every
        prefix-cache entry.

        ``retain=False`` (preemption parcels): the fork takes no references
        of its own — the request's existing holds travel with the parcel
        instead, so discarding the parcel's snapshot needs no release.

        Ring layers fork exactly like KV layers (their residue-class tables
        map pool blocks too); SSM layers have no blocks to fork — their
        whole per-lane state is copied dense into the snapshot and charged
        as ``dense_bytes`` (skipping it would under-charge hybrid
        snapshots and let the LRU evict them late).
        """
        plan = []
        n_swap = 0
        for section, key, leaf in self._lane_layers(sub):
            if isinstance(leaf, MambaState):
                plan.append((section, key, leaf, None, None, None))
                continue
            blocks = np.asarray(leaf.blocks)
            owned = np.asarray(leaf.owned)
            swap = (blocks >= 0) & (blocks == owned)
            plan.append((section, key, leaf, blocks, owned, swap))
            n_swap += int(swap.sum())
        while True:
            try:
                fresh = self.kv_store.alloc_blocks(n_swap)
                break
            except pagedlib.PoolExhausted:
                if not self.prefix_cache.evict_lru():
                    return None
        fi = 0
        tabs: Dict[str, Dict] = {"blocks": {}, "tail": {}}
        taken: List[np.ndarray] = []
        mapped_all: List[np.ndarray] = []
        sections = {"blocks": dict(sub.blocks), "tail": dict(sub.tail)}
        dense_bytes = int(np.asarray(sub.pos).nbytes)
        for section, key, leaf, blocks, owned, swap in plan:
            if isinstance(leaf, MambaState):
                layer = {"kind": "ssm",
                         "conv": np.asarray(leaf.conv).copy(),
                         "ssm": np.asarray(leaf.ssm).copy()}
                dense_bytes += layer["conv"].nbytes + layer["ssm"].nbytes
                tabs[section][key] = layer
                continue
            k = int(swap.sum())
            new_owned = owned.copy()
            new_owned[swap] = fresh[fi:fi + k]
            fi += k
            taken.append(blocks[swap].astype(np.int64).reshape(-1))
            mapped_all.append(blocks[blocks >= 0].astype(np.int64).reshape(-1))
            if isinstance(leaf, pagedlib.PagedRingCache):
                layer = {"kind": "ring", "blocks": blocks.copy(),
                         "pos": np.asarray(leaf.pos).copy(),
                         "next_pos": np.asarray(leaf.next_pos).copy()}
            else:
                layer = {"kind": "kv", "blocks": blocks.copy(),
                         "pos": np.asarray(leaf.pos).copy(),
                         "length": np.asarray(leaf.length).copy(),
                         "scores": None if leaf.scores is None
                         else np.asarray(leaf.scores).copy()}
            dense_bytes += sum(a.nbytes for kk, a in layer.items()
                               if kk != "kind" and a is not None)
            tabs[section][key] = layer
            sections[section][key] = leaf._replace(
                owned=jnp.asarray(new_owned, jnp.int32))
        # the fork takes one reference per mapped block; the lane's original
        # hold on the swapped blocks converts to a shared hold (released at
        # retirement), so evicting the snapshot can never free blocks a
        # RUNNING lane still reads.
        if retain:
            self.kv_store.retain_blocks(
                np.concatenate(mapped_all) if mapped_all
                else np.zeros(0, np.int64))
        taken_ids = np.concatenate(taken) if taken else np.zeros(0, np.int64)
        self._lane_shared[slot] = np.concatenate(
            [self._lane_shared[slot], taken_ids])
        snap = pagedlib.TableSnapshot(
            tables=tabs, state_pos=np.asarray(sub.pos).copy(),
            dense_bytes=dense_bytes)
        owned_bytes = n_swap * self.kv_store.pool.block_bytes
        sub = sub._replace(blocks=sections["blocks"], tail=sections["tail"])
        return snap, owned_bytes, sub, taken_ids

    def _release_lane(self, slot: int) -> None:
        """Drop every pool reference the retiring lane's request held, and
        settle the prefix cache's byte charge for any *charged* block whose
        last reference the lane held (its entry was evicted mid-run: the
        drop freed nothing then, so the charge waited for this moment —
        without settling, the effective LRU budget would shrink forever).
        A block still held by any entry has refcount >= 2 here and is
        excluded, so the attribution is exact (modulo the rare
        preempt-then-snapshot lineage, where settle's floor bounds it)."""
        ids = self._lane_shared[slot]
        if ids.size:
            charged = self._lane_charged[slot]
            if charged.size:
                ref = np.asarray(self.kv_store.pool.ref)[ids]
                freeing = ids[ref == 1]
                n = int(np.isin(freeing, charged).sum())
                if n:
                    self.prefix_cache.settle(
                        n * self.kv_store.pool.block_bytes)
            self.kv_store.release_blocks(ids)
        self._lane_shared[slot] = np.zeros((0,), np.int64)
        self._lane_charged[slot] = np.zeros((0,), np.int64)

    def _prefill_request_paged(self, req: Request, slot: int):
        """In-model paged prefill: the request's KV goes straight into the
        pool through its lane's block tables and never leaves.

        Prefix hits splice the snapshot's shared blocks directly into the
        live tables (no gather-to-dense working copy); the remainder streams
        through the *paged* ``decode_chunk``; block-boundary snapshots are
        refcount forks. Cold (or non-evicting over-budget) prompts take the
        dense one-dispatch prefill and scatter into the lane's reserved
        blocks once. Chunk boundaries are identical to the dense backend's,
        which is what keeps the two backends token-for-token equal.
        """
        self._slot_states, sub = self._lane_take(
            self._slot_states, jnp.asarray(slot, jnp.int32))
        sub = self._lane_reset(sub)
        if not req.cache_prefix or (not self._policy_evicts
                                    and req.prompt_len > self.budget):
            logits, dense_state = self._cold_prefill(req.prompt)
            return logits, self._page_in(sub, dense_state)
        entry = self.prefix_cache.lookup(req.prompt)
        start, logits = 0, None
        if entry is not None:
            self.prefix_tokens_reused += entry.length
            self._inst.prefill_reused.inc(entry.length)
            ids = entry.snap.block_ids()
            self.kv_store.retain_blocks(ids)
            self._lane_shared[slot] = np.concatenate(
                [self._lane_shared[slot], ids])
            # every snapshot-mapped block is charged to some entry along
            # the lineage -> settle-eligible when the lane outlives them
            self._lane_charged[slot] = np.concatenate(
                [self._lane_charged[slot], ids])
            sub = self._set_lane_tables(sub, entry.snap)
            logits, start = entry.logits, entry.length
            if entry.length == req.prompt_len:
                return logits, sub
        prompt, t = req.prompt, req.prompt_len
        block = self.prefix_block
        off = start
        while off < t:
            nxt = min(t, (off // block + 1) * block)
            logits, sub = self._chunk_prefill(sub, prompt[off:nxt])
            off = nxt
            fork = self._fork_lane_tables(sub, slot)
            if fork is not None:
                snap, owned_bytes, sub, taken = fork
                made = self.prefix_cache.insert_snapshot(prompt[:off], snap,
                                                         logits, owned_bytes)
                if made is not None and taken.size:
                    # the blocks this entry took over are now cache-charged
                    self._lane_charged[slot] = np.concatenate(
                        [self._lane_charged[slot], taken])
        return logits, sub

    def _sample_next(self, req: Request, logits_row) -> int:
        """Sample one token for a request from its [1, V] logits row."""
        sp = req.sampling
        if sp.temperature == 0.0:
            tok = sampling.greedy(logits_row)
        else:
            req._key, sub = jax.random.split(req._key)
            tok = sampling.sample(sub, logits_row, sp.temperature, sp.top_k)
        return int(tok[0])

    def _record(self, req: Request, tok: int) -> None:
        if req.error is not None:
            # already FAILED (a spec wave can record several tokens per
            # lane per tick): drop everything after the failing token so
            # the stream ends where the callback broke
            return
        req.output_tokens.append(tok)
        self._slot_tokens[req.slot] = tok
        if req.t_first is None:
            req.t_first = self.clock()
            self._inst.ttft.observe(req.t_first - req.t_submit)
        self._inst.tokens.inc()
        if req.on_token is not None:
            try:
                req.on_token(req, tok)
            except Exception as e:
                # a raising user callback must not unwind mid-step() (the
                # other lanes' bookkeeping would be lost and the slot would
                # leak): mark the request FAILED and let the normal retire
                # path reclaim the slot this same tick.
                req.error = e
                self._inst.callback_errors.inc()
                self.tracer.instant("callback_error",
                                    tid=req.request_id + 1,
                                    error=repr(e))

    def _probe_lengths(self) -> Optional[np.ndarray]:
        """Per-lane occupied-slot count of one representative budgeted-KV
        layer. Ladder compaction fires *inside* the traced decode step
        (``lax.cond``), invisible to host code — so the engine detects it
        by watching occupancy across an append: a lane that appended a
        token but did not grow must have compacted. Only called when
        metrics are enabled (two small D2H reads per tick); returns None
        for stacks with no such layer (pure-SSM / ring-only: nothing
        ladder-compacts)."""
        state = self._slot_states
        for sec in (state.tail, state.blocks):
            for key in sorted(sec):
                leaf = sec[key]
                length = getattr(leaf, "length", None)
                if length is None:
                    continue
                arr = np.asarray(length)
                if arr.size % self.max_batch:
                    continue
                if isinstance(leaf, pagedlib.PagedKVCache):
                    # paged layer stacks put the lane axis last
                    return arr.reshape(-1, self.max_batch)[0]
                # dense engine states broadcast the lane axis first
                return arr.reshape(self.max_batch, -1)[:, 0]
        return None

    # -- preemption (paged backend) -------------------------------------- #
    def preempt(self, slot: int) -> Optional[Request]:
        """Swap a RUNNING request out of its batch slot.

        In-model paged mode this is a pure **table handoff**: the request
        parks its block tables (plus tiny metadata) in a parcel — its KV
        never leaves the pool, no bytes are copied — and the lane's reserved
        set is refilled so the next occupant's writes cannot touch the
        parked blocks. The store-backed fallback pages the dense slot state
        into the pool instead. Either way the request re-enters the pending
        heap under its admission key and resumes token-for-token exactly.
        Returns None (and leaves the request running) when the pool cannot
        supply the handoff even after evicting every prefix-cache entry."""
        if self.kv_store is None:
            raise RuntimeError("preemption requires kv_backend='paged' "
                               "(a dense slot state has no pool to park in)")
        req = self.scheduler.running[slot]
        if self._paged_in_model:
            rest, sub = self._lane_take(self._slot_states,
                                        jnp.asarray(slot, jnp.int32))
            self._slot_states = rest
            fork = self._fork_lane_tables(sub, slot, retain=False)
            if fork is None:
                # re-attach the lane untouched; the request keeps running
                self._slot_states = self._lane_put(
                    self._slot_states, sub, jnp.asarray(slot, jnp.int32))
                return None
            snap, _, sub, _ = fork
            # the fork's holds AND the lane's shared holds all travel with
            # the parcel; the lane starts its next occupancy clean.
            held = self._lane_shared[slot]
            held_charged = self._lane_charged[slot]
            self._lane_shared[slot] = np.zeros((0,), np.int64)
            self._lane_charged[slot] = np.zeros((0,), np.int64)
            self._slot_states = self._lane_put(
                self._slot_states, sub, jnp.asarray(slot, jnp.int32))
            req._resume = (_LaneParcel(snap=snap, held=held,
                                       held_charged=held_charged),
                           int(self._slot_tokens[slot]))
        else:
            one = jax.tree.map(lambda x: x[slot], self._slot_states)
            while True:
                try:
                    snap, _ = self.kv_store.put(one)
                    break
                except pagedlib.PoolExhausted:
                    # prefix snapshots are recomputable; a live request
                    # is not
                    if not self.prefix_cache.evict_lru():
                        return None
            req._resume = (snap, int(self._slot_tokens[slot]))
        self.scheduler.requeue(slot)
        self.preemptions += 1
        req.n_preempts += 1
        self._inst.preempted.inc()
        tr = self.tracer
        if tr.enabled:
            tid = req.request_id + 1
            tr.end(("running", req.request_id), outcome="preempted",
                   tokens=len(req.output_tokens))
            tr.instant("preempt", tid=tid, slot=slot)
            tr.begin(("queued", req.request_id), "queued", tid=tid,
                     resumption=True)
        return req

    def _maybe_preempt(self) -> None:
        """Deadline-pressure preemption: while a pending request outranks a
        RUNNING one under the admission policy and no slot is free, swap the
        worst-ranked running request out to the pool. Running requests are
        compared at sequence -1, so a pending request must *strictly* beat
        them — FIFO never preempts, and ties always favour the incumbent."""
        if not self.preempt_enabled or self.kv_store is None \
                or self._slot_states is None:
            return
        sch = self.scheduler
        while sch.pending and sch.n_free == 0 and sch.running:
            best_pending = sch.pending[0][0]       # heap root: O(1)
            worst_slot, worst_key = max(
                ((s, sch.admission.key(r, -1))
                 for s, r in sch.running.items()),
                key=lambda sk: sk[1])
            if not best_pending < worst_key:
                break
            if self.preempt(worst_slot) is None:
                break

    def step(self) -> List[Request]:
        """One engine tick. Returns the requests that finished this tick.

        1. Admit pending requests (admission-policy order) into free slots:
           per-request prefill — reusing the longest cached prompt prefix
           and/or padding to a power-of-two bucket when enabled — sample
           the first token, splice the request's decode state into its
           slot.
        2. vmap-decode every slot one step (inactive slots are masked out of
           all bookkeeping — their lanes compute but are never read).
        3. Per-request sampling of the next token; requests reaching
           ``max_new_tokens`` retire and free their slot immediately.
        """
        self._ensure_slot_states()
        self._maybe_preempt()
        finished: List[Request] = []
        self._tick += 1
        self._inst.steps.inc()

        def retire(slot):
            if self._paged_in_model:
                self._release_lane(slot)
            req = self.scheduler.retire(slot)
            if req.error is not None:
                req.status = FAILED
            req.t_finish = self.clock()
            inst = self._inst
            (inst.retired_failed if req.error is not None
             else inst.retired_finished).inc()
            n = len(req.output_tokens)
            if n >= 2 and req.t_first is not None:
                inst.tpot.observe((req.t_finish - req.t_first) / (n - 1))
            if req.deadline is not None:
                slack = req.deadline - req.t_finish
                inst.deadline_slack.observe(slack)
                (inst.deadline_met if slack >= 0
                 else inst.deadline_missed).inc()
            if self.tracer.enabled:
                self.tracer.end(("running", req.request_id),
                                outcome=req.status, tokens=n)
            return req

        for slot, req in self.scheduler.admit():
            now = self.clock()
            self._inst.admitted.inc()
            resuming = req._resume is not None
            if resuming:
                self._inst.resumed.inc()
            elif req.t_admit is None:
                req.t_admit = now
                self._inst.queue_wait.observe(now - req.t_submit)
            if self.tracer.enabled:
                self.tracer.end(("queued", req.request_id), slot=slot)
                self.tracer.begin(("running", req.request_id), "running",
                                  tid=req.request_id + 1, slot=slot,
                                  resumed=resuming)
            if self._spec is not None:
                # a prefill/resume rewrites this lane's tables: the
                # persistent draft view no longer mirrors the live lanes
                self._spec.invalidate()
            if req._resume is not None:
                # preempted request: continue exactly where it stopped (the
                # last sampled token re-enters the batched decode below)
                parked, tok = req._resume
                req._resume = None
                if self._paged_in_model:
                    # table handoff: point the lane at the parcel's blocks
                    # (every write will CoW into the lane's reserved set)
                    # and move the parcel's pool holds onto the lane
                    self._slot_states, sub = self._lane_take(
                        self._slot_states, jnp.asarray(slot, jnp.int32))
                    sub = self._set_lane_tables(sub, parked.snap)
                    self._lane_shared[slot] = np.concatenate(
                        [self._lane_shared[slot], parked.held])
                    self._lane_charged[slot] = np.concatenate(
                        [self._lane_charged[slot], parked.held_charged])
                    self._slot_states = self._lane_put(
                        self._slot_states, sub, jnp.asarray(slot, jnp.int32))
                else:
                    state1 = self.kv_store.get(parked)
                    self.kv_store.release(parked)
                    self._slot_states = self._splice(
                        self._slot_states, state1,
                        jnp.asarray(slot, jnp.int32))
                self._slot_tokens[slot] = tok
                continue
            with self.tracer.span("prefill", tid=0,
                                  request_id=req.request_id, slot=slot,
                                  prompt_len=req.prompt_len):
                if self._paged_in_model:
                    logits, sub = self._prefill_request_paged(req, slot)
                    self._slot_states = self._lane_put(
                        self._slot_states, sub, jnp.asarray(slot, jnp.int32))
                else:
                    logits, state1 = self._prefill_request(req)
                    self._slot_states = self._splice(
                        self._slot_states, state1,
                        jnp.asarray(slot, jnp.int32))
            self._record(req, self._sample_next(req, logits))
            if req.done:
                finished.append(retire(slot))

        if self.scheduler.running:
            spec_done = self._spec.wave() if self._spec is not None else None
            if spec_done is not None:
                # speculative wave: tokens were emitted and recorded inside
                # the wave (up to k+1 per lane); retire what finished.
                for slot in spec_done:
                    finished.append(retire(slot))
            else:
                # occupancy probe before/after the append (compaction
                # detection); a spec wave appends k+1 and rolls back, so
                # only this stepwise branch probes. Reads complete before
                # the donating dispatch consumes the state.
                probe = (self._probe_lengths() if self.metrics.enabled
                         else None)
                lanes = sorted(self.scheduler.running)
                with self.tracer.span("decode", tid=0, tick=self._tick,
                                      lanes=len(lanes)):
                    if self._paged_in_model:
                        # ONE batched paged decode step — the pool is
                        # shared across lanes, so the slot axis is real
                        # batch, not a vmap; each lane advances on its own
                        # pos/length clock.
                        toks = jnp.asarray(self._slot_tokens,
                                           jnp.int32)[:, None]
                        if self._spec is not None:
                            # stepwise tick with spec on: the persistent
                            # draft falls one feed behind the live lanes;
                            # record the feed so the next wave replays it
                            # instead of re-forking (note_stepwise copies)
                            self._spec.note_stepwise(self._slot_tokens)
                        logits, self._slot_states = self._paged_step(
                            self.params, state=self._slot_states,
                            tokens=toks)
                        logits = np.asarray(logits)    # [max_batch, V]
                    else:
                        toks = jnp.asarray(self._slot_tokens,
                                           jnp.int32)[:, None, None]
                        logits, self._slot_states = self._slot_step(
                            self.params, self._slot_states, toks)
                        logits = np.asarray(logits)    # [max_batch, 1, V]
                self._inst.decode_dispatches.inc()
                if probe is not None:
                    after = self._probe_lengths()
                    for slot in lanes:
                        if after[slot] <= probe[slot]:
                            self._inst.compactions.inc()
                            self.tracer.instant(
                                "compaction", tid=0, slot=slot,
                                occupancy=int(after[slot]))
                for slot in sorted(self.scheduler.running):
                    req = self.scheduler.running[slot]
                    self._record(req,
                                 self._sample_next(req,
                                                   logits[slot].reshape(1, -1)))
                    if req.done:
                        finished.append(retire(slot))
        if self._sanitizer is not None and self._paged_in_model:
            sanlib.check_lanes(self)
        return finished

    def run(self) -> List[Request]:
        """Drive :meth:`step` until the queue drains; returns the finished
        requests in submission order."""
        done: List[Request] = []
        while self.scheduler.has_work:
            done.extend(self.step())
        return sorted(done, key=lambda r: r.request_id)
