"""Shared-prefix prompt cache: snapshot post-prefill decode states by prompt.

A million-user deployment re-prefills the same system prompt thousands of
times; the LaCache promise is to never recompute what the ladder already
holds. :class:`PrefixCache` extends that promise *across requests*: after a
request opts in (``Engine.submit(..., cache_prefix=True)``), its
:class:`~repro.models.model.DecodeState` (every ``KVCache`` / ring / SSM
pytree leaf, batch = 1) is snapshotted under a hash of its prompt tokens —
at the full prompt *and* at every ``Engine.prefix_block`` boundary along
the way. A later request whose prompt shares a cached prefix restores the
longest matching snapshot and prefills only the remainder through
``decode_chunk``; the block-boundary snapshots mean two prompts sharing a
system prefix hit each other even when neither is a full prefix of the
other.

Two storage backends:

* **dense** (default, ``store=None``): each entry holds the decode-state
  pytree by reference — snapshots that extend one another still occupy
  independent buffers.
* **paged** (``store=``:class:`repro.core.paged.PagedStateStore`): entries
  hold *block tables* into the global physical pool. Snapshots along one
  prompt's lineage physically share their whole-block prefix (refcounts,
  copy-on-write), so N block-boundary snapshots of one long prompt cost
  ~one prompt of KV instead of N. The LRU byte budget then charges each
  entry only its **uniquely-owned** bytes (newly allocated blocks + dense
  non-KV leaves) — charging full copies would evict shared-heavy entries
  that cost almost nothing; ``bytes_shared`` exposes the savings. Evicting
  an entry uncharges only the bytes that actually leave residency (blocks
  kept alive by a descendant's reference transfer their charge to the
  survivors), so the budget bounds resident pool bytes, not a stale
  insert-time estimate. When the physical pool itself runs out of free
  blocks, least-recently-used entries are evicted until the new snapshot
  fits.

Correctness notes:

* Snapshots are position-exact even after compaction: each ``KVCache``
  stores the absolute token position per slot and ``DecodeState.pos`` is
  the absolute next position, so continuing from a snapshot is
  indistinguishable from having decoded through it.
* JAX arrays are immutable and the engine's donating dispatches never
  donate a snapshot, so dense entries are shared by reference and paged
  entries gather fresh working copies — a hit never mutates the cache.
* Lookup is longest-match: hashes of every cached length are probed from
  the longest candidate down, and the stored tokens are compared on a hash
  hit, so a digest collision can never splice the wrong state.

Eviction is LRU under a byte budget (``max_bytes``): both ``lookup`` hits
and ``insert`` refresh recency; inserting past the budget evicts the least
recently used entries first. A single entry larger than the whole budget is
refused rather than thrashing the cache.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Optional

import jax
import numpy as np

from repro.core.paged import PagedStateStore, PoolExhausted
from repro.obs.metrics import NULL_INSTRUMENT


def _digest(tokens: np.ndarray) -> bytes:
    return hashlib.sha1(
        np.ascontiguousarray(tokens, np.int32).tobytes()).digest()


def tree_bytes(tree) -> int:
    """Total bytes of every array leaf in a pytree."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


@dataclasses.dataclass(eq=False)
class PrefixEntry:
    """One cached prefix: the tokens it covers, the batch-1 decode state
    snapshot positioned just past them (dense pytree *or* a paged-store
    snapshot of block tables), and the last-token logits (so an exact-match
    request can sample its first token with zero compute)."""

    tokens: np.ndarray          # [length] int32
    state: Any                  # DecodeState (dense backend) or None (paged)
    logits: Any                 # [1, V] logits of tokens[-1]
    nbytes: int                 # uniquely-owned bytes (see module docstring)
    snap: Any = None            # PagedSnapshot (paged backend) or None

    @property
    def length(self) -> int:
        return int(self.tokens.shape[0])


class PrefixCache:
    """LRU map from token-prefix hashes to decode-state snapshots."""

    def __init__(self, max_bytes: int = 256 << 20,
                 store: Optional[PagedStateStore] = None):
        if max_bytes < 1:
            raise ValueError("prefix cache needs a positive byte budget")
        self.max_bytes = int(max_bytes)
        self.store = store
        self._entries: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self._len_count: dict = {}     # distinct entry lengths -> #entries
        self._nbytes = 0
        self.peak_bytes = 0
        self.lookups = 0
        self.hits = 0
        self.insertions = 0
        self.evictions = 0
        # published metric handles (no-ops until bind_metrics)
        self._m_lookups = NULL_INSTRUMENT
        self._m_hits = NULL_INSTRUMENT
        self._m_insertions = NULL_INSTRUMENT
        self._m_evictions = NULL_INSTRUMENT

    def bind_metrics(self, registry) -> None:
        """Publish cache activity into a metrics registry (the engine calls
        this at construction): event counters mirror the attribute counters
        above; entry count / resident bytes are snapshot-time callback
        gauges, so the hot path never samples them."""
        self._m_lookups = registry.counter(
            "prefix_lookups_total", "prefix-cache lookups")
        self._m_hits = registry.counter(
            "prefix_hits_total", "lookups that found a reusable prefix")
        self._m_insertions = registry.counter(
            "prefix_insertions_total", "snapshots registered")
        self._m_evictions = registry.counter(
            "prefix_evictions_total",
            "entries evicted (LRU budget or pool pressure)")
        if registry.enabled:
            registry.gauge_fn("prefix_entries", lambda: len(self._entries),
                              "resident prefix-cache entries")
            registry.gauge_fn("prefix_bytes", lambda: self._nbytes,
                              "bytes charged to resident entries")

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return self._nbytes

    @property
    def bytes_shared(self) -> int:
        """Physical bytes deduplicated by block sharing (paged backend)."""
        return self.store.bytes_shared if self.store is not None else 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def lookup(self, tokens) -> Optional[PrefixEntry]:
        """Longest cached prefix of ``tokens`` (LRU-refreshing), or None."""
        self.lookups += 1
        self._m_lookups.inc()
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        # probe by the distinct-length index, not a scan of every entry:
        # O(distinct lengths), which stays small (block-aligned snapshots)
        lengths = sorted((length for length in self._len_count
                          if length <= tokens.shape[0]), reverse=True)
        for length in lengths:
            h = _digest(tokens[:length])
            entry = self._entries.get(h)
            if entry is not None and np.array_equal(entry.tokens,
                                                    tokens[:length]):
                self._entries.move_to_end(h)
                self.hits += 1
                self._m_hits.inc()
                return entry
        return None

    def restore(self, entry: PrefixEntry):
        """(logits, decode state) of an entry; dense entries return their
        stored pytree by reference, paged entries gather a fresh working
        state through the block tables (the pool copy stays shared)."""
        if entry.snap is not None:
            return entry.logits, self.store.get(entry.snap)
        return entry.logits, entry.state

    def insert(self, tokens, state, logits,
               parent: Optional[PrefixEntry] = None) -> Optional[PrefixEntry]:
        """Snapshot ``state``/``logits`` under ``tokens``; returns the new
        entry, or None when it cannot be cached (alone exceeds the byte
        budget, or the paged pool cannot fit it even after evicting every
        other entry). ``parent`` (paged backend) names the snapshot this
        state extends — its whole-block prefix is shared, not copied."""
        tokens = np.array(tokens, np.int32).reshape(-1)
        if self.store is not None:
            entry = self._insert_paged(tokens, state, logits, parent)
        else:
            nbytes = tree_bytes(state) + tree_bytes(logits)
            if nbytes > self.max_bytes:
                return None
            entry = PrefixEntry(tokens=tokens, state=state, logits=logits,
                                nbytes=nbytes)
        if entry is None:
            return None
        return self._register(entry)

    def insert_snapshot(self, tokens, snap, logits,
                        owned_bytes: int) -> Optional[PrefixEntry]:
        """Register an already-built snapshot (the in-model paged engine's
        refcount forks of live lane tables — :class:`repro.core.paged.
        TableSnapshot`). ``owned_bytes`` is the snapshot's unique block cost
        (the blocks whose ownership it took over); metadata and logits ride
        on top. The snapshot arrives holding its own pool references; a
        refused insert releases them."""
        tokens = np.array(tokens, np.int32).reshape(-1)
        nbytes = owned_bytes + snap.dense_bytes + tree_bytes(logits)
        if nbytes > self.max_bytes:
            self.store.release(snap)
            return None
        return self._register(PrefixEntry(tokens=tokens, state=None,
                                          logits=logits, nbytes=nbytes,
                                          snap=snap))

    def _register(self, entry: PrefixEntry) -> PrefixEntry:
        """LRU-register an entry (same-prefix replacement, byte-budget
        eviction, peak tracking)."""
        h = _digest(entry.tokens)
        old = self._entries.pop(h, None)
        if old is not None:
            self._drop_entry(old)
        self._entries[h] = entry
        self._len_count[entry.length] = self._len_count.get(entry.length,
                                                            0) + 1
        self._nbytes += entry.nbytes
        self.insertions += 1
        self._m_insertions.inc()
        # the `self._entries` guard matters for in-model table snapshots:
        # evicting an entry whose blocks a RUNNING lane still reads frees
        # nothing yet (the charge stays until the lane retires and calls
        # :meth:`settle`), so _nbytes can transiently exceed the budget
        # with no entry left to evict.
        while self._nbytes > self.max_bytes and self._entries:
            _, evicted = self._entries.popitem(last=False)
            self._drop_entry(evicted)
            self.evictions += 1
            self._m_evictions.inc()
        # one basis for both backends: bytes the cache holds resident
        # (paged: live blocks charged to entries + dense overhead; dense:
        # full snapshot copies) — so peak_bytes is comparable across
        # kv_backend settings (benchmarks/throughput.py paged_vs_dense)
        self.peak_bytes = max(self.peak_bytes, self._nbytes)
        return entry

    def settle(self, nbytes: int) -> None:
        """Uncharge bytes that left residency *outside* an entry drop.

        In-model paged serving: evicting a ``TableSnapshot`` entry while a
        RUNNING lane still reads its blocks frees nothing at drop time —
        the charge stays (bounding resident bytes), and the blocks only
        free when the lane retires. The engine measures exactly those
        bytes at retirement (charged blocks whose last reference the lane
        held) and settles them here; without this, the charge would leak
        and monotonically shrink the effective LRU budget."""
        self._nbytes = max(0, self._nbytes - int(nbytes))

    def evict_lru(self) -> bool:
        """Evict the least-recently-used entry (used for pool-pressure
        relief as well as the byte budget); False when already empty."""
        if not self._entries:
            return False
        _, evicted = self._entries.popitem(last=False)
        self._drop_entry(evicted)
        self.evictions += 1
        self._m_evictions.inc()
        return True

    def _insert_paged(self, tokens, state, logits, parent):
        """Page ``state`` into the store, evicting LRU entries while the
        free list cannot hold it. The put happens *before* any same-hash
        replacement is disposed, so an entry may safely parent its own
        replacement (the shared blocks are retained first)."""
        while True:
            try:
                snap, owned = self.store.put(
                    state, parent=None if parent is None else parent.snap)
                break
            except PoolExhausted:
                if not self.evict_lru():
                    return None
        nbytes = owned + tree_bytes(logits)
        if nbytes > self.max_bytes:
            self.store.release(snap)
            return None
        return PrefixEntry(tokens=tokens, state=None, logits=logits,
                           nbytes=nbytes, snap=snap)

    def _drop_entry(self, entry: PrefixEntry) -> None:
        if entry.snap is not None:
            # uncharge only the bytes that actually left residency: pool
            # blocks whose last reference this entry held, plus its dense
            # overhead (non-KV leaves + logits). Blocks that survive in a
            # descendant snapshot stay charged — ownership transfers to the
            # survivors, so the byte budget keeps bounding resident KV even
            # as ancestors of a snapshot lineage evict first (LRU order).
            before = self.store.bytes_in_use
            self.store.release(entry.snap)
            freed = before - self.store.bytes_in_use
            self._nbytes -= freed + entry.snap.dense_bytes \
                + tree_bytes(entry.logits)
        else:
            self._nbytes -= entry.nbytes
        self._drop_len(entry.length)

    def _drop_len(self, length: int) -> None:
        n = self._len_count.get(length, 0) - 1
        if n <= 0:
            self._len_count.pop(length, None)
        else:
            self._len_count[length] = n

    def clear(self) -> None:
        for entry in self._entries.values():
            if entry.snap is not None:
                self.store.release(entry.snap)
        self._entries.clear()
        self._len_count.clear()
        self._nbytes = 0
