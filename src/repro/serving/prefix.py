"""Shared-prefix prompt cache: snapshot post-prefill decode states by prompt.

A million-user deployment re-prefills the same system prompt thousands of
times; the LaCache promise is to never recompute what the ladder already
holds. :class:`PrefixCache` extends that promise *across requests*: after a
request opts in (``Engine.submit(..., cache_prefix=True)``), its
:class:`~repro.models.model.DecodeState` (every ``KVCache`` / ring / SSM
pytree leaf, batch = 1) is snapshotted under a hash of its prompt tokens —
at the full prompt *and* at every ``Engine.prefix_block`` boundary along
the way. A later request whose prompt shares a cached prefix restores the
longest matching snapshot and prefills only the remainder through
``decode_chunk``; the block-boundary snapshots mean two prompts sharing a
system prefix hit each other even when neither is a full prefix of the
other.

Correctness notes:

* Snapshots are position-exact even after compaction: each ``KVCache``
  stores the absolute token position per slot and ``DecodeState.pos`` is
  the absolute next position, so continuing from a snapshot is
  indistinguishable from having decoded through it.
* JAX arrays are immutable and the engine's donating dispatches never
  donate a snapshot, so entries are shared by reference — a hit costs no
  copy.
* Lookup is longest-match: hashes of every cached length are probed from
  the longest candidate down, and the stored tokens are compared on a hash
  hit, so a digest collision can never splice the wrong state.

Eviction is LRU under a byte budget (``max_bytes``): both ``lookup`` hits
and ``insert`` refresh recency; inserting past the budget evicts the least
recently used entries first. A single entry larger than the whole budget is
refused rather than thrashing the cache.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Optional

import jax
import numpy as np


def _digest(tokens: np.ndarray) -> bytes:
    return hashlib.sha1(
        np.ascontiguousarray(tokens, np.int32).tobytes()).digest()


def tree_bytes(tree) -> int:
    """Total bytes of every array leaf in a pytree."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


@dataclasses.dataclass(eq=False)
class PrefixEntry:
    """One cached prefix: the tokens it covers, the batch-1 decode state
    snapshot positioned just past them, and the last-token logits (so an
    exact-match request can sample its first token with zero compute)."""

    tokens: np.ndarray          # [length] int32
    state: Any                  # DecodeState, batch = 1, pos == length
    logits: Any                 # [1, V] logits of tokens[-1]
    nbytes: int

    @property
    def length(self) -> int:
        return int(self.tokens.shape[0])


class PrefixCache:
    """LRU map from token-prefix hashes to decode-state snapshots."""

    def __init__(self, max_bytes: int = 256 << 20):
        if max_bytes < 1:
            raise ValueError("prefix cache needs a positive byte budget")
        self.max_bytes = int(max_bytes)
        self._entries: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self._len_count: dict = {}     # distinct entry lengths -> #entries
        self._nbytes = 0
        self.lookups = 0
        self.hits = 0
        self.insertions = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return self._nbytes

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def lookup(self, tokens) -> Optional[PrefixEntry]:
        """Longest cached prefix of ``tokens`` (LRU-refreshing), or None."""
        self.lookups += 1
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        # probe by the distinct-length index, not a scan of every entry:
        # O(distinct lengths), which stays small (block-aligned snapshots)
        lengths = sorted((length for length in self._len_count
                          if length <= tokens.shape[0]), reverse=True)
        for length in lengths:
            h = _digest(tokens[:length])
            entry = self._entries.get(h)
            if entry is not None and np.array_equal(entry.tokens,
                                                    tokens[:length]):
                self._entries.move_to_end(h)
                self.hits += 1
                return entry
        return None

    def insert(self, tokens, state, logits) -> bool:
        """Snapshot ``state``/``logits`` under ``tokens``; returns False when
        the entry alone exceeds the byte budget (and is not cached)."""
        tokens = np.array(tokens, np.int32).reshape(-1)
        nbytes = tree_bytes(state) + tree_bytes(logits)
        if nbytes > self.max_bytes:
            return False
        h = _digest(tokens)
        old = self._entries.pop(h, None)
        if old is not None:
            self._nbytes -= old.nbytes
            self._drop_len(old.length)
        entry = PrefixEntry(tokens=tokens, state=state, logits=logits,
                            nbytes=nbytes)
        self._entries[h] = entry
        self._len_count[entry.length] = self._len_count.get(entry.length,
                                                            0) + 1
        self._nbytes += nbytes
        self.insertions += 1
        while self._nbytes > self.max_bytes:
            _, evicted = self._entries.popitem(last=False)
            self._nbytes -= evicted.nbytes
            self._drop_len(evicted.length)
            self.evictions += 1
        return True

    def _drop_len(self, length: int) -> None:
        n = self._len_count.get(length, 0) - 1
        if n <= 0:
            self._len_count.pop(length, None)
        else:
            self._len_count[length] = n

    def clear(self) -> None:
        self._entries.clear()
        self._len_count.clear()
        self._nbytes = 0
