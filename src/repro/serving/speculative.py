"""Self-speculative decoding through a ladder-compacted draft cache.

Long-context decode is memory-bound: each step streams the whole budgeted
KV once to produce one token. LaCache's iterative compaction already
manufactures the artifact speculation needs — a cheap, aggressively
compressed KV view of the *same* model — so the draft is not a second
model but a **compacted copy fork** of the live lane:

1. **fork** — every live lane is compacted down to ``draft_budget`` slots
   with the standard keep-mask + RoPE slot-delta machinery, its surviving
   rows *copied* into the draft's own engine-lifetime block reservation,
   and the draft's slot buffers trimmed to a page-aligned ``draft_slots``
   window. The copy (never aliasing a live block) is what lets the fork
   **persist across waves**; the trim is what makes it cheap — paged
   attention costs scale with the slot-buffer width, not its occupancy,
   so the draft decodes through its own small executable.
2. **draft** — ``k + 1`` greedy steps through the trimmed view: the first
   ``k`` produce the proposals, the extra step pre-ingests the last
   proposal's KV so a fully-accepted wave leaves the draft cache
   consistent with the live stream. Appends land in draft-owned blocks;
   capacity is gated host-side so the draft never compacts mid-wave.
3. **verify** — the target feeds ``[last_token, d_1..d_k]`` (``k + 1``
   tokens) through the existing paged ``decode_chunk`` in one dispatch
   and takes the greedy argmax at every position.
4. **commit** — greedy acceptance (emit the matching draft prefix plus
   the target's token at the first disagreement — or its bonus token when
   all ``k`` agree), then a metadata-only rollback of the SAME rejected
   suffix on both the live state and the draft. Both caches end the wave
   holding exactly the emitted stream minus its last token (the next
   wave's first feed), so the draft stays valid and the expensive fork
   amortizes over many waves. The emitted stream is token-for-token
   identical to non-speculative greedy decode.

The draft is **invalidated** (re-forked on the next wave) whenever a
lane's tables are rewritten outside a wave — an admission/resume prefill
into a lane — and when the draft's own slot window fills up. A fallback
to *stepwise* decode (a stochastic request running, or an active lane
without ``k + 1`` free slots — the stepwise step then fires compaction
exactly as non-speculative decode would) does **not** invalidate: the
draft's validity depends only on the emitted token stream, not the live
tables, so the decoder records the tokens each fallback tick fed
(:meth:`SpecDecoder.note_stepwise`) and replays them through the draft at
the next wave — a few trimmed-width catch-up steps instead of a full
re-fork + re-compaction every time one sampled request joins the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paged as pagedlib
from repro.models import model as M
from repro.serving import sampling


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Draft/verify loop configuration (``Engine(spec_config=...)``).

    ``k``: draft tokens proposed per wave — the target verifies ``k + 1``
    positions in one chunk and emits between 1 and ``k + 1`` tokens.
    ``draft_budget``: live slots the draft view is compacted down to at
    fork time; ``None`` resolves to ``max(n_sink + 1, budget // 4)``
    clamped so ``draft_budget + k <= budget``.
    ``draft_slots``: width of the draft's trimmed slot buffers (rounded up
    to a page multiple). The gap above ``draft_budget`` is cross-wave
    headroom: the draft grows by the accepted tokens each wave and is only
    re-forked (the expensive part) when the window fills. ``None``
    resolves to ``draft_budget + 8 * (k + 1)`` — roughly eight waves of
    fork amortization at full acceptance.
    """

    k: int = 4
    draft_budget: Optional[int] = None
    draft_slots: Optional[int] = None

    def validate(self) -> "SpecConfig":
        if not isinstance(self.k, (int, np.integer)) \
                or isinstance(self.k, bool) or self.k < 1:
            raise ValueError(f"k must be an int >= 1, got {self.k!r}")
        for name, v in (("draft_budget", self.draft_budget),
                        ("draft_slots", self.draft_slots)):
            if v is not None and (
                    not isinstance(v, (int, np.integer))
                    or isinstance(v, bool) or v < 1):
                raise ValueError(
                    f"{name} must be None or an int >= 1, got {v!r}")
        return self


class SpecDecoder:
    """Engine-side driver of the draft/verify wave.

    Holds the draft's engine-lifetime block reservation (one fully-
    covering ``owned`` set per kv leaf, same shape as the lanes' own —
    released by ``Engine.close()`` before the shutdown leak audit), the
    persistent cross-wave draft state, and the per-wave telemetry
    aggregates. Per-request acceptance counters live on
    :class:`repro.serving.engine.Request`.
    """

    def __init__(self, engine, config: SpecConfig):
        config = config.validate()
        self.engine = engine
        self.config = config
        self.k = int(config.k)
        cfg = engine.cfg
        self.enabled = (engine.kv_backend == "paged"
                        and engine._paged_in_model
                        and M.spec_decode_eligible(cfg))
        # telemetry (aggregates across requests)
        self.waves = 0
        self.forks = 0
        self.fallback_steps = 0
        self.catchup_steps = 0
        self.proposed = 0
        self.accepted = 0
        # published metric handles (no-ops under the engine's default
        # null registry; resolved once here, incremented per wave)
        m = engine.metrics
        self._m_waves = m.counter("spec_waves_total",
                                  "draft/verify waves run")
        self._m_forks = m.counter("spec_forks_total",
                                  "draft view (re-)forks")
        self._m_fallbacks = m.counter(
            "spec_fallback_steps_total",
            "ticks that fell back to stepwise decode, by reason",
            labels=("reason",))
        tokens = m.counter("spec_tokens_total",
                           "draft tokens, proposed vs accepted",
                           labels=("kind",))
        self._m_proposed = tokens.labels("proposed")
        self._m_accepted = tokens.labels("accepted")
        self._m_fb_stochastic = self._m_fallbacks.labels("stochastic")
        self._m_fb_headroom = self._m_fallbacks.labels("headroom")
        self._m_catchup = m.counter(
            "spec_catchup_steps_total",
            "draft steps replaying stepwise-fallback tokens (fork kept "
            "alive across a fallback instead of re-forked)")
        self.draft_budget = 0
        self.draft_slots = 0
        self._owned: Optional[Dict[str, np.ndarray]] = None
        self._owned_blocks = 0
        # the persistent draft: a trimmed DecodeState without pool planes
        # (planes are threaded in from the live state at each use), plus a
        # host-side upper bound on its occupancy for the capacity gate
        self._draft = None
        self._draft_len_ub = 0
        # tokens fed by stepwise fallback ticks while a draft was alive:
        # replayed through the draft at the next wave (catch-up) so the
        # fork survives fallbacks instead of dying to them
        self._lag: List[np.ndarray] = []
        if not self.enabled:
            return
        spec = M.ladder_spec(cfg)
        db = config.draft_budget
        if db is None:
            db = min(max(spec.n_sink + 1, engine.budget // 4),
                     engine.budget - self.k)
        if db < 1 or db + self.k > engine.budget:
            raise ValueError(
                f"draft_budget={db} with k={self.k} does not fit the lane "
                f"budget {engine.budget} (need 1 <= draft_budget and "
                "draft_budget + k <= budget so the draft never compacts "
                "mid-wave)")
        self.draft_budget = int(db)
        ps = engine.page_size
        ds = config.draft_slots
        if ds is None:
            ds = self.draft_budget + 8 * (self.k + 1)
        ds = max(int(ds), self.draft_budget + self.k + 1)
        self.draft_slots = -(-ds // ps) * ps
        # donate ONLY the pool planes into the fork: the live tables stay
        # host-referenced across the wave and must survive it, while the
        # planes move draft -> live and back.
        self._fork = jax.jit(
            lambda state, planes, owned: M.fork_draft_state(
                cfg, state, planes, owned, self.draft_budget, ps,
                draft_slots=self.draft_slots),
            donate_argnames=("planes",))
        # one jit, two executables: the live-shaped and draft-shaped
        # rollbacks specialize on their state shapes
        self._rollback = jax.jit(
            lambda state, drop: M.spec_rollback_state(cfg, state, drop, ps),
            donate_argnames=("state",))

    # ------------------------------------------------------------------ #
    # Draft reservation lifecycle
    # ------------------------------------------------------------------ #
    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the target accepted."""
        return self.accepted / max(1, self.proposed)

    def _kv_leaves(self, state):
        for key in sorted(state.blocks):
            leaf = state.blocks[key]
            if isinstance(leaf, pagedlib.PagedKVCache):
                yield key, leaf
        for key in sorted(state.tail):
            leaf = state.tail[key]
            if isinstance(leaf, pagedlib.PagedKVCache):
                yield key, leaf

    def ensure_reserved(self, state) -> None:
        """Allocate the draft's own block reservation (engine lifetime,
        first wave): per kv leaf, one block set shaped exactly like the
        leaf's ``owned``. Full coverage is required for safety, not just
        capacity — the policy compaction pass may transiently scatter up
        to the full pre-compact length before the forced pass trims it."""
        if self._owned is not None:
            return
        store = self.engine.kv_store
        owned: Dict[str, np.ndarray] = {}
        total = 0
        for key, leaf in self._kv_leaves(state):
            shape = tuple(leaf.owned.shape)
            n = int(np.prod(shape, dtype=int))
            while True:
                try:
                    ids = store.alloc_blocks(n)
                    break
                except pagedlib.PoolExhausted:
                    if not self.engine.prefix_cache.evict_lru():
                        raise
            owned[key] = np.asarray(ids, np.int32).reshape(shape)
            total += n
        self._owned = owned
        self._owned_blocks = total

    def invalidate(self) -> None:
        """Drop the persistent draft view. Called when a lane's tables
        are rewritten outside a wave — an admission/resume prefill into a
        lane — and on capacity re-forks. Stepwise fallbacks do NOT call
        this; they record their fed tokens via :meth:`note_stepwise` and
        the next wave replays them. The block reservation stays; only the
        (cheap) metadata dies, and the next wave re-forks."""
        self._draft = None
        self._draft_len_ub = 0
        self._lag.clear()

    def note_stepwise(self, tokens: np.ndarray) -> None:
        """Record the tokens a stepwise-fallback tick fed to the live
        lanes (the engine passes a copy of its pre-step ``_slot_tokens``).

        The draft's validity invariant — it holds the emitted stream
        minus its last token — depends only on the token stream, never on
        the live tables (the draft owns its own blocks), so a stepwise
        tick merely puts the draft one feed behind. Replaying the lagged
        feeds at the next wave costs one trimmed-width draft step each —
        far cheaper than the re-fork + re-compaction an invalidate forces.
        When the lag outgrows the draft window's remaining headroom the
        draft is dropped (the next wave re-forks, as before)."""
        if self._draft is None:
            return
        if (self._draft_len_ub + len(self._lag) + 1 + self.k + 1
                > self.draft_slots):
            self.invalidate()
            return
        self._lag.append(np.asarray(tokens, np.int64).copy())

    def release(self) -> None:
        """Drop the draft reservation (``Engine.close()``)."""
        self.invalidate()
        if self._owned is None:
            return
        ids = np.concatenate([a.reshape(-1).astype(np.int64)
                              for a in self._owned.values()])
        self.engine.kv_store.release_blocks(ids)
        self._owned = None
        self._owned_blocks = 0

    @property
    def owned_blocks(self) -> int:
        return self._owned_blocks

    # ------------------------------------------------------------------ #
    # The wave
    # ------------------------------------------------------------------ #
    def wave(self) -> Optional[List[int]]:
        """Run one draft/verify wave over the running lanes.

        Returns the slots whose requests finished (the caller retires
        them), or ``None`` when this tick must fall back to a normal
        stepwise decode: the config is ineligible, a stochastically
        sampling request is actually RUNNING with room to emit
        (acceptance below is greedy), or some active lane lacks ``k + 1``
        free slots — in which case the stepwise path lets compaction fire
        exactly as non-speculative decode would, keeping the streams
        token-for-token equal. Fallbacks leave the persistent draft alive
        (the engine reports the stepwise feeds via :meth:`note_stepwise`
        and the next wave catches the draft up).
        """
        eng = self.engine
        if not self.enabled:
            return None
        running = eng.scheduler.running
        slots = sorted(running)
        k_chunk = self.k + 1
        if any(r.sampling.temperature != 0.0 and not r.done
               for r in running.values()):
            self.fallback_steps += 1
            self._m_fb_stochastic.inc()
            return None
        state = eng._slot_states
        # chunk-verify gate over ACTIVE lanes only: retired lanes keep
        # stale (possibly full) tables until their next reset and are
        # never read, so they must not pin the headroom at zero.
        for _, leaf in self._kv_leaves(state):
            ln = np.asarray(leaf.length)[..., slots]
            if ln.size and int(ln.max()) + k_chunk > leaf.n_slots:
                self.fallback_steps += 1
                self._m_fb_headroom.inc()
                return None
        self.ensure_reserved(state)
        self.waves += 1
        self._m_waves.inc()
        eng.tracer.begin(("spec_wave", eng._tick), "spec_wave", tid=0,
                         lanes=len(slots))

        # --- fork (or reuse): compacted copy of the live tables -------- #
        if self._draft is not None \
                and (self._draft_len_ub + len(self._lag) + k_chunk
                     > self.draft_slots):
            self.invalidate()                      # window full: re-fork
        planes = state.kv_pool
        live = state._replace(kv_pool=None)
        if self._draft is not None and self._lag:
            # catch-up: replay the tokens stepwise-fallback ticks fed to
            # the live lanes (outputs discarded — only the KV appends
            # matter) so the surviving fork holds the emitted stream
            # minus its last token again
            draft = self._draft._replace(kv_pool=planes)
            for fed in self._lag:
                _, draft = eng._paged_step(
                    eng.params, state=draft,
                    tokens=jnp.asarray(fed, jnp.int32)[:, None])
            self.catchup_steps += len(self._lag)
            self._m_catchup.inc(len(self._lag))
            self._draft_len_ub += len(self._lag)
            self._lag.clear()
            planes = draft.kv_pool
            self._draft = draft._replace(kv_pool=None)
        if self._draft is None:
            draft = self._fork(live, planes, dict(self._owned))
            self.forks += 1
            self._m_forks.inc()
            self._draft_len_ub = self.draft_budget
        else:
            draft = self._draft._replace(kv_pool=planes)
        # the draft's buffers are donated through the steps below; clear
        # the persistent handle so an exception mid-wave re-forks cleanly
        self._draft = None

        # --- draft: k proposals + one pre-ingest step ------------------ #
        # the extra step appends d_k's KV (its output is discarded) so a
        # fully-accepted wave leaves the draft holding the whole accepted
        # stream minus the last emitted token — the next wave's first feed
        toks = jnp.asarray(eng._slot_tokens, jnp.int32)[:, None]
        drafts = []
        for i in range(k_chunk):
            dlogits, draft = eng._paged_step(eng.params, state=draft,
                                             tokens=toks)
            tok = sampling.greedy(dlogits)               # [b]
            if i < self.k:
                drafts.append(tok)
            toks = tok[:, None]
        drafts_np = np.stack([np.asarray(t) for t in drafts], axis=1)
        live = live._replace(kv_pool=draft.kv_pool)
        draft = draft._replace(kv_pool=None)

        # --- verify: k+1 positions in ONE batched chunk dispatch ------- #
        feed = np.concatenate(
            [np.asarray(eng._slot_tokens, np.int64)[:, None],
             drafts_np.astype(np.int64)], axis=1)             # [b, k+1]
        vlogits, live = eng._paged_chunk(eng.params, state=live,
                                         tokens=jnp.asarray(feed, jnp.int32))
        targets = np.asarray(sampling.greedy(vlogits))        # [b, k+1]

        # --- accept + commit ------------------------------------------- #
        emit_raw = sampling.greedy_verify(drafts_np, targets)  # [b], 1..k+1
        emit = np.zeros_like(emit_raw)
        for slot in slots:
            req = running[slot]
            room = req.max_new_tokens - len(req.output_tokens)
            emit[slot] = min(int(emit_raw[slot]), room)
            req.spec_waves += 1
            req.spec_proposed += self.k
            req.spec_accepted += int(emit_raw[slot]) - 1
        self.proposed += self.k * len(slots)
        self._m_proposed.inc(self.k * len(slots))
        if slots:
            acc = int((emit_raw[slots] - 1).sum())
            self.accepted += acc
            self._m_accepted.inc(acc)
        eng.tracer.end(("spec_wave", eng._tick),
                       emitted=int(emit.sum()) if slots else 0)
        # both caches appended k+1 tokens; rolling the SAME rejected
        # suffix off each leaves both holding the emitted stream minus
        # its last token. Inactive lanes emit 0 => full rollback; their
        # clocks and tables return to the (stale, never-read) pre-wave
        # values on both sides.
        drop = jnp.asarray(k_chunk - emit, jnp.int32)
        eng._slot_states = self._rollback(live, drop)
        self._draft = self._rollback(draft, drop)
        self._draft_len_ub += int(emit.max()) if slots else 0

        finished: List[int] = []
        for slot in slots:
            req = running[slot]
            for t in targets[slot, :emit[slot]].tolist():
                eng._record(req, int(t))
            if req.done:
                finished.append(slot)
        return finished
