"""Token sampling."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(key, logits: jnp.ndarray, temperature: float = 1.0,
           top_k: int = 0) -> jnp.ndarray:
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k and top_k > 0 and top_k < lf.shape[-1]:
        vals, _ = jax.lax.top_k(lf, top_k)
        thresh = vals[..., -1:]
        lf = jnp.where(lf >= thresh, lf, -1e30)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)


def log_prob_of(logits: jnp.ndarray, token: jnp.ndarray) -> jnp.ndarray:
    """log p(token | context); logits [b, V], token [b]."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, token[:, None], axis=-1)[:, 0]
    return gold - logz
