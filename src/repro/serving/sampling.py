"""Token sampling."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(key, logits: jnp.ndarray, temperature: float = 1.0,
           top_k: int = 0) -> jnp.ndarray:
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k and top_k > 0 and top_k < lf.shape[-1]:
        vals, _ = jax.lax.top_k(lf, top_k)
        thresh = vals[..., -1:]
        lf = jnp.where(lf >= thresh, lf, -1e30)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)


def greedy_verify(drafts, targets):
    """Greedy acceptance rule for self-speculative decoding (host-side).

    drafts [b, k]: the draft's proposed tokens; targets [b, k+1]: the
    target model's greedy argmax at every verified position (position j is
    conditioned on the accepted prefix plus ``drafts[:, :j]``). Returns
    ``emit [b]`` in ``[1, k+1]``: the accepted draft prefix length plus the
    one free token the target supplies at the first disagreement (or the
    bonus token when all k agree) — the standard rule that makes the
    emitted stream token-for-token equal to non-speculative greedy.
    """
    import numpy as np
    drafts = np.asarray(drafts)
    targets = np.asarray(targets)
    k = drafts.shape[1]
    ok = drafts == targets[:, :k]                             # [b, k]
    accepted = np.where(ok.all(axis=1), k, np.argmin(ok, axis=1))
    return (accepted + 1).astype(np.int64)


def log_prob_of(logits: jnp.ndarray, token: jnp.ndarray) -> jnp.ndarray:
    """log p(token | context); logits [b, V], token [b]."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, token[:, None], axis=-1)[:, 0]
    return gold - logz
