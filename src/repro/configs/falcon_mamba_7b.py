"""falcon-mamba-7b [ssm] — mamba1, attention-free. [arXiv:2410.05355]

KV-free: the paper's technique is inapplicable by construction
(DESIGN.md §5) — O(1) recurrent state is the native contrast to LaCache's
O(1) compacted cache.
"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", arch_type="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=65024, attn_every=-1,
    d_state=16, d_conv=4, expand=2,
    lacache=LaCacheConfig(),
    source="arXiv:2410.05355",
)
