"""llama2-7b — the paper's own primary evaluation model (Tab. 1).
[hf:meta-llama/Llama-2-7b]"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=32000, rope_theta=1.0e4,
    lacache=LaCacheConfig(budget=512, n_sink=4, n_recent=128),
    source="hf:meta-llama/Llama-2-7b (paper Tab. 1)",
)
