"""Architecture config registry (``--arch <id>``)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import INPUT_SHAPES, LaCacheConfig, ModelConfig, ShapeConfig

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "grok-1-314b": "grok_1_314b",
    "qwen1.5-110b": "qwen1_5_110b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-small": "whisper_small",
    "llama3.2-1b": "llama3_2_1b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "gemma3-27b": "gemma3_27b",
    "granite-20b": "granite_20b",
    "llama2-7b": "llama2_7b",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "llama2-7b"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}
