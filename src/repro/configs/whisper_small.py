"""whisper-small [audio] — enc-dec; conv/mel frontend stubbed to frame
embeddings per assignment. [arXiv:2212.04356]

Decoder self-attention gets the LaCache budgeted cache; cross-attention KV
(1500 encoder frames) is static and never evicted (DESIGN.md §5).
"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", arch_type="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    encoder_layers=12, cross_attention=True, n_audio_frames=1500,
    pos_emb="abs", act="gelu", mlp_gated=False,
    lacache=LaCacheConfig(),
    source="arXiv:2212.04356",
)
