"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887]

Ladder span is computed over the 9 attention layers only (DESIGN.md §5);
Mamba layers carry recurrent state.
"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", arch_type="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    attn_every=8, n_experts=16, top_k=2, moe_every=2,
    d_state=16, d_conv=4, expand=2,
    lacache=LaCacheConfig(),
    source="arXiv:2403.19887",
)
