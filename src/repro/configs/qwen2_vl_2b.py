"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (vision stub). [arXiv:2409.12191]

The ViT/projector frontend is stubbed per assignment: ``input_specs`` feeds
precomputed patch embeddings; this config is the LM decoder backbone.
M-RoPE forces rope_mode="original" (patch 2D positions live in the cache keys;
text decode rotates with plain RoPE, exactly equivalent for equal components).
"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", arch_type="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    mrope=True, mrope_sections=(16, 24, 24), n_patches=1024,
    rope_theta=1.0e6, qkv_bias=True,
    lacache=LaCacheConfig(rope_mode="original"),
    source="arXiv:2409.12191",
)
