"""qwen1.5-110b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B (family card)]"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", arch_type="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=49152, vocab_size=152064, qkv_bias=True,
    rope_theta=1.0e6,
    lacache=LaCacheConfig(),
    source="hf:Qwen/Qwen1.5-0.5B",
)
