"""gemma3-27b [dense] — 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-1b-pt (family card)]

Local layers use their native 1024-token sliding window (already O(1));
the LaCache ladder applies to the 1-in-6 global layers (DESIGN.md §5).
"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", arch_type="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    local_global_pattern=5, sliding_window=1024, rope_theta=1.0e6,
    act="gelu", lacache=LaCacheConfig(),
    source="hf:google/gemma-3-1b-pt",
)
