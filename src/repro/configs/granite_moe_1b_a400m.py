"""granite-moe-1b-a400m [moe] — 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", arch_type="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155, n_experts=32, top_k=8,
    # §Perf iter 3: moe_group_size=256 cuts dispatch FLOPs 21% (useful-frac
    # 0.27->0.34) but grows dispatch/routing collectives 57%; this pair is
    # collective-bound, so the default S=1024 stays (see EXPERIMENTS.md).
    rope_theta=1.0e4, act="silu", mlp_gated=True,
    lacache=LaCacheConfig(),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
