"""granite-20b [dense] — llama-arch code model, MQA (kv=1). [arXiv:2405.04324]

With kv_heads=1 < |model| the cache shards over the slot axis instead of
heads (DESIGN.md §4) — the partial-softmax all-reduce case.
"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", arch_type="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    lacache=LaCacheConfig(),
    source="arXiv:2405.04324",
)
