"""Config dataclasses for the repro framework.

Every architecture in ``repro/configs/<id>.py`` instantiates :class:`ModelConfig`.
Configs are immutable; use :func:`dataclasses.replace` to derive variants
(e.g. the reduced smoke-test variants via :func:`ModelConfig.reduced`).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class LaCacheConfig:
    """Configuration of the paper's technique (LaCache, ICML 2025).

    ``span``/``overlap`` default to the paper's language-modeling settings
    (S = L_attn/4, O = S/2) when left as None; they are resolved against the
    number of *cache-bearing* (attention) layers, not physical layers.
    """

    budget: int = 1024          # per-layer KV slot budget B
    n_sink: int = 4             # pinned attention-sink slots
    n_recent: int = 128         # always-kept most-recent slots
    span: Optional[int] = None  # S: layers retaining the same token chunk
    overlap: Optional[int] = None  # O: band overlap between consecutive rungs
    chunk: int = 16             # C: tokens per ladder rung chunk
    rope_mode: str = "cache"    # "cache" (slot-relative) | "original"
    policy: str = "lacache"     # any name registered in repro.core.policy
                                # (built-ins: lacache|streaming|h2o|tova|full)
    fused_compaction: bool = True  # compaction inside serve_step (lax.cond)

    def eviction_policy(self):
        """Resolve the policy name to its EvictionPolicy object.

        Lazy import: configs must stay importable without pulling in the
        core package (core.ladder itself imports configs.base).
        """
        from repro.core.policy import get_policy
        return get_policy(self.policy)

    def resolve(self, n_attn_layers: int) -> "LaCacheConfig":
        span = self.span
        if span is None:
            span = max(1, n_attn_layers // 4)
        span = min(span, n_attn_layers)
        overlap = self.overlap
        if overlap is None:
            overlap = span // 2
        overlap = min(overlap, span - 1) if span > 1 else 0
        return dataclasses.replace(self, span=span, overlap=overlap)


@dataclass(frozen=True)
class LayerSpec:
    """Resolved per-layer structure."""

    kind: str            # "attn" | "mamba"
    attn: Optional[str] = None   # "global" | "local" (sliding window)
    moe: bool = False
    cache_ord: int = -1  # ordinal among cache-bearing attention layers (-1: none)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    pos_emb: str = "rope"       # "rope" | "abs" (whisper)
    sliding_window: int = 0     # window size for "local" layers
    local_global_pattern: int = 0  # N -> N local : 1 global; 0 = all global
    mrope: bool = False         # Qwen2-VL M-RoPE (temporal/height/width sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE on layers with i % moe_every == moe_every-1
    capacity_factor: float = 1.25
    moe_group_size: int = 1024  # GShard dispatch group S; dispatch FLOPs
                                # scale as cf*k*S per token (§Perf iter 3)
    router_aux_weight: float = 0.01
    # --- SSM / hybrid ---
    attn_every: int = 0         # 0: all attention; -1: no attention (pure SSM);
                                # k>1: attention on layers with i % k == k//2
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    cross_attention: bool = False
    n_audio_frames: int = 1500
    # --- VLM stub ---
    n_patches: int = 0          # prefix patch-embedding slots fed by the stub
    # --- misc ---
    norm_eps: float = 1.0e-5
    tie_embeddings: bool = False
    act: str = "silu"
    mlp_gated: bool = True
    max_position: int = 131072
    bf16_boundary_accum: bool = False  # accumulate the TP-boundary matmuls
                                       # (wo/w_down) in bf16 so SPMD partial-
                                       # sum all-reduces move bf16 not f32
                                       # (§Perf iter 2d; small numeric cost)
    dtype: str = "bfloat16"
    lacache: LaCacheConfig = field(default_factory=LaCacheConfig)
    source: str = ""            # provenance citation

    # ------------------------------------------------------------------ #
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 for TP sharding (Megatron-style).
        Loss/targets use the logical ``vocab_size``; only the embedding and
        lm_head tensors are padded."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank if self.dt_rank is not None else max(1, math.ceil(self.d_model / 16))

    def layer_specs(self) -> List[LayerSpec]:
        specs: List[LayerSpec] = []
        ord_ = 0
        for i in range(self.n_layers):
            if self.attn_every == -1:
                kind = "mamba"
            elif self.attn_every in (0, 1):
                kind = "attn"
            else:
                kind = "attn" if i % self.attn_every == self.attn_every // 2 else "mamba"
            attn = None
            if kind == "attn":
                if self.local_global_pattern > 0:
                    p = self.local_global_pattern + 1
                    attn = "global" if i % p == p - 1 else "local"
                else:
                    attn = "global"
            moe = self.n_experts > 0 and (i % self.moe_every == self.moe_every - 1)
            cache_ord = -1
            if kind == "attn" and attn == "global":
                cache_ord = ord_
                ord_ += 1
            # static spec from concrete config fields; never holds tracers
            # analysis: allow(PYT001)
            specs.append(LayerSpec(kind=kind, attn=attn, moe=moe, cache_ord=cache_ord))
        return specs

    @property
    def n_cache_layers(self) -> int:
        """Number of global-attention (budgeted-cache-bearing) layers."""
        return sum(1 for s in self.layer_specs() if s.cache_ord >= 0)

    @property
    def n_local_layers(self) -> int:
        return sum(1 for s in self.layer_specs() if s.attn == "local")

    @property
    def n_mamba_layers(self) -> int:
        return sum(1 for s in self.layer_specs() if s.kind == "mamba")

    def scan_period(self) -> int:
        """Length of the repeating layer pattern (for lax.scan over periods)."""
        p = 1
        if self.attn_every > 1:
            p = _lcm(p, self.attn_every)
        if self.local_global_pattern > 0:
            p = _lcm(p, self.local_global_pattern + 1)
        if self.n_experts > 0 and self.moe_every > 1:
            p = _lcm(p, self.moe_every)
        return p

    def resolved_lacache(self) -> LaCacheConfig:
        return self.lacache.resolve(max(1, self.n_cache_layers))

    def reduced(self, **overrides) -> "ModelConfig":
        """Reduced smoke-test variant of the same family (CPU-runnable)."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        # preserve GQA/MQA character
        if self.n_kv_heads == 1:
            n_kv = 1
        elif self.n_kv_heads < self.n_heads:
            n_kv = max(1, n_heads // 2)
        else:
            n_kv = n_heads
        period = self.scan_period()
        n_layers = max(2, period)  # keep one full pattern period
        kw = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            n_audio_frames=16 if self.encoder_layers else self.n_audio_frames,
            n_patches=8 if self.n_patches else 0,
            max_position=8192,
            dtype="float32",
            lacache=dataclasses.replace(
                self.lacache, budget=64, n_sink=2, n_recent=8, chunk=2,
                span=None, overlap=None),
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
            mrope_sections=(8, 12, 12),  # sums to head_dim(64)/2
        )
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned (input-shape) workload."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
