"""llama3.2-1b [dense] — small llama3. [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import LaCacheConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", arch_type="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256, rope_theta=5.0e5, tie_embeddings=True,
    lacache=LaCacheConfig(),
    source="hf:meta-llama/Llama-3.2-1B",
)
