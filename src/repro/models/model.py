"""Composable transformer LM covering all assigned architecture families.

One stack definition serves dense GQA/MQA, MoE, Mamba (pure SSM), hybrid
(Jamba-style interleave), local/global sliding-window (Gemma-3), M-RoPE VLM
backbones (Qwen2-VL) and encoder-decoder audio backbones (Whisper).

Deep stacks are compiled as ``lax.scan`` over the repeating layer *period*
(DESIGN.md §4) with stacked parameters and remat; the remainder layers are
unrolled ("tail"). Three entry points:

  * :func:`forward_train`  — full-sequence teacher forcing (no cache),
  * :func:`prefill`        — dense prefill -> LaCache-compacted decode state,
  * :func:`decode_step`    — one token against the budgeted caches
                             (the paper's serve path, iterative compaction).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import cache as cachelib
from repro.core import ladder
from repro.core import paged as pagedlib
from repro.core.cache import CrossKVCache, KVCache, MambaState
from repro.core.paged import PagedKVCache, PoolKV
from repro.core.policy import EvictionPolicy
from repro.launch.axes import shard
from repro.models import common, layers
from repro.models.common import normal, ones, rms_norm, split_params, zeros

FRAME_DIM = 128   # stub audio-frame embedding dim (conv frontend carve-out)
PATCH_DIM = 128   # stub vision-patch embedding dim (ViT carve-out)


# =========================================================================== #
# Structure helpers
# =========================================================================== #
def _periodization(cfg: ModelConfig) -> Tuple[int, int, list]:
    specs = cfg.layer_specs()
    period = cfg.scan_period()
    n_full = cfg.n_layers // period
    return period, n_full, specs


def cache_positions(cfg: ModelConfig) -> Dict[str, Any]:
    """Static layout: which period positions carry which state kind."""
    period, n_full, specs = _periodization(cfg)
    pspecs = specs[:period]
    gpp = sum(1 for s in pspecs if s.attn == "global")
    layout = {
        "period": period, "n_full": n_full, "specs": specs, "pspecs": pspecs,
        "gpp": gpp,
        "tail_specs": specs[n_full * period:],
    }
    return layout


def ladder_spec(cfg: ModelConfig, budget: Optional[int] = None) -> ladder.LadderSpec:
    lc = cfg.resolved_lacache()
    spec = ladder.make_spec(lc, max(1, cfg.n_cache_layers))
    if budget is not None:
        spec = spec._replace(budget=budget)
    return spec


def eviction_policy(cfg: ModelConfig) -> EvictionPolicy:
    """The config's resolved EvictionPolicy object."""
    return cfg.lacache.eviction_policy()


# =========================================================================== #
# Init
# =========================================================================== #
def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    p: Dict[str, Any] = {}
    ks = jax.random.split(key, 6)
    if spec.kind == "attn":
        p["norm"] = ones((cfg.d_model,), (None,), jnp.float32)
        p["attn"] = layers.init_attention(ks[0], cfg, dtype)
        if cfg.cross_attention:
            p["cross_norm"] = ones((cfg.d_model,), (None,), jnp.float32)
            p["cross"] = layers.init_cross_attention(ks[1], cfg, dtype)
    else:
        p["norm"] = ones((cfg.d_model,), (None,), jnp.float32)
        p["mamba"] = layers.init_mamba(ks[2], cfg, dtype)
    if cfg.d_ff > 0 and spec.kind == "attn" or (cfg.d_ff > 0 and spec.kind == "mamba" and cfg.arch_type == "hybrid"):
        p["mlp_norm"] = ones((cfg.d_model,), (None,), jnp.float32)
        if spec.moe:
            p["moe"] = layers.init_moe(ks[3], cfg, dtype)
        else:
            p["mlp"] = layers.init_mlp(ks[4], cfg, dtype)
    return p


def _stack_vals(xs):
    """Stack param values; abstract-init (ShapeDtypeStruct) safe."""
    if isinstance(xs[0], jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct((len(xs),) + tuple(xs[0].shape), xs[0].dtype)
    return jnp.stack(xs, axis=0)


def init(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, logical_axes) pytrees."""
    dtype = jnp.dtype(cfg.dtype)
    layout = cache_positions(cfg)
    period, n_full = layout["period"], layout["n_full"]
    keys = jax.random.split(key, 8)

    tree: Dict[str, Any] = {
        "embed": normal(keys[0], (cfg.padded_vocab, cfg.d_model),
                        ("model", "fsdp"), 0.02, dtype),
        "final_norm": ones((cfg.d_model,), (None,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = normal(keys[1], (cfg.d_model, cfg.padded_vocab),
                                 ("fsdp", "model"), 0.02, dtype)

    bkeys = jax.random.split(keys[2], max(1, n_full) * period).reshape(
        max(1, n_full), period, 2)
    blocks = []
    for i in range(n_full):
        blocks.append({f"p{p}": _init_layer(bkeys[i, p], cfg,
                                            layout["pspecs"][p], dtype)
                       for p in range(period)})
    if blocks:
        tree["blocks"] = jax.tree.map(
            lambda *xs: (_stack_vals([x[0] for x in xs]), (None,) + xs[0][1]),
            *blocks, is_leaf=common.is_param)
    tkeys = jax.random.split(keys[3], max(1, len(layout["tail_specs"])))
    tree["tail"] = {f"t{i}": _init_layer(tkeys[i], cfg, s, dtype)
                    for i, s in enumerate(layout["tail_specs"])}

    if cfg.n_patches > 0:
        tree["patch_proj"] = normal(keys[4], (PATCH_DIM, cfg.d_model),
                                    (None, "fsdp"), 0.02, dtype)
    if cfg.encoder_layers > 0:
        ekeys = jax.random.split(keys[5], cfg.encoder_layers + 1)
        enc_blocks = [{"p0": _init_layer(ekeys[i], cfg,
                                         LayerSpec(kind="attn", attn="global"),
                                         dtype)}
                      for i in range(cfg.encoder_layers)]
        # strip cross-attn from encoder blocks
        for b in enc_blocks:
            b["p0"].pop("cross", None)
            b["p0"].pop("cross_norm", None)
        tree["enc"] = {
            "frame_proj": normal(ekeys[-1], (FRAME_DIM, cfg.d_model),
                                 (None, "fsdp"), 0.02, dtype),
            "blocks": jax.tree.map(
                lambda *xs: (_stack_vals([x[0] for x in xs]), (None,) + xs[0][1]),
                *enc_blocks, is_leaf=common.is_param),
            "final_norm": ones((cfg.d_model,), (None,), jnp.float32),
        }
    return split_params(tree)


# =========================================================================== #
# Layer application (shared by all passes)
# =========================================================================== #
def _apply_ffn(p, cfg, x, aux):
    if "moe" in p:
        h, a = layers.moe_ffn(p["moe"], cfg, rms_norm(x, p["mlp_norm"], cfg.norm_eps))
        return x + h, aux + a
    if "mlp" in p:
        h = layers.mlp(p["mlp"], cfg, rms_norm(x, p["mlp_norm"], cfg.norm_eps))
        return x + h, aux
    return x, aux


def _apply_layer_train(p, cfg: ModelConfig, spec: LayerSpec, x, positions,
                       aux, *, positions3=None, cross: Optional[CrossKVCache] = None,
                       causal=True, kv_keep=None, true_len=None):
    """Returns (x, aux, extra) where extra carries per-layer state for
    dense prefill: ("kv", (k, k_rot, v)) / ("mamba", MambaState) or None.

    ``kv_keep``: optional bool[T] per-layer token-retention mask (evaluation
    of static cache patterns, paper Fig. 3) — attention sees only kept
    positions (plus the causal constraint).
    ``true_len``: traced real-token count for bucketed prefill — SSM layers
    run the pad-masked scan so their final state freezes at ``true_len``
    (attention layers need nothing here: causality already makes the padded
    forward exact for real positions)."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    extra = None
    if spec.kind == "attn":
        window = cfg.sliding_window if spec.attn == "local" else 0
        if kv_keep is not None and spec.attn == "global":
            from repro.kernels import ref as kref
            q, k, v = layers._qkv(p["attn"], cfg, h)
            q = layers._rope_q(cfg, q, positions, positions3)
            k_rot = layers._rope_q(cfg, k, positions, positions3)
            o = kref.mha_reference(q, k_rot, v, causal=True,
                                   kv_valid=kv_keep)
            y = o.reshape(h.shape[0], h.shape[1], -1) @ p["attn"]["wo"]
            x = x + y
            x, aux = _apply_ffn(p, cfg, x, aux)
            return x, aux, None
        if not causal:
            from repro.kernels import ops as kops
            q, k, v = layers._qkv(p["attn"], cfg, h)
            o = kops.flash_attention(q, k, v, causal=False)
            y = o.reshape(h.shape[0], h.shape[1], -1) @ p["attn"]["wo"]
        else:
            y, kv = layers.attention_train(p["attn"], cfg, h, positions,
                                           window=window, positions3=positions3)
            extra = kv  # (k_unrotated, k_rotated, v)
        x = x + y
        if cross is not None and "cross" in p:
            hc = rms_norm(x, p["cross_norm"], cfg.norm_eps)
            x = x + layers.cross_attention(p["cross"], cfg, hc, cross)
    else:
        y, mstate = layers.mamba_train(p["mamba"], cfg, h, true_len=true_len)
        x = x + y
        extra = mstate
    x, aux = _apply_ffn(p, cfg, x, aux)
    return x, aux, extra


# =========================================================================== #
# Embedding / position helpers
# =========================================================================== #
def _embed_tokens(params, cfg, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    return shard(e, "batch", "res_seq", "residual")


def _build_embeds(params, cfg: ModelConfig, tokens, patches=None):
    """Token embeddings, with the VLM patch prefix prepended when present.

    Returns (embeds [b, T, d], positions [T] or None, positions3 [b, T, 3]).
    """
    emb = _embed_tokens(params, cfg, tokens)
    b = tokens.shape[0]
    if patches is not None and cfg.n_patches > 0:
        pe = patches.astype(emb.dtype) @ params["patch_proj"]
        emb = jnp.concatenate([pe, emb], axis=1)
    t = emb.shape[1]
    positions = jnp.arange(t)
    positions3 = None
    if cfg.mrope:
        npat = cfg.n_patches if patches is not None else 0
        side = max(1, int(npat ** 0.5)) if npat else 1
        pid = jnp.arange(t)
        hh = jnp.where(pid < npat, (pid // side), 0)
        ww = jnp.where(pid < npat, (pid % side), 0)
        tt = jnp.zeros_like(pid)
        text_pos = side + (pid - npat)          # sequential after the image
        p3 = jnp.where((pid < npat)[:, None],
                       jnp.stack([tt, hh, ww], axis=-1),
                       jnp.stack([text_pos] * 3, axis=-1))
        positions3 = jnp.broadcast_to(p3[None], (b, t, 3)).astype(jnp.int32)
    if cfg.pos_emb == "abs":
        emb = emb + common.sinusoidal_positions(t, cfg.d_model)[None].astype(emb.dtype)
    return emb, positions, positions3


# =========================================================================== #
# Encoder (whisper)
# =========================================================================== #
def encode_audio(params, cfg: ModelConfig, frames):
    """frames: [b, n_frames, FRAME_DIM] stub embeddings -> [b, n_frames, d]."""
    x = frames.astype(jnp.dtype(cfg.dtype)) @ params["enc"]["frame_proj"]
    x = x + common.sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)

    def body(carry, pblock):
        h, aux = carry
        h, aux, _ = _apply_layer_train(
            # analysis: allow(PYT001) — literal static spec, no tracers
            pblock["p0"], cfg, LayerSpec(kind="attn", attn="global"),
            h, jnp.arange(h.shape[1]), aux, causal=False)
        return (h, aux), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["enc"]["blocks"])
    return rms_norm(x, params["enc"]["final_norm"], cfg.norm_eps)


def _cross_caches(params, cfg: ModelConfig, enc_out):
    """Precompute per-layer cross-attention KV (stacked per period/tail)."""
    layout = cache_positions(cfg)

    def per_block(pblock):
        return {k: layers.encode_cross_kv(v["cross"], cfg, enc_out)
                for k, v in pblock.items() if "cross" in v}

    cross_blocks = None
    if layout["n_full"]:
        cross_blocks = jax.vmap(per_block)(params["blocks"])
    cross_tail = {k: layers.encode_cross_kv(v["cross"], cfg, enc_out)
                  for k, v in params["tail"].items() if "cross" in v}
    return cross_blocks, cross_tail


# =========================================================================== #
# Train / dense-prefill forward
# =========================================================================== #
def forward_train(params, cfg: ModelConfig, tokens, *, patches=None,
                  frames=None, collect_kv: bool = False, remat: bool = True,
                  kv_keep_masks=None, true_len=None):
    """Teacher-forcing forward. Returns (logits, aux, kv_list or None).

    ``collect_kv`` additionally returns each global-attention layer's
    (k_unrotated, k_rotated, v) for dense prefill -> cache construction.
    ``kv_keep_masks``: bool[n_layers, T] static per-layer retention pattern
    (Fig. 3 evaluation; global-attention layers only).
    ``true_len``: traced real-token count for bucketed prefill (pad-masked
    SSM scan; see :func:`_apply_layer_train`).
    """
    layout = cache_positions(cfg)
    x, positions, positions3 = _build_embeds(params, cfg, tokens, patches)
    cross_blocks = cross_tail = None
    if cfg.cross_attention and frames is not None:
        enc_out = encode_audio(params, cfg, frames)
        cross_blocks, cross_tail = _cross_caches(params, cfg, enc_out)
    aux0 = jnp.zeros((), jnp.float32)

    def period_body(carry, xs):
        h, aux = carry
        pblock = xs["params"]
        cross_b = xs.get("cross")
        keeps = xs.get("kv_keep")
        extras = {}
        for p in range(layout["period"]):
            spec = layout["pspecs"][p]
            cr = None
            if cross_b is not None and f"p{p}" in cross_b:
                cr = cross_b[f"p{p}"]
            h, aux, extra = _apply_layer_train(
                pblock[f"p{p}"], cfg, spec, h, positions, aux,
                positions3=positions3, cross=cr,
                kv_keep=None if keeps is None else keeps[p],
                true_len=true_len)
            if collect_kv and extra is not None:
                extras[f"p{p}"] = extra
        return (h, aux), extras if collect_kv else None

    if remat:
        policy = jax.checkpoint_policies.save_only_these_names("tp_out")
        body = jax.checkpoint(period_body, policy=policy)
    else:
        body = period_body
    kv_blocks = None
    if layout["n_full"]:
        xs = {"params": params["blocks"]}
        if cross_blocks is not None:
            xs["cross"] = cross_blocks
        if kv_keep_masks is not None:
            n_full, period = layout["n_full"], layout["period"]
            xs["kv_keep"] = jnp.asarray(kv_keep_masks)[
                : n_full * period].reshape(n_full, period, -1)
        (x, aux), kv_blocks = jax.lax.scan(body, (x, aux0), xs)
    else:
        aux = aux0

    kv_tail = {}
    n_scanned = layout["n_full"] * layout["period"]
    for i, spec in enumerate(layout["tail_specs"]):
        cr = cross_tail.get(f"t{i}") if cross_tail else None
        x, aux, extra = _apply_layer_train(
            params["tail"][f"t{i}"], cfg, spec, x, positions, aux,
            positions3=positions3, cross=cr,
            kv_keep=None if kv_keep_masks is None
            else jnp.asarray(kv_keep_masks)[n_scanned + i],
            true_len=true_len)
        if collect_kv and extra is not None:
            kv_tail[f"t{i}"] = extra

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = shard(x @ head, "batch", "seq", "model")
    if collect_kv:
        return logits, aux, (kv_blocks, kv_tail)
    return logits, aux, None


# =========================================================================== #
# Decode state (budgeted LaCache caches + ring windows + SSM states)
# =========================================================================== #
class DecodeState(NamedTuple):
    """Typed decode-state pytree threaded through prefill / decode_step /
    decode_chunk (replaces the raw string-keyed dict).

    * ``pos``: absolute position of the next token — a scalar for dense
      (lockstep) states, a per-lane ``[b]`` vector for in-model paged
      states (each serving lane advances on its own clock),
    * ``blocks``: per-period-position layer states, leaves stacked
      ``[n_full, ...]`` for the lax.scan over periods,
    * ``tail``: per-tail-layer states (unrolled remainder layers),
    * ``cross_blocks``/``cross_tail``: static encoder cross-attention KV
      (whisper), ``None`` for decoder-only models,
    * ``kv_pool``: ``None`` for dense states; a
      :class:`repro.core.paged.PoolKV` for in-model paged states — the
      global pool's K/V planes, threaded through every layer of
      ``decode_step``/``decode_chunk`` so attention consumes block tables
      directly (refcounts and the free list stay host-side in the engine).

    NamedTuple => automatically a registered pytree with stable field-name
    key paths, so jit boundaries, sharding rules and engine code address
    fields as attributes instead of string-indexing into dicts.
    """

    pos: jnp.ndarray
    blocks: Dict[str, Any]
    tail: Dict[str, Any]
    cross_blocks: Any = None
    cross_tail: Any = None
    kv_pool: Any = None


def _empty_layer_state(cfg: ModelConfig, spec: LayerSpec, batch: int,
                       n_slots: int, dtype):
    if spec.kind == "mamba":
        return MambaState(
            conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
            ssm=jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32))
    if spec.attn == "local":
        w = max(1, cfg.sliding_window)
        return layers.init_ring_cache(batch, w, cfg.n_kv_heads, cfg.head_dim_, dtype)
    with_scores = eviction_policy(cfg).needs_scores
    return cachelib.init_cache(batch, n_slots, cfg.n_kv_heads, cfg.head_dim_,
                               dtype, with_scores=with_scores)


def init_decode_state(params, cfg: ModelConfig, batch: int, n_slots: int,
                      frames=None) -> DecodeState:
    """Empty decode state. ``n_slots`` is the per-layer cache buffer size
    (= LaCache budget B, or seq_len for the full-cache baseline)."""
    dtype = jnp.dtype(cfg.dtype)
    layout = cache_positions(cfg)

    def stack_layer(spec):
        one = _empty_layer_state(cfg, spec, batch, n_slots, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (layout["n_full"],) + x.shape),
            one)

    blocks = {f"p{p}": stack_layer(layout["pspecs"][p])
              for p in range(layout["period"])} if layout["n_full"] else {}
    tail = {f"t{i}": _empty_layer_state(cfg, s, batch, n_slots, dtype)
            for i, s in enumerate(layout["tail_specs"])}
    cb = ct = None
    if cfg.cross_attention and frames is not None:
        enc_out = encode_audio(params, cfg, frames)
        cb, ct = _cross_caches(params, cfg, enc_out)
    return DecodeState(pos=jnp.zeros((), jnp.int32), blocks=blocks, tail=tail,
                       cross_blocks=cb, cross_tail=ct)


def paged_decode_eligible(cfg: ModelConfig) -> bool:
    """Whether the in-model paged decode path supports this architecture.

    Every layer *kind* now has a paged representation, so the ladder's
    architecture-agnostic fixed-budget promise extends to the fast path:

    * global-attention layers — per-lane :class:`PagedKVCache` block tables
      (budgeted slots, compaction through the table),
    * sliding-window (ring) layers — per-lane
      :class:`~repro.core.paged.PagedRingCache` residue-class tables (the
      ``slot == pos % w`` invariant carried as per-lane metadata alongside
      the block table),
    * SSM (Mamba) layers — small dense per-lane states threaded through the
      ``kv_pool`` pytree (nothing to page, but they fork/splice/preempt/
      resume bit-exactly with the tables),

    and hybrid stacks compose the three per ``layer_specs()``. Only encoder
    cross-attention (static encoder KV shared batch-wide) and M-RoPE (2-D
    positions) remain on the store-backed fallback.
    """
    return not cfg.cross_attention and not cfg.mrope


def init_paged_decode_state(cfg: ModelConfig, batch: int, n_slots: int,
                            page_size: int, pool_kv: PoolKV,
                            alloc_fn) -> DecodeState:
    """Empty in-model paged decode state over ``pool_kv``.

    ``alloc_fn(n)`` is the engine's host-side allocator: it returns ``n``
    fresh physical block ids (refcount 1, reserved for the lifetime of this
    state). Each lane of each global-attention layer gets
    ``blocks_for(n_slots, page_size)`` reserved blocks and each lane of
    each ring layer ``blocks_for(window, page_size)`` — its copy-on-write
    destination set — so the jitted decode loop never needs an allocation.
    SSM layers carry dense per-lane states (nothing to reserve).
    """
    import numpy as _np
    layout = cache_positions(cfg)
    if not paged_decode_eligible(cfg):
        raise ValueError("in-model paged decode does not support cross-"
                         "attention or M-RoPE architectures")
    mb = pagedlib.blocks_for(n_slots, page_size)
    with_scores = eviction_policy(cfg).needs_scores
    dtype = jnp.dtype(cfg.dtype)

    def mk_kv(stack: Tuple[int, ...]) -> PagedKVCache:
        shape = stack + (batch,)
        n = int(_np.prod(shape, dtype=int)) if shape else 1
        ids = _np.asarray(alloc_fn(n * mb)).reshape(shape + (mb,))
        return PagedKVCache(
            blocks=jnp.full(shape + (mb,), -1, jnp.int32),
            owned=jnp.asarray(ids, jnp.int32),
            pos=jnp.full(shape + (n_slots,), -1, jnp.int32),
            length=jnp.zeros(shape, jnp.int32),
            scores=jnp.zeros(shape + (n_slots,), jnp.float32)
            if with_scores else None)

    def mk_ring(stack: Tuple[int, ...]) -> pagedlib.PagedRingCache:
        w = max(1, cfg.sliding_window)
        mbr = pagedlib.blocks_for(w, page_size)
        shape = stack + (batch,)
        n = int(_np.prod(shape, dtype=int)) if shape else 1
        ids = _np.asarray(alloc_fn(n * mbr)).reshape(shape + (mbr,))
        return pagedlib.PagedRingCache(
            blocks=jnp.full(shape + (mbr,), -1, jnp.int32),
            owned=jnp.asarray(ids, jnp.int32),
            pos=jnp.full(shape + (w,), -1, jnp.int32),
            next_pos=jnp.zeros(shape, jnp.int32))

    def mk_ssm(stack: Tuple[int, ...]) -> MambaState:
        shape = stack + (batch,)
        return MambaState(
            conv=jnp.zeros(shape + (cfg.d_conv - 1, cfg.d_inner), dtype),
            ssm=jnp.zeros(shape + (cfg.d_inner, cfg.d_state), jnp.float32))

    def mk(spec: LayerSpec, stack: Tuple[int, ...]):
        if spec.kind == "mamba":
            return mk_ssm(stack)
        if spec.attn == "local":
            return mk_ring(stack)
        return mk_kv(stack)

    blocks = {f"p{p}": mk(layout["pspecs"][p], (layout["n_full"],))
              for p in range(layout["period"])} if layout["n_full"] else {}
    tail = {f"t{i}": mk(s, ())
            for i, s in enumerate(layout["tail_specs"])}
    return DecodeState(pos=jnp.zeros((batch,), jnp.int32), blocks=blocks,
                       tail=tail, kv_pool=pool_kv)


def _page_in_node(kvp: PoolKV, pkc: PagedKVCache, dkc: KVCache, bs: int
                  ) -> Tuple[PoolKV, PagedKVCache]:
    """Scatter one dense (batch-1 per lane) layer cache into the lane's
    reserved blocks; the table maps exactly the occupied prefix."""
    lane_shape = pkc.length.shape
    n = 1
    for d in lane_shape:
        n *= d
    s, mb = pkc.n_slots, pkc.max_blocks
    owned = pkc.owned.reshape(n, mb)
    k = dkc.k.reshape((n, s) + dkc.k.shape[-2:])
    v = dkc.v.reshape((n, s) + dkc.v.shape[-2:])
    dlen = jnp.reshape(dkc.length, (n,))
    slot = jnp.arange(s)
    live = slot[None] < dlen[:, None]
    dstblk = jnp.take(owned, slot // bs, axis=1)             # [n, s]
    oob = kvp.n_blocks * bs
    dst = jnp.where(live, dstblk * bs + slot % bs, oob)
    kflat = pagedlib._flat_rows(kvp.k).at[dst].set(
        k.astype(kvp.k.dtype), mode="drop")
    vflat = pagedlib._flat_rows(kvp.v).at[dst].set(
        v.astype(kvp.v.dtype), mode="drop")
    blocks = jnp.where(jnp.arange(mb)[None] * bs < dlen[:, None], owned, -1)
    return (PoolKV(k=kflat.reshape(kvp.k.shape), v=vflat.reshape(kvp.v.shape)),
            pkc._replace(blocks=blocks.reshape(lane_shape + (mb,)),
                         pos=jnp.reshape(dkc.pos, lane_shape + (s,)),
                         length=dlen.reshape(lane_shape),
                         scores=None if pkc.scores is None
                         else jnp.reshape(dkc.scores, lane_shape + (s,))))


def _page_in_ring_node(kvp: PoolKV, prc: "pagedlib.PagedRingCache",
                       ring: "layers.RingKVCache", bs: int
                       ) -> Tuple[PoolKV, "pagedlib.PagedRingCache"]:
    """Scatter one dense (batch-1 per lane) ring cache into the lane's
    reserved blocks via the residue-class layout (ring slot j at pool row
    ``owned[j // bs] * bs + j % bs``). Occupied ring slots always form the
    prefix ``[0, min(next_pos, window))``, so the table maps exactly the
    blocks covering it."""
    lane_shape = prc.next_pos.shape
    n = 1
    for d in lane_shape:
        n *= d
    w, mb = prc.window, prc.max_blocks
    owned = prc.owned.reshape(n, mb)
    k = ring.k.reshape((n, w) + ring.k.shape[-2:])
    v = ring.v.reshape((n, w) + ring.v.shape[-2:])
    npos = jnp.reshape(ring.next_pos, (n,))
    occ = jnp.minimum(npos, w)
    slot = jnp.arange(w)
    live = slot[None] < occ[:, None]
    dstblk = jnp.take(owned, slot // bs, axis=1)             # [n, w]
    oob = kvp.n_blocks * bs
    dst = jnp.where(live, dstblk * bs + slot % bs, oob)
    kflat = pagedlib._flat_rows(kvp.k).at[dst].set(
        k.astype(kvp.k.dtype), mode="drop")
    vflat = pagedlib._flat_rows(kvp.v).at[dst].set(
        v.astype(kvp.v.dtype), mode="drop")
    blocks = jnp.where(jnp.arange(mb)[None] * bs < occ[:, None], owned, -1)
    # dense ring pos is batch-uniform [*, w] with lane batch 1, so the lane
    # count n equals the dense instance count and a straight reshape fits
    return (PoolKV(k=kflat.reshape(kvp.k.shape), v=vflat.reshape(kvp.v.shape)),
            prc._replace(blocks=blocks.reshape(lane_shape + (mb,)),
                         pos=jnp.reshape(ring.pos, lane_shape + (w,)),
                         next_pos=npos.reshape(lane_shape)))


def page_in_dense_state(paged_state: DecodeState, dense_state: DecodeState,
                        page_size: int) -> DecodeState:
    """Move a dense (batch-1) post-prefill state into an empty in-model
    paged state: every attention layer's K/V rows scatter into the lane's
    reserved blocks (global slots by occupied prefix, ring windows by
    residue class) and SSM states copy across dense (one traced dispatch —
    the once-per-admission cost of a cold prefill under the paged backend;
    prefix hits skip this entirely by splicing shared tables instead)."""
    def node(kvp, pnode, dnode):
        if isinstance(pnode, PagedKVCache):
            return _page_in_node(kvp, pnode, dnode, page_size)
        if isinstance(pnode, pagedlib.PagedRingCache):
            return _page_in_ring_node(kvp, pnode, dnode, page_size)
        # SSM: the dense (batch-1) state already has the lane layout
        return kvp, jax.tree.map(
            lambda p, d: jnp.reshape(d.astype(p.dtype), p.shape),
            pnode, dnode)

    kvp = paged_state.kv_pool
    blocks = {}
    for key, pkc in paged_state.blocks.items():
        kvp, blocks[key] = node(kvp, pkc, dense_state.blocks[key])
    tail = {}
    for key, pkc in paged_state.tail.items():
        kvp, tail[key] = node(kvp, pkc, dense_state.tail[key])
    pos = jnp.broadcast_to(jnp.asarray(dense_state.pos, jnp.int32).reshape(-1),
                           paged_state.pos.shape)
    return paged_state._replace(pos=pos, blocks=blocks, tail=tail,
                                kv_pool=kvp)


def _build_layer_cache_from_prefill(cfg: ModelConfig, spec: LayerSpec, extra,
                                    positions, n_slots: int, lspec, layer_ord,
                                    true_len=None):
    """Turn dense-prefill per-layer state into the decode-time state.

    ``true_len`` (traced int32, bucketed prefill): the tokens were right-
    padded to a bucket length; only the first ``true_len`` are real. Causal
    attention makes the padded forward exact for real positions, so the
    cache build just has to drop the pad entries.
    """
    dtype = jnp.dtype(cfg.dtype)
    if spec.kind == "mamba":
        return extra  # final MambaState
    k_unrot, k_rot, v = extra
    t = k_unrot.shape[1]
    batch = k_unrot.shape[0]
    if spec.attn == "local":
        w = max(1, cfg.sliding_window)
        if true_len is not None:
            # ring invariant slot == pos % w, built by residue class: slot j
            # holds the newest real position p_j ≡ j (mod w), gathered
            # dynamically because true_len is traced.
            j = jnp.arange(w)
            last = true_len - 1
            p_j = last - ((last - j) % w)
            live = p_j >= 0
            src = jnp.clip(p_j, 0, t - 1)
            gk = jnp.take(k_rot, src, axis=1).astype(dtype)
            gv = jnp.take(v, src, axis=1).astype(dtype)
            kk = jnp.where(live[None, :, None, None], gk, 0)
            vv = jnp.where(live[None, :, None, None], gv, 0)
            pos_arr = jnp.where(live, p_j, -1).astype(jnp.int32)
            return layers.RingKVCache(k=kk, v=vv, pos=pos_arr,
                                      next_pos=true_len.astype(jnp.int32))
        take = min(w, t)
        ring = layers.init_ring_cache(batch, w, cfg.n_kv_heads, cfg.head_dim_, dtype)
        kw = k_rot[:, t - take:]
        vw = v[:, t - take:]
        pos = jnp.full((w,), -1, jnp.int32).at[:take].set(
            jnp.arange(t - take, t, dtype=jnp.int32))
        # ring invariant: slot == pos % w. Rotate so entries land on their slot.
        slots = pos[:take] % w
        k = ring.k.at[:, slots].set(kw.astype(dtype))
        vv = ring.v.at[:, slots].set(vw.astype(dtype))
        pos_arr = jnp.full((w,), -1, jnp.int32).at[slots].set(pos[:take])
        return layers.RingKVCache(k=k, v=vv, pos=pos_arr,
                                  next_pos=jnp.asarray(t, jnp.int32))
    # global attention: budgeted slot cache. Keys are stored ROTATED: during
    # prefill position == slot index, so k_rot serves both rope modes; under
    # cache-relative mode compaction applies the slot-delta fixup.
    policy = eviction_policy(cfg)
    cache_rope = (cfg.pos_emb == "rope" and cfg.lacache.rope_mode == "cache"
                  and not cfg.mrope)
    n_buf = max(t, n_slots)
    c = cachelib.init_cache(batch, n_buf, cfg.n_kv_heads, cfg.head_dim_, dtype,
                            with_scores=policy.needs_scores)
    c = cachelib.append(c, k_rot, v, jnp.arange(t, dtype=jnp.int32))
    if true_len is not None:
        c = cachelib.truncate(c, true_len)
    c = cachelib.compact_to_budget(
        c, lspec, layer_ord, policy, n_slots,
        rope_theta=cfg.rope_theta if cache_rope else None)
    return cachelib.crop(c, n_slots)


def prefill(params, cfg: ModelConfig, tokens, *, n_slots: int,
            patches=None, frames=None, true_len=None):
    """Dense prefill: full forward, then LaCache compaction into the budget
    (paper Fig. 2: 'compact the original full KV cache'). Returns
    (last_logits [b, V], decode_state).

    ``true_len`` (traced int32 scalar) enables *bucketed* prefill: ``tokens``
    is right-padded to a bucket length and only ``tokens[:, :true_len]`` are
    real. Causality makes the forward exact for real positions; the cache
    build drops pad entries (global slots via :func:`cachelib.truncate`,
    ring windows by residue-class gather), and SSM layers run the
    pad-masked scan (``dt`` zeroed past ``true_len``, conv window
    dynamic-sliced) so their final state freezes at ``true_len`` — bucketed
    prefill is exact for SSM and hybrid stacks too.
    """
    if true_len is not None:
        if patches is not None or frames is not None:
            raise ValueError("true_len (bucketed prefill) does not support "
                             "patches/frames inputs")
        true_len = jnp.asarray(true_len, jnp.int32)
    layout = cache_positions(cfg)
    lspec = ladder_spec(cfg, budget=n_slots)
    logits, _, (kv_blocks, kv_tail) = forward_train(
        params, cfg, tokens, patches=patches, frames=frames,
        collect_kv=True, remat=False, true_len=true_len)
    t_total = logits.shape[1]
    positions = jnp.arange(t_total)
    gpp = layout["gpp"]

    blocks_state = {}
    for p in range(layout["period"]):
        spec = layout["pspecs"][p]
        key = f"p{p}"
        if kv_blocks is None or key not in kv_blocks:
            continue
        extra = kv_blocks[key]  # leaves stacked [n_full, ...]
        if spec.kind == "mamba" or spec.attn == "local":
            blocks_state[key] = jax.vmap(
                lambda e: _build_layer_cache_from_prefill(
                    cfg, spec, e, positions, n_slots, lspec, 0,
                    true_len=true_len))(extra)
        else:
            rank = sum(1 for q in range(p) if layout["pspecs"][q].attn == "global")
            ords = jnp.arange(layout["n_full"]) * gpp + rank
            blocks_state[key] = jax.vmap(
                lambda e, o: _build_layer_cache_from_prefill(
                    cfg, spec, e, positions, n_slots, lspec, o,
                    true_len=true_len))(extra, ords)

    tail_state = {}
    n_tail_base = layout["n_full"] * gpp
    tr = 0
    for i, spec in enumerate(layout["tail_specs"]):
        key = f"t{i}"
        if key not in kv_tail:
            continue
        if spec.attn == "global":
            ordl = n_tail_base + tr
            tr += 1
        else:
            ordl = 0
        tail_state[key] = _build_layer_cache_from_prefill(
            cfg, spec, kv_tail[key], positions, n_slots, lspec, ordl,
            true_len=true_len)

    cb = ct = None
    if cfg.cross_attention and frames is not None:
        enc_out = encode_audio(params, cfg, frames)
        cb, ct = _cross_caches(params, cfg, enc_out)
    if true_len is None:
        last, pos = logits[:, -1], jnp.asarray(t_total, jnp.int32)
    else:
        last = jax.lax.dynamic_slice_in_dim(logits, true_len - 1, 1, 1)[:, 0]
        pos = true_len
    state = DecodeState(pos=pos, blocks=blocks_state, tail=tail_state,
                        cross_blocks=cb, cross_tail=ct)
    return last, state


# =========================================================================== #
# Decode step
# =========================================================================== #
def _state_budget(state: DecodeState) -> Optional[int]:
    """The per-layer slot-buffer size carried by the state (dense or paged);
    None when the state holds no global-attention cache."""
    for v in list(state.blocks.values()) + list(state.tail.values()):
        if isinstance(v, (KVCache, PagedKVCache)):
            return v.n_slots
    return None


def _apply_layer_decode(p, cfg: ModelConfig, spec: LayerSpec, x, st, *,
                        lspec, layer_ord, policy, true_pos, cross=None,
                        kvp=None):
    """Returns (x, st, kvp): paged layer states additionally thread the
    shared pool planes through the layer (dense states pass them along)."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    if spec.kind == "mamba":
        y, st = layers.mamba_decode(p["mamba"], cfg, h, st)
        x = x + y
    elif spec.attn == "local":
        if isinstance(st, pagedlib.PagedRingCache):
            y, st, kvp = layers.attention_decode_ring_paged(
                p["attn"], cfg, h, st, kvp, window=cfg.sliding_window)
        else:
            y, st = layers.attention_decode_ring(
                p["attn"], cfg, h, st, window=cfg.sliding_window)
        x = x + y
    elif isinstance(st, PagedKVCache):
        y, st, kvp = layers.attention_decode_paged(
            p["attn"], cfg, h, st, kvp, spec=lspec, layer_ord=layer_ord,
            policy=policy, true_pos=true_pos)
        x = x + y
    else:
        y, st = layers.attention_decode(
            p["attn"], cfg, h, st, spec=lspec, layer_ord=layer_ord,
            policy=policy, true_pos=true_pos)
        x = x + y
    if cross is not None and "cross" in p:
        hc = rms_norm(x, p["cross_norm"], cfg.norm_eps)
        x = x + layers.cross_attention(p["cross"], cfg, hc, cross)
    x, _ = _apply_ffn(p, cfg, x, jnp.zeros((), jnp.float32))
    return x, st, kvp


def decode_step(params, cfg: ModelConfig, state: DecodeState, tokens
                ) -> Tuple[jnp.ndarray, DecodeState]:
    """One autoregressive step: tokens [b, 1] -> (logits [b, V], state).

    Runs LaCache iterative compaction in-step (lax.cond inside each layer)
    whenever a layer's budget is full — the paper's Sec. 3.3 mechanism.

    With ``state.kv_pool`` set (in-model paged decode), layer caches are
    per-lane block tables into the shared pool: attention dispatches to the
    paged kernel, compaction rewrites tables in place, and ``state.pos`` is
    a per-lane ``[b]`` vector so ragged serving batches decode in ONE call
    instead of a per-lane vmap (the pool is shared, so lanes cannot be
    vmapped without duplicating it).
    """
    layout = cache_positions(cfg)
    lspec = ladder_spec(cfg)
    policy = eviction_policy(cfg)
    budget = _state_budget(state)
    if budget is not None:
        # compaction still *triggers* on buffer overflow (n_slots), but it
        # keeps to the configured ladder budget when the buffer is larger:
        # extra engine slots are decode headroom between compactions, not a
        # silent raise of the ladder budget. (Clamping down is still
        # required when the buffer is smaller than the configured budget —
        # the keep set must fit the buffer.)
        lspec = lspec._replace(budget=min(lspec.budget, budget))
    paged = state.kv_pool is not None
    pos = state.pos                        # scalar (dense) or [b] (paged)
    x = _embed_tokens(params, cfg, tokens)
    if cfg.pos_emb == "abs":
        if paged:
            rows = jax.vmap(lambda p_: _sinusoid_at(p_, cfg.d_model))(pos)
            x = x + rows[:, None].astype(x.dtype)
        else:
            x = x + _sinusoid_at(pos, cfg.d_model).astype(x.dtype)[None, None]
    gpp = layout["gpp"]

    kvp = state.kv_pool
    new_blocks = state.blocks
    if layout["n_full"]:
        def body(carry, xs):
            h, kvp = carry
            pblock, caches, pidx = xs["params"], xs["caches"], xs["idx"]
            cross_b = xs.get("cross")
            new_caches = {}
            for p in range(layout["period"]):
                spec = layout["pspecs"][p]
                key = f"p{p}"
                st = caches.get(key)
                rank = sum(1 for q in range(p)
                           if layout["pspecs"][q].attn == "global")
                ordl = pidx * gpp + rank
                cr = cross_b.get(key) if cross_b else None
                h, st_new, kvp = _apply_layer_decode(
                    pblock[key], cfg, spec, h, st, lspec=lspec,
                    layer_ord=ordl, policy=policy, true_pos=pos, cross=cr,
                    kvp=kvp)
                if st is not None:
                    new_caches[key] = st_new
            return (h, kvp), new_caches

        xs = {"params": params["blocks"], "caches": state.blocks,
              "idx": jnp.arange(layout["n_full"])}
        if state.cross_blocks is not None:
            xs["cross"] = state.cross_blocks
        (x, kvp), new_blocks = jax.lax.scan(body, (x, kvp), xs)

    n_tail_base = layout["n_full"] * gpp
    tr = 0
    new_tail = {}
    for i, spec in enumerate(layout["tail_specs"]):
        key = f"t{i}"
        st = state.tail.get(key)
        if spec.attn == "global":
            ordl = n_tail_base + tr
            tr += 1
        else:
            ordl = 0
        cr = (state.cross_tail or {}).get(key)
        x, st_new, kvp = _apply_layer_decode(
            params["tail"][key], cfg, spec, x, st, lspec=lspec,
            layer_ord=ordl, policy=policy, true_pos=pos, cross=cr, kvp=kvp)
        if st is not None:
            new_tail[key] = st_new

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = shard(x @ head, "batch", "seq", "model")
    new_state = state._replace(pos=pos + 1, blocks=new_blocks,
                               tail=new_tail, kv_pool=kvp)
    return logits[:, 0], new_state


def _sinusoid_at(pos, d_model: int):
    import math as _m
    log_timescale = _m.log(10000.0) / max(1, d_model // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(d_model // 2, dtype=jnp.float32))
    scaled = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)])


# =========================================================================== #
# Loss
# =========================================================================== #
def lm_loss(logits, targets, mask=None):
    """Next-token cross entropy; logits [b, t, V], targets [b, t]."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# =========================================================================== #
# Chunked decode: streaming prefill / scoring (paper's PG19 sliding window)
# =========================================================================== #
def decode_chunk(params, cfg: ModelConfig, state: DecodeState, tokens
                 ) -> Tuple[jnp.ndarray, DecodeState]:
    """Process T tokens against the budgeted caches in one pass:
    tokens [b, T] -> (logits [b, T, V], state). Each token sees the whole
    compacted past plus the chunk prefix — identical semantics to T calls of
    decode_step (exactly equal when no compaction fires mid-chunk; otherwise
    compaction is amortized once per chunk, the paper's window setting).
    O(budget * T) attention instead of O(T^2) dense prefill."""
    layout = cache_positions(cfg)
    lspec = ladder_spec(cfg)
    policy = eviction_policy(cfg)
    budget = _state_budget(state)
    if budget is not None:
        # same headroom rule as decode_step: overflow-triggered, but keep
        # to the configured ladder budget when the buffer is larger
        lspec = lspec._replace(budget=min(lspec.budget, budget))
    paged = state.kv_pool is not None
    pos0 = state.pos                       # scalar (dense) or [b] (paged)
    tc = tokens.shape[1]
    x = _embed_tokens(params, cfg, tokens)
    if cfg.pos_emb == "abs":
        if paged:
            rows = jax.vmap(lambda p: jax.vmap(
                lambda q: _sinusoid_at(q, cfg.d_model))(p + jnp.arange(tc))
                )(pos0)
            x = x + rows.astype(x.dtype)
        else:
            rows = jax.vmap(lambda p: _sinusoid_at(p, cfg.d_model))(
                pos0 + jnp.arange(tc))
            x = x + rows[None].astype(x.dtype)
    gpp = layout["gpp"]

    def apply_one(p, spec, h, st, ordl, cross, kvp):
        hh = rms_norm(h, p["norm"], cfg.norm_eps)
        if spec.kind == "mamba":
            y, st = layers.mamba_chunk(p["mamba"], cfg, hh, st)
        elif spec.attn == "local":
            if isinstance(st, pagedlib.PagedRingCache):
                y, st, kvp = layers.ring_chunk_paged(
                    p["attn"], cfg, hh, st, kvp, window=cfg.sliding_window)
            else:
                y, st = layers.ring_chunk(p["attn"], cfg, hh, st,
                                          window=cfg.sliding_window)
        elif isinstance(st, PagedKVCache):
            y, st, kvp = layers.attention_decode_chunk_paged(
                p["attn"], cfg, hh, st, kvp, spec=lspec, layer_ord=ordl,
                policy=policy, start_pos=pos0)
        else:
            y, st = layers.attention_decode_chunk(
                p["attn"], cfg, hh, st, spec=lspec, layer_ord=ordl,
                policy=policy, start_pos=pos0)
        h = h + y
        if cross is not None and "cross" in p:
            hc = rms_norm(h, p["cross_norm"], cfg.norm_eps)
            h = h + layers.cross_attention(p["cross"], cfg, hc, cross)
        h, _ = _apply_ffn(p, cfg, h, jnp.zeros((), jnp.float32))
        return h, st, kvp

    kvp = state.kv_pool
    new_blocks = state.blocks
    if layout["n_full"]:
        def body(carry, xs):
            h, kvp = carry
            pblock, caches, pidx = xs["params"], xs["caches"], xs["idx"]
            cross_b = xs.get("cross")
            new_caches = {}
            for p in range(layout["period"]):
                spec = layout["pspecs"][p]
                key = f"p{p}"
                st = caches.get(key)
                rank = sum(1 for qq in range(p)
                           if layout["pspecs"][qq].attn == "global")
                ordl = pidx * gpp + rank
                cr = cross_b.get(key) if cross_b else None
                h, st_new, kvp = apply_one(pblock[key], spec, h, st, ordl,
                                           cr, kvp)
                if st is not None:
                    new_caches[key] = st_new
            return (h, kvp), new_caches

        xs = {"params": params["blocks"], "caches": state.blocks,
              "idx": jnp.arange(layout["n_full"])}
        if state.cross_blocks is not None:
            xs["cross"] = state.cross_blocks
        (x, kvp), new_blocks = jax.lax.scan(body, (x, kvp), xs)

    n_tail_base = layout["n_full"] * gpp
    tr = 0
    new_tail = {}
    for i, spec in enumerate(layout["tail_specs"]):
        key = f"t{i}"
        st = state.tail.get(key)
        ordl = n_tail_base + tr if spec.attn == "global" else 0
        if spec.attn == "global":
            tr += 1
        cr = (state.cross_tail or {}).get(key)
        x, st_new, kvp = apply_one(params["tail"][key], spec, x, st, ordl,
                                   cr, kvp)
        if st is not None:
            new_tail[key] = st_new

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = shard(x @ head, "batch", "seq", "model")
    new_state = state._replace(pos=pos0 + tc, blocks=new_blocks,
                               tail=new_tail, kv_pool=kvp)
    return logits, new_state


# =========================================================================== #
# Self-speculative decoding: ladder-compacted draft fork / rollback
# =========================================================================== #
def spec_decode_eligible(cfg: ModelConfig) -> bool:
    """Whether the self-speculative draft/verify loop supports this config.

    The draft decodes through a compacted fork of the live block tables and
    the target verifies ``k`` tokens in one chunk, then *rolls back* the
    rejected suffix. Rollback is only exact for global-attention paged
    caches (unmapping the newest slots restores the prior state bit-exactly):

    * ring layers overwrite old rows in place (``slot = pos % w``) — the
      overwritten content is gone, so a rejected token cannot be rewound,
    * SSM layers advance a recurrence — no inverse step exists,
    * score-carrying policies accumulate observations per dispatch, so a
      chunked verify would diverge from the stepwise score trajectory even
      when every token is accepted.

    Those configs simply run the normal stepwise decode (the engine falls
    back transparently; spec == non-spec trivially).
    """
    if not paged_decode_eligible(cfg):
        return False
    if any(s.kind != "attn" or s.attn != "global" for s in cfg.layer_specs()):
        return False
    return not eviction_policy(cfg).needs_scores


def fork_draft_state(cfg: ModelConfig, state: DecodeState, planes: PoolKV,
                     draft_owned: Dict[str, jnp.ndarray], draft_budget: int,
                     page_size: int,
                     draft_slots: Optional[int] = None) -> DecodeState:
    """Fork the live paged state into a ladder-compacted draft view.

    ``state`` is the live batched decode state *without* its pool planes
    (the caller moves them in via ``planes`` so they can be donated);
    ``draft_owned[key]`` is the draft's own fully-covering block
    reservation for kv leaf ``key`` (same shape as that leaf's ``owned``).
    Every lane is compacted down to ``draft_budget`` live slots with the
    standard keep-mask + RoPE slot-delta fixup and its surviving rows are
    *copied* into ``draft_owned`` — even lanes already under the draft
    budget, which keep all their rows. The resulting draft view never
    aliases a live block, so it can outlive this wave: the caller may keep
    decoding through it across many draft/verify waves (rolling back the
    rejected suffix each time) without holding refcounts on the live
    tables, and live appends/compactions can never corrupt it. The live
    tables are never written.

    ``draft_slots`` (page-aligned, ``>= draft_budget + the appends the
    draft will absorb``) trims the draft's slot buffers to that width.
    This is where the draft actually gets *cheap*: paged attention
    gathers and masks over the full slot buffer regardless of occupancy,
    so a compacted draft at live width pays live-width attention — the
    trimmed state gives the draft decode step its own small executable
    whose attention cost scales with ``draft_slots``, not the live
    ``n_slots``. Compaction has already packed survivors into the slot
    prefix (dead table entries are ``-1``), so the trim is a static slice
    of table/pos/score leaves.
    """
    if not spec_decode_eligible(cfg):
        raise ValueError("config is not spec-decode eligible")
    layout = cache_positions(cfg)
    policy = eviction_policy(cfg)
    dspec = ladder_spec(cfg)._replace(budget=draft_budget)
    cache_rope = (cfg.pos_emb == "rope" and cfg.lacache.rope_mode == "cache"
                  and not cfg.mrope)
    theta = cfg.rope_theta if cache_rope else None
    gpp = layout["gpp"]

    kvp = planes
    new_blocks = {}
    if layout["n_full"]:
        def body(carry, xs):
            kvp = carry
            caches, owned, pidx = xs["caches"], xs["owned"], xs["idx"]
            out = {}
            for p in range(layout["period"]):
                key = f"p{p}"
                rank = sum(1 for q in range(p)
                           if layout["pspecs"][q].attn == "global")
                ordl = pidx * gpp + rank
                st = caches[key]._replace(owned=owned[key])
                kvp, st = pagedlib.paged_draft_compact(
                    kvp, st, dspec, ordl, policy, rope_theta=theta)
                out[key] = st
            return kvp, out

        xs = {"caches": state.blocks,
              "owned": {k: draft_owned[k] for k in state.blocks},
              "idx": jnp.arange(layout["n_full"])}
        kvp, new_blocks = jax.lax.scan(body, kvp, xs)

    n_tail_base = layout["n_full"] * gpp
    new_tail = {}
    for i in range(len(layout["tail_specs"])):
        key = f"t{i}"
        st = state.tail[key]._replace(owned=draft_owned[key])
        kvp, st = pagedlib.paged_draft_compact(
            kvp, st, dspec, n_tail_base + i, policy, rope_theta=theta)
        new_tail[key] = st

    if draft_slots is not None:
        if draft_slots % page_size:
            raise ValueError(f"draft_slots={draft_slots} must be a multiple "
                             f"of the page size {page_size}")
        nb = draft_slots // page_size

        def trim(st):
            if draft_slots >= st.n_slots:
                return st
            return st._replace(
                blocks=st.blocks[..., :nb], owned=st.owned[..., :nb],
                pos=st.pos[..., :draft_slots],
                scores=None if st.scores is None
                else st.scores[..., :draft_slots])

        new_blocks = {k: trim(v) for k, v in new_blocks.items()}
        new_tail = {k: trim(v) for k, v in new_tail.items()}

    # `pos + 0` forces a fresh buffer: the draft state is donated into the
    # subsequent draft decode steps, so none of its leaves may alias a
    # buffer the live state (held host-side meanwhile) still references.
    return state._replace(pos=state.pos + 0, blocks=new_blocks,
                          tail=new_tail, kv_pool=kvp)


def spec_rollback_state(cfg: ModelConfig, state: DecodeState, drop,
                        page_size: int) -> DecodeState:
    """Rewind the newest ``drop[b]`` tokens of every kv leaf (metadata-only
    unmap via :func:`repro.core.paged.paged_rollback`) and the per-lane
    clock — the commit step after verify rejects a speculative suffix."""
    def roll(leaf):
        if isinstance(leaf, PagedKVCache):
            return pagedlib.paged_rollback(leaf, drop, page_size)
        return leaf

    drop = jnp.asarray(drop, jnp.int32)
    return state._replace(
        pos=jnp.maximum(state.pos - drop, 0),
        blocks={k: roll(v) for k, v in state.blocks.items()},
        tail={k: roll(v) for k, v in state.tail.items()})
