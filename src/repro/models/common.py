"""Shared model components: norms, rotary embeddings (RoPE / M-RoPE / abs),
parameter initialization with attached logical sharding axes."""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Param = Tuple[jnp.ndarray, Tuple[Optional[str], ...]]  # (value, logical axes)


def is_param(x) -> bool:
    return (isinstance(x, tuple) and len(x) == 2
            and isinstance(x[1], tuple)
            and all(a is None or isinstance(a, str) for a in x[1]))


def split_params(tree):
    """Split a {(value, axes)} tree into (values, axes) trees."""
    values = jax.tree.map(lambda p: p[0], tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p[1], tree, is_leaf=is_param)
    return values, axes


import contextlib as _contextlib

_ABSTRACT = [False]


@_contextlib.contextmanager
def abstract_init():
    """Make param initializers emit ShapeDtypeStructs (dry-run: no alloc)."""
    _ABSTRACT.append(True)
    try:
        yield
    finally:
        _ABSTRACT.pop()


def _make(fn, shape, dtype):
    if _ABSTRACT[-1]:
        return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
    return fn()


def normal(key, shape, axes, scale=0.02, dtype=jnp.float32) -> Param:
    return (_make(lambda: scale * jax.random.normal(key, shape, dtype),
                  shape, dtype), axes)


def zeros(shape, axes, dtype=jnp.float32) -> Param:
    return (_make(lambda: jnp.zeros(shape, dtype), shape, dtype), axes)


def ones(shape, axes, dtype=jnp.float32) -> Param:
    return (_make(lambda: jnp.ones(shape, dtype), shape, dtype), axes)


def const(fn, shape, axes, dtype=jnp.float32) -> Param:
    """Computed-constant parameter (e.g. Mamba A_log) — abstract-safe."""
    return (_make(lambda: fn().astype(dtype), shape, dtype), axes)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Rotary embeddings
# --------------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., t, heads, head_dim]; positions: broadcastable to [..., t]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # [d/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., t, d/2]
    cos = jnp.cos(angles)[..., None, :]                     # [..., t, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Sequence[int]) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: the d/2 frequency slots are split into (temporal,
    height, width) sections, each rotated by its own position component.

    x: [b, t, h, d]; positions3: [b, t, 3] (text tokens: all components equal).
    """
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # [d/2]
    assert sum(sections) == d // 2, (sections, d)
    comp = []
    for i, s in enumerate(sections):
        comp += [i] * s
    comp = jnp.array(comp)                                  # [d/2] -> component id
    idx = jnp.broadcast_to(
        comp[None, None, :], (positions3.shape[0], positions3.shape[1], d // 2))
    pos = jnp.take_along_axis(positions3.astype(jnp.float32), idx, axis=-1)
    angles = pos * freqs[None, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d_model: int) -> jnp.ndarray:
    """Whisper-style sinusoidal absolute position embeddings."""
    log_timescale = math.log(10000.0) / max(1, d_model // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(d_model // 2, dtype=jnp.float32))
    scaled = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]
