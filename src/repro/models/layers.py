"""Transformer sub-layers: GQA/MQA attention (train / prefill / decode with
budgeted LaCache slots), sliding-window ring caches, SwiGLU MLP, top-k MoE
(GShard-style capacity dispatch), Mamba-1 mixer, cross-attention (whisper)."""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.core import cache as cachelib
from repro.core import paged as pagedlib
from repro.core.cache import CrossKVCache, KVCache, MambaState
from repro.core.ladder import LadderSpec
from repro.core.policy import PolicyLike, get_policy
from repro.kernels import ops as kops
from repro.launch.axes import shard
from repro.models import common
from repro.models.common import activation, normal, ones, rms_norm, zeros


# =========================================================================== #
# Ring cache for sliding-window (local) attention layers
# =========================================================================== #
class RingKVCache(NamedTuple):
    k: jnp.ndarray          # [b, window, kv, hd]
    v: jnp.ndarray
    pos: jnp.ndarray        # [window] int32, -1 empty
    next_pos: jnp.ndarray   # scalar int32: global position of next token


def init_ring_cache(batch, window, kv_heads, head_dim, dtype) -> RingKVCache:
    return RingKVCache(
        k=jnp.zeros((batch, window, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, window, kv_heads, head_dim), dtype),
        pos=jnp.full((window,), -1, jnp.int32),
        next_pos=jnp.zeros((), jnp.int32))


def ring_append(c: RingKVCache, k_new, v_new) -> RingKVCache:
    """Append one token at slot ``next_pos % window``."""
    w = c.k.shape[1]
    slot = c.next_pos % w
    k = jax.lax.dynamic_update_slice(c.k, k_new.astype(c.k.dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(c.v, v_new.astype(c.v.dtype), (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(c.pos, c.next_pos[None], (slot,))
    return RingKVCache(k, v, pos, c.next_pos + 1)


# =========================================================================== #
# Attention
# =========================================================================== #
def init_attention(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    p = {
        "wq": normal(ks[0], (d, h * hd), ("fsdp", "model"), sc, dtype),
        "wk": normal(ks[1], (d, kv * hd), ("fsdp", "model"), sc, dtype),
        "wv": normal(ks[2], (d, kv * hd), ("fsdp", "model"), sc, dtype),
        "wo": normal(ks[3], (h * hd, d), ("model", "fsdp"), sc / math.sqrt(2 * cfg.n_layers), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((h * hd,), ("model",), dtype)
        p["bk"] = zeros((kv * hd,), ("model",), dtype)
        p["bv"] = zeros((kv * hd,), ("model",), dtype)
    return p


def _boundary_matmul(cfg: ModelConfig, x, w):
    """TP-boundary projection; optionally bf16-accumulated so the SPMD
    partial-sum collective moves bf16 instead of f32 (§Perf iter 2d)."""
    if cfg.bf16_boundary_accum and x.dtype == jnp.bfloat16:
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.bfloat16)
    return x @ w


def _qkv(w, cfg: ModelConfig, x):
    b, t, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = x @ w["wq"]
    k = x @ w["wk"]
    v = x @ w["wv"]
    if cfg.qkv_bias:
        q, k, v = q + w["bq"], k + w["bk"], v + w["bv"]
    q = shard(q.reshape(b, t, h, hd), "batch", "seq", "model", None)
    k = shard(k.reshape(b, t, kv, hd), "batch", "seq", "kv", None)
    v = shard(v.reshape(b, t, kv, hd), "batch", "seq", "kv", None)
    return q, k, v


def _rope_q(cfg: ModelConfig, q, positions, positions3=None):
    if cfg.pos_emb != "rope":
        return q
    if cfg.mrope and positions3 is not None:
        return common.apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
    return common.apply_rope(q, positions, cfg.rope_theta)


def attention_train(w, cfg: ModelConfig, x, positions, *, window: int = 0,
                    positions3=None, impl: Optional[str] = None):
    """Full-sequence causal attention (train / dense prefill). Returns (y, (k, v))."""
    b, t, _ = x.shape
    q, k, v = _qkv(w, cfg, x)
    q = _rope_q(cfg, q, positions, positions3)
    k_rot = _rope_q(cfg, k, positions, positions3)
    o = kops.flash_attention(q, k_rot, v, causal=True, window=window, impl=impl)
    o = shard(o, "batch", "seq", "model", None)
    y = _boundary_matmul(cfg, o.reshape(b, t, -1), w["wo"])
    # saved across remat: backward must not re-run the TP all-reduce (§Perf 2)
    y = checkpoint_name(y, "tp_out")
    return shard(y, "batch", "res_seq", "residual"), (k, k_rot, v)


def attention_decode(w, cfg: ModelConfig, x, kv_cache: KVCache, *,
                     spec: LadderSpec, layer_ord, policy: PolicyLike,
                     true_pos, impl: Optional[str] = None
                     ) -> Tuple[jnp.ndarray, KVCache]:
    """Single-token decode against a budgeted (LaCache) slot cache.

    rope_mode "cache": K stored rotated by its *slot* index; compaction
    re-rotates moved keys by the slot delta (cache.compact rope_theta) —
    cache-relative positions (stable beyond the pre-training window) without
    the O(budget) re-rotation every step (§Perf iter 1c).
    rope_mode "original": K stored rotated by true positions.
    """
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    policy = get_policy(policy)
    rope_mode = cfg.lacache.rope_mode
    cache_rope = (cfg.pos_emb == "rope" and rope_mode == "cache"
                  and not cfg.mrope)
    q, k_new, v_new = _qkv(w, cfg, x)           # t == 1

    kv_cache = cachelib.maybe_compact(
        kv_cache, spec, layer_ord, policy, 1,
        rope_theta=cfg.rope_theta if cache_rope else None)
    if cfg.pos_emb == "rope":
        if cache_rope:
            slot = kv_cache.length               # append target slot
            k_store = common.apply_rope(k_new, slot[None, None], cfg.rope_theta)
            qq = common.apply_rope(q, slot[None, None], cfg.rope_theta)
        else:
            k_store = _rope_q(cfg, k_new, jnp.asarray(true_pos)[None, None])
            qq = _rope_q(cfg, q, jnp.asarray(true_pos)[None, None])
    else:
        k_store, qq = k_new, q
    kv_cache = cachelib.append(kv_cache, k_store, v_new,
                               jnp.asarray(true_pos, jnp.int32)[None])
    keys = kv_cache.k

    if policy.needs_scores:
        o, probs = kops.decode_attention(qq[:, 0], keys, kv_cache.v,
                                         kv_cache.length, return_probs=True)
        kv_cache = policy.observe(kv_cache, probs)
    else:
        o = kops.decode_attention(qq[:, 0], keys, kv_cache.v, kv_cache.length,
                                  impl=impl)
    y = o.reshape(b, 1, h * hd) @ w["wo"]
    return shard(y, "batch", "seq", "residual"), kv_cache


def attention_decode_paged(w, cfg: ModelConfig, x, st: "pagedlib.PagedKVCache",
                           kvp: "pagedlib.PoolKV", *, spec: LadderSpec,
                           layer_ord, policy: PolicyLike, true_pos,
                           impl: Optional[str] = None):
    """Single-token decode against an *in-model paged* slot cache.

    The lane-batched twin of :func:`attention_decode`: ``st`` holds per-lane
    block tables into the shared pool planes ``kvp``; compaction rewrites
    the table (with the cache-relative RoPE slot-delta fixup applied through
    pool-row gather/scatter) and the append copy-on-writes shared blocks
    into the lane's reserved set — no dense working copy is ever gathered in
    this path; attention consumes the table via
    :func:`repro.kernels.ops.paged_decode_attention`.

    ``true_pos``: per-lane absolute positions [b] (each lane advances on its
    own clock). Returns (y, st, kvp).
    """
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim_
    policy = get_policy(policy)
    cache_rope = (cfg.pos_emb == "rope" and cfg.lacache.rope_mode == "cache"
                  and not cfg.mrope)
    q, k_new, v_new = _qkv(w, cfg, x)           # t == 1

    kvp, st = pagedlib.paged_maybe_compact(
        kvp, st, spec, layer_ord, policy, 1,
        rope_theta=cfg.rope_theta if cache_rope else None)
    true_pos = jnp.asarray(true_pos, jnp.int32).reshape(-1)   # [b]
    if cfg.pos_emb == "rope":
        if cache_rope:
            slots = st.length[:, None]          # per-lane append target slot
            k_store = common.apply_rope(k_new, slots, cfg.rope_theta)
            qq = common.apply_rope(q, slots, cfg.rope_theta)
        else:
            k_store = common.apply_rope(k_new, true_pos[:, None],
                                        cfg.rope_theta)
            qq = common.apply_rope(q, true_pos[:, None], cfg.rope_theta)
    else:
        k_store, qq = k_new, q
    kvp, st = pagedlib.paged_append(kvp, st, k_store, v_new,
                                    true_pos[:, None])

    if policy.needs_scores:
        o, probs = kops.paged_decode_attention(
            qq[:, 0], kvp.k, kvp.v, st.blocks, st.length,
            n_slots=st.n_slots, return_probs=True)
        st = pagedlib.paged_observe(policy, st, probs)
    else:
        o = kops.paged_decode_attention(
            qq[:, 0], kvp.k, kvp.v, st.blocks, st.length,
            n_slots=st.n_slots, impl=impl)
    y = o.reshape(b, 1, h * hd) @ w["wo"]
    return shard(y, "batch", "seq", "residual"), st, kvp


def attention_decode_ring(w, cfg: ModelConfig, x, ring: RingKVCache, *,
                          window: int, impl: Optional[str] = None
                          ) -> Tuple[jnp.ndarray, RingKVCache]:
    """Single-token decode for sliding-window (local) layers: ring buffer."""
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim_
    q, k_new, v_new = _qkv(w, cfg, x)
    true_pos = ring.next_pos
    k_rot = common.apply_rope(k_new, true_pos[None, None], cfg.rope_theta) \
        if cfg.pos_emb == "rope" else k_new
    ring = ring_append(ring, k_rot, v_new)
    qq = common.apply_rope(q, true_pos[None, None], cfg.rope_theta) \
        if cfg.pos_emb == "rope" else q
    from repro.kernels import ref as kref
    # validity: stored position within the window and occupied (the shared
    # predicate the paged ring oracle also consumes)
    valid = kref.ring_valid_mask(ring.pos, ring.next_pos, window)
    o = kref.mha_reference(qq, ring.k, ring.v, causal=False, kv_valid=valid)
    y = o.reshape(b, 1, h * hd) @ w["wo"]
    return shard(y, "batch", "seq", "residual"), ring


def attention_decode_ring_paged(w, cfg: ModelConfig, x,
                                st: "pagedlib.PagedRingCache",
                                kvp: "pagedlib.PoolKV", *, window: int,
                                impl: Optional[str] = None):
    """Single-token sliding-window decode against an *in-model paged* ring.

    The lane-batched twin of :func:`attention_decode_ring`: the ring's K/V
    rows live in the shared pool behind a residue-class block table
    (:class:`repro.core.paged.PagedRingCache`), the append copy-on-writes
    shared blocks into the lane's reserved set, and attention dispatches
    through :func:`repro.kernels.ops.paged_ring_decode_attention`. Each
    lane advances on its own ``next_pos`` clock. Returns (y, st, kvp).
    """
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim_
    q, k_new, v_new = _qkv(w, cfg, x)
    true_pos = st.next_pos                                    # [b]
    if cfg.pos_emb == "rope":
        k_rot = common.apply_rope(k_new, true_pos[:, None], cfg.rope_theta)
        qq = common.apply_rope(q, true_pos[:, None], cfg.rope_theta)
    else:
        k_rot, qq = k_new, q
    kvp, st = pagedlib.paged_ring_append(kvp, st, k_rot, v_new)
    o = kops.paged_ring_decode_attention(
        qq[:, 0], kvp.k, kvp.v, st.blocks, st.pos, st.next_pos,
        window=window, impl=impl)
    y = o.reshape(b, 1, h * hd) @ w["wo"]
    return shard(y, "batch", "seq", "residual"), st, kvp


def init_cross_attention(key, cfg: ModelConfig, dtype):
    return init_attention(key, cfg, dtype)


def cross_attention(w, cfg: ModelConfig, x, cross: CrossKVCache,
                    impl: Optional[str] = None):
    """Decoder cross-attention over static encoder KV (whisper)."""
    b, t, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    q = (x @ w["wq"] + (w["bq"] if cfg.qkv_bias else 0.0)).reshape(b, t, h, hd)
    o = kops.flash_attention(q, cross.k, cross.v, causal=False, impl=impl)
    y = o.reshape(b, t, h * hd) @ w["wo"]
    return shard(y, "batch", "seq", "residual")


def encode_cross_kv(w, cfg: ModelConfig, enc_out) -> CrossKVCache:
    b, t, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    k = (enc_out @ w["wk"] + (w["bk"] if cfg.qkv_bias else 0.0)).reshape(b, t, kv, hd)
    v = (enc_out @ w["wv"] + (w["bv"] if cfg.qkv_bias else 0.0)).reshape(b, t, kv, hd)
    return CrossKVCache(k=k, v=v)


# =========================================================================== #
# MLP / MoE
# =========================================================================== #
def init_mlp(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    sc = 1.0 / math.sqrt(d)
    p = {"w_up": normal(ks[0], (d, f), ("fsdp", "model"), sc, dtype),
         "w_down": normal(ks[1], (f, d), ("model", "fsdp"),
                          sc / math.sqrt(2 * cfg.n_layers), dtype)}
    if cfg.mlp_gated:
        p["w_gate"] = normal(ks[2], (d, f), ("fsdp", "model"), sc, dtype)
    return p


def mlp(w, cfg: ModelConfig, x):
    act = activation(cfg.act)
    h = x @ w["w_up"]
    if cfg.mlp_gated:
        h = act(x @ w["w_gate"]) * h
    else:
        h = act(h)
    h = shard(h, "batch", "seq", "model")
    y = checkpoint_name(_boundary_matmul(cfg, h, w["w_down"]), "tp_out")
    return shard(y, "batch", "res_seq", "residual")


def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    # expert-parallel when E >= 16, else tensor-parallel inside experts.
    # "moe_dm"/"moe_ff" are mode-dependent logical axes: training shards the
    # d_model dim FSDP-style; serving shards d_ff instead so the row-parallel
    # partial-sum lands on the small (e_loc, C, d) tensor (§Perf iter 1e).
    ep = e >= 16
    ax_e = "experts" if ep else None
    ax_f = "moe_ff" if ep else "model"
    return {
        "router": normal(ks[0], (d, e), ("fsdp", None), sc, jnp.float32),
        "w_up": normal(ks[1], (e, d, f), (ax_e, "moe_dm", ax_f), sc, dtype),
        "w_gate": normal(ks[2], (e, d, f), (ax_e, "moe_dm", ax_f), sc, dtype),
        "w_down": normal(ks[3], (e, f, d), (ax_e, ax_f, "moe_dm"),
                         sc / math.sqrt(2 * cfg.n_layers), dtype),
    }


def moe_ffn(w, cfg: ModelConfig, x, *, group_size: Optional[int] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k MoE with GShard-style grouped capacity dispatch.

    Tokens are folded into groups of ``group_size``; within each group, each
    expert processes at most C = ceil(cf * k * S / E) tokens (overflow drops —
    standard GShard semantics). Returns (y, aux_loss).
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    act = activation(cfg.act)
    group_size = group_size or cfg.moe_group_size
    if t >= group_size and t % group_size == 0:
        s = group_size
    else:
        s = t
    g = (b * t) // s
    xg = x.reshape(g, s, d)
    xg = shard(xg, "batch", None, None)

    logits = (xg.astype(jnp.float32) @ w["router"])          # [g, s, e]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # [g, s, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    cap = int(math.ceil(cfg.capacity_factor * k * s / e))
    cap = min(cap, s)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)       # [g, s, k, e]
    # position of each (token, choice) within its expert queue
    pos = jnp.cumsum(onehot.reshape(g, s * k, e), axis=1).reshape(g, s, k, e)
    pos = pos * onehot - 1.0
    keep = (pos >= 0) & (pos < cap)
    onehot = onehot * keep
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32) \
        * onehot[..., None]                                  # [g, s, k, e, c]
    dispatch = pos_oh.sum(axis=2)                            # [g, s, e, c]
    combine = (pos_oh * gate[..., None, None]).sum(axis=2)   # [g, s, e, c]

    xin = jnp.einsum("gsec,gsd->gecd", dispatch.astype(xg.dtype), xg)
    xin = shard(xin, "batch", "experts", None, None)
    hg = jnp.einsum("gecd,edf->gecf", xin, w["w_gate"])
    hu = jnp.einsum("gecd,edf->gecf", xin, w["w_up"])
    h = act(hg) * hu
    out = jnp.einsum("gecf,efd->gecd", h, w["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(xg.dtype), out)

    # switch-style load balance loss
    me = probs.mean(axis=1)                                  # [g, e]
    ce = onehot.sum(axis=2).mean(axis=1)                     # [g, e] frac routed
    aux = (me * ce).sum(axis=-1).mean() * e
    return y.reshape(b, t, d), aux


def moe_ffn_dense(w, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference all-experts dispatch (exact, E/k x FLOPs) — tests only."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    act = activation(cfg.act)
    xf = x.reshape(b * t, d)
    logits = xf.astype(jnp.float32) @ w["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    weights = jax.vmap(lambda i, g: jnp.zeros((e,), jnp.float32).at[i].set(g))(
        idx, gate)
    h = jnp.einsum("td,edf->tef", xf, w["w_gate"])
    u = jnp.einsum("td,edf->tef", xf, w["w_up"])
    o = jnp.einsum("tef,efd->ted", act(h) * u, w["w_down"])
    y = jnp.einsum("te,ted->td", weights.astype(x.dtype), o)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(axis=1)
    aux = (probs.mean(axis=0) * onehot.mean(axis=0)).sum() * e
    return y.reshape(b, t, d), aux


# =========================================================================== #
# Mamba-1 mixer
# =========================================================================== #
def init_mamba(key, cfg: ModelConfig, dtype):
    d, di, n, r, dc = (cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank_,
                       cfg.d_conv)
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)

    def _a_log():
        return jnp.log(jnp.tile(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1)))

    def _dt_bias():
        return jnp.log(jnp.expm1(jnp.clip(
            jnp.exp(jax.random.uniform(ks[5], (di,)) *
                    (math.log(0.1) - math.log(0.001)) + math.log(0.001)),
            1e-4)))

    return {
        "in_proj": normal(ks[0], (d, 2 * di), ("fsdp", "model"), sc, dtype),
        "conv_w": normal(ks[1], (dc, di), (None, "model"), 0.5, dtype),
        "conv_b": zeros((di,), ("model",), dtype),
        "x_proj": normal(ks[2], (di, r + 2 * n), ("model", None), 1.0 / math.sqrt(di), dtype),
        "dt_proj": normal(ks[3], (r, di), (None, "model"), 1.0 / math.sqrt(r), dtype),
        "dt_bias": common.const(_dt_bias, (di,), ("model",), dtype),
        "A_log": common.const(_a_log, (di, n), ("model", None)),
        "D": ones((di,), ("model",), jnp.float32),
        "out_proj": normal(ks[4], (di, d), ("model", "fsdp"),
                           1.0 / math.sqrt(di) / math.sqrt(2 * cfg.n_layers), dtype),
    }


def _mamba_split(w, cfg, x):
    di, n, r = cfg.d_inner, cfg.d_state, cfg.dt_rank_
    xz = x @ w["in_proj"]                                    # [b, t, 2*di]
    xi, z = jnp.split(xz, 2, axis=-1)
    return xi, z


def _mamba_ssm_inputs(w, cfg, xi):
    n, r = cfg.d_state, cfg.dt_rank_
    dbc = xi @ w["x_proj"]                                   # [b, t, r+2n]
    dt_r = dbc[..., :r]
    B = dbc[..., r:r + n].astype(jnp.float32)
    C = dbc[..., r + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_r @ w["dt_proj"] + w["dt_bias"])  # [b, t, di]
    return dt, B, C


def mamba_train(w, cfg: ModelConfig, x, *, impl: Optional[str] = None,
                true_len=None):
    """Full-sequence Mamba-1 mixer. Returns (y, final MambaState).

    ``true_len`` (traced int32, bucketed prefill): ``x`` is right-padded and
    only the first ``true_len`` positions are real. The scan is *pad-masked*:
    ``dt`` is zeroed at pad positions, so ``dA = exp(0·A) = 1`` and
    ``dB·x = 0`` — the SSM state passes through pads unchanged and the
    returned ``hT`` equals the state after exactly ``true_len`` tokens. The
    conv window is dynamic-sliced to the last ``d_conv - 1`` *real* inputs.
    Outputs at real positions are untouched (the recurrence and the causal
    conv never look forward), so bucketed prefill stays exact for SSM and
    hybrid stacks.
    """
    b, t, _ = x.shape
    di, dc = cfg.d_inner, cfg.d_conv
    xi, z = _mamba_split(w, cfg, x)
    xi = shard(xi, "batch", "seq", "model")
    # depthwise causal conv1d
    pad = jnp.zeros((b, dc - 1, di), xi.dtype)
    xp = jnp.concatenate([pad, xi], axis=1)
    conv = sum(xp[:, i:i + t] * w["conv_w"][i][None, None] for i in range(dc))
    if dc <= 1:
        conv_state = jnp.zeros((b, 0, di), xi.dtype)
    elif true_len is None:
        conv_state = xp[:, -(dc - 1):]
    else:
        # real inputs occupy xp[:, dc-1 : dc-1+true_len]; the state after
        # true_len tokens is the dc-1 rows ending there
        conv_state = jax.lax.dynamic_slice_in_dim(xp, true_len, dc - 1,
                                                  axis=1)
    xc = jax.nn.silu(conv + w["conv_b"])
    dt, B, C = _mamba_ssm_inputs(w, cfg, xc)
    if true_len is not None:
        real = jnp.arange(t) < true_len
        dt = jnp.where(real[None, :, None], dt, 0.0)
    A = -jnp.exp(w["A_log"])
    y, hT = kops.ssm_scan(xc, dt, A, B, C, w["D"], impl=impl)
    y = y * jax.nn.silu(z)
    out = y @ w["out_proj"]
    return shard(out, "batch", "res_seq", "residual"), MambaState(
        conv=conv_state.astype(x.dtype), ssm=hT)


def mamba_decode(w, cfg: ModelConfig, x, state: MambaState
                 ) -> Tuple[jnp.ndarray, MambaState]:
    """One-token recurrent Mamba step (O(1) state — the KV-free contrast)."""
    b = x.shape[0]
    di, dc, n = cfg.d_inner, cfg.d_conv, cfg.d_state
    xi, z = _mamba_split(w, cfg, x)                          # [b, 1, di]
    xi = shard(xi, "batch", "seq", "model")                  # keep di sharded
    z = shard(z, "batch", "seq", "model")
    window = jnp.concatenate([state.conv, xi], axis=1)       # [b, dc, di]
    conv = (window * w["conv_w"][None]).sum(axis=1) + w["conv_b"]
    xc = jax.nn.silu(conv)[:, None]                          # [b, 1, di]
    xc = shard(xc, "batch", "seq", "model")
    dt, B, C = _mamba_ssm_inputs(w, cfg, xc)
    A = -jnp.exp(w["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A[None])                # [b, di, n]
    h = state.ssm * dA + (dt[:, 0] * xc[:, 0])[:, :, None] * B[:, 0][:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0]) + xc[:, 0] * w["D"]
    y = y[:, None] * jax.nn.silu(z)
    out = y.astype(x.dtype) @ w["out_proj"]
    return shard(out, "batch", "seq", "residual"), MambaState(
        conv=window[:, 1:].astype(x.dtype), ssm=h)


# =========================================================================== #
# Chunked decode (streaming prefill): T>1 tokens against the budgeted cache
# =========================================================================== #
def attention_decode_chunk(w, cfg: ModelConfig, x, kv_cache: KVCache, *,
                           spec: LadderSpec, layer_ord, policy: PolicyLike,
                           start_pos) -> Tuple[jnp.ndarray, KVCache]:
    """Process a chunk of T tokens against the compacted cache (paper's
    PG19 sliding-window evaluation; O(budget * T) instead of O(T^2)).

    The chunk is appended to the cache first; attention then runs causally
    over the slot buffer with q_offset = first chunk slot, so each chunk
    token sees [whole compacted past || chunk prefix]."""
    b, tc, _ = x.shape
    h = cfg.n_heads
    policy = get_policy(policy)
    rope_mode = cfg.lacache.rope_mode
    cache_rope = (cfg.pos_emb == "rope" and rope_mode == "cache"
                  and not cfg.mrope)
    q, k_new, v_new = _qkv(w, cfg, x)

    kv_cache = cachelib.maybe_compact(
        kv_cache, spec, layer_ord, policy, tc,
        rope_theta=cfg.rope_theta if cache_rope else None)
    if cfg.pos_emb == "rope":
        if cache_rope:
            slots = kv_cache.length + jnp.arange(tc)
            k_store = common.apply_rope(k_new, slots[None], cfg.rope_theta)
            qq = common.apply_rope(q, slots[None], cfg.rope_theta)
        else:
            pos = start_pos + jnp.arange(tc)
            k_store = _rope_q(cfg, k_new, pos[None])
            qq = _rope_q(cfg, q, pos[None])
    else:
        k_store, qq = k_new, q
    q_off = kv_cache.length  # first chunk slot
    kv_cache = cachelib.append(
        kv_cache, k_store, v_new,
        (start_pos + jnp.arange(tc)).astype(jnp.int32))

    from repro.kernels import ref as kref
    valid = jnp.arange(kv_cache.n_slots) < kv_cache.length
    o = kref.mha_reference(qq, kv_cache.k, kv_cache.v, causal=True,
                           q_offset=q_off, kv_valid=valid)
    y = o.reshape(b, tc, h * cfg.head_dim_) @ w["wo"]
    return shard(y, "batch", "seq", "residual"), kv_cache


def attention_decode_chunk_paged(w, cfg: ModelConfig, x,
                                 st: "pagedlib.PagedKVCache",
                                 kvp: "pagedlib.PoolKV", *, spec: LadderSpec,
                                 layer_ord, policy: PolicyLike, start_pos):
    """Chunk decode (streaming prefill) against an in-model paged cache.

    The lane-batched twin of :func:`attention_decode_chunk`: the chunk is
    appended through the block table (CoW into the lane's reserved blocks),
    then attention runs causally over the gathered logical view with a
    per-lane ``q_offset`` — bit-for-bit the dense chunk computation, because
    the gathered view is exactly the dense slot buffer. ``start_pos``:
    per-lane absolute position of the chunk's first token [b].
    Returns (y, st, kvp).
    """
    b, tc, _ = x.shape
    h = cfg.n_heads
    policy = get_policy(policy)
    cache_rope = (cfg.pos_emb == "rope" and cfg.lacache.rope_mode == "cache"
                  and not cfg.mrope)
    q, k_new, v_new = _qkv(w, cfg, x)

    kvp, st = pagedlib.paged_maybe_compact(
        kvp, st, spec, layer_ord, policy, tc,
        rope_theta=cfg.rope_theta if cache_rope else None)
    start = jnp.asarray(start_pos, jnp.int32).reshape(-1)     # [b]
    if cfg.pos_emb == "rope":
        if cache_rope:
            slots = st.length[:, None] + jnp.arange(tc)[None]  # [b, tc]
            k_store = common.apply_rope(k_new, slots, cfg.rope_theta)
            qq = common.apply_rope(q, slots, cfg.rope_theta)
        else:
            posns = start[:, None] + jnp.arange(tc)[None]
            k_store = common.apply_rope(k_new, posns, cfg.rope_theta)
            qq = common.apply_rope(q, posns, cfg.rope_theta)
    else:
        k_store, qq = k_new, q
    q_off = st.length                                          # [b]
    kvp, st = pagedlib.paged_append(
        kvp, st, k_store, v_new,
        (start[:, None] + jnp.arange(tc)[None]).astype(jnp.int32))

    o = kops.paged_verify_attention(qq, kvp.k, kvp.v, st.blocks, st.length,
                                    q_off, n_slots=st.n_slots)
    y = o.reshape(b, tc, h * cfg.head_dim_) @ w["wo"]
    return shard(y, "batch", "seq", "residual"), st, kvp


def _ring_window_attend(cfg: ModelConfig, qq, keys, vals, kpos, pos_c, *,
                        window: int):
    """Windowed-causal attention over ``[ring || chunk]`` — THE single
    inline core both the dense and the paged ring chunk paths run, so the
    backends' bit-for-bit agreement cannot drift. ``kpos`` [L, w+tc] /
    ``pos_c`` [L, tc] carry a leading lane axis: L == 1 broadcasts
    batch-uniform metadata (dense rings), L == b is per-lane (paged).
    Dead ring slots carry ``kpos == -1`` (dense zeros / paged gathered
    garbage alike) and mask out before the softmax. Returns float32
    [b, tc, h, hd]."""
    h, hd, kvh = cfg.n_heads, cfg.head_dim_, cfg.n_kv_heads
    mask = (kpos[:, None, :] >= 0) \
        & (kpos[:, None, :] <= pos_c[:, :, None]) \
        & (kpos[:, None, :] > pos_c[:, :, None] - window)     # [L, tc, w+tc]
    qf = qq.astype(jnp.float32) / (hd ** 0.5)
    kf = jnp.repeat(keys.astype(jnp.float32), h // kvh, axis=2)
    vf = jnp.repeat(vals.astype(jnp.float32), h // kvh, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


def _ring_rebuild_gather(keys, vals, start, tc: int, wsz: int):
    """Residue-class rebuild sources: slot j's newest position ``p_j ≡ j
    (mod wsz)`` gathered from ``[ring || chunk]`` (duplicate-free by the
    ring invariant). ``start`` [L]: lane clocks (L == 1 broadcasts).
    Returns (gk, gv, pos [L, wsz], live [L, wsz])."""
    last = start + tc - 1
    j = jnp.arange(wsz)[None]
    p_j = last[:, None] - ((last[:, None] - j) % wsz)
    src = jnp.where(p_j >= start[:, None], wsz + (p_j - start[:, None]), j)
    live = p_j >= 0
    gk = jnp.take_along_axis(keys, src[:, :, None, None], axis=1)
    gv = jnp.take_along_axis(vals, src[:, :, None, None], axis=1)
    return gk, gv, jnp.where(live, p_j, -1).astype(jnp.int32), live


def ring_chunk_paged(w, cfg: ModelConfig, x, st: "pagedlib.PagedRingCache",
                     kvp: "pagedlib.PoolKV", *, window: int):
    """Chunk decode (streaming prefill) against an in-model paged ring.

    The lane-batched twin of :func:`ring_chunk`: the old ring is gathered
    through the residue-class table, the chunk attends to ``[ring || chunk]``
    through the shared :func:`_ring_window_attend` core (so the backends
    agree bit-for-bit), and the rebuilt ring scatters wholesale into the
    lane's ``owned`` blocks (every live slot is rewritten anyway, so the
    table redirects to the reserved set and shared snapshot blocks are
    left untouched). Returns (y, st, kvp).
    """
    b, tc, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    wsz = st.window
    start = st.next_pos                                       # [b]
    pos_c = start[:, None] + jnp.arange(tc)[None]             # [b, tc]
    q, k_new, v_new = _qkv(w, cfg, x)
    if cfg.pos_emb == "rope":
        qq = common.apply_rope(q, pos_c, cfg.rope_theta)
        k_rot = common.apply_rope(k_new, pos_c, cfg.rope_theta)
    else:
        qq, k_rot = q, k_new
    rk, rv = pagedlib.paged_gather_view(kvp, st, wsz)
    keys = jnp.concatenate([rk, k_rot.astype(rk.dtype)], axis=1)
    vals = jnp.concatenate([rv, v_new.astype(rv.dtype)], axis=1)
    kpos = jnp.concatenate([st.pos, pos_c.astype(jnp.int32)], axis=1)
    o = _ring_window_attend(cfg, qq, keys, vals, kpos, pos_c,
                            window=window).astype(x.dtype)
    y = o.reshape(b, tc, h * hd) @ w["wo"]

    gk, gv, pp, _ = _ring_rebuild_gather(keys, vals, start, tc, wsz)
    kvp, st = pagedlib.paged_ring_rebuild(kvp, st, gk, gv, pp, start + tc)
    return shard(y, "batch", "seq", "residual"), st, kvp


def mamba_chunk(w, cfg: ModelConfig, x, state: MambaState
                ) -> Tuple[jnp.ndarray, MambaState]:
    """Chunk of T tokens through the recurrence, threading conv+ssm state."""
    b, tc, _ = x.shape
    di, dc = cfg.d_inner, cfg.d_conv
    xi, z = _mamba_split(w, cfg, x)
    xi = shard(xi, "batch", "seq", "model")
    xp = jnp.concatenate([state.conv.astype(xi.dtype), xi], axis=1)
    conv = sum(xp[:, i:i + tc] * w["conv_w"][i][None, None] for i in range(dc))
    conv_state = xp[:, -(dc - 1):] if dc > 1 else jnp.zeros((b, 0, di), xi.dtype)
    xc = jax.nn.silu(conv + w["conv_b"])
    dt, B, C = _mamba_ssm_inputs(w, cfg, xc)
    A = -jnp.exp(w["A_log"])
    y, hT = kops.ssm_scan(xc, dt, A, B, C, w["D"], h0=state.ssm)
    y = y * jax.nn.silu(z)
    out = y @ w["out_proj"]
    return shard(out, "batch", "res_seq", "residual"), MambaState(
        conv=conv_state.astype(x.dtype), ssm=hT)


def ring_chunk(w, cfg: ModelConfig, x, ring: RingKVCache, *, window: int
               ) -> Tuple[jnp.ndarray, RingKVCache]:
    """Chunk decode for sliding-window layers: attend to [ring || chunk]
    with the window mask, then rebuild the ring from the newest positions
    (gather by residue class — duplicate-free by the ring invariant
    slot == pos % window). Runs the shared :func:`_ring_window_attend` /
    :func:`_ring_rebuild_gather` core with batch-uniform (L == 1) lane
    metadata — the identical computation the paged twin runs per-lane."""
    b, tc, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    wsz = ring.k.shape[1]
    start = ring.next_pos
    pos_c = start + jnp.arange(tc)
    q, k_new, v_new = _qkv(w, cfg, x)
    if cfg.pos_emb == "rope":
        qq = common.apply_rope(q, pos_c[None], cfg.rope_theta)
        k_rot = common.apply_rope(k_new, pos_c[None], cfg.rope_theta)
    else:
        qq, k_rot = q, k_new
    keys = jnp.concatenate([ring.k, k_rot.astype(ring.k.dtype)], axis=1)
    vals = jnp.concatenate([ring.v, v_new.astype(ring.v.dtype)], axis=1)
    kpos = jnp.concatenate([ring.pos, pos_c.astype(jnp.int32)])
    o = _ring_window_attend(cfg, qq, keys, vals, kpos[None], pos_c[None],
                            window=window).astype(x.dtype)
    y = o.reshape(b, tc, h * hd) @ w["wo"]

    gk, gv, pp, live = _ring_rebuild_gather(keys, vals, start[None], tc, wsz)
    kk = jnp.where(live[0][None, :, None, None], gk,
                   jnp.zeros((), gk.dtype))
    vv = jnp.where(live[0][None, :, None, None], gv,
                   jnp.zeros((), gv.dtype))
    return shard(y, "batch", "seq", "residual"), RingKVCache(
        k=kk, v=vv, pos=pp[0], next_pos=start + tc)
