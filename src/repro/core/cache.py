"""Functional, fixed-shape KV cache with LaCache iterative compaction.

All state is a pytree of fixed-shape arrays (SPMD/jit friendly — DESIGN.md §4):

* ``k``/``v``: ``[batch, n_slots, kv_heads, head_dim]`` slot buffers,
* ``pos``:    ``[n_slots]`` original token position per slot (-1 = empty);
  batch-uniform because the engine decodes lockstep batches,
* ``length``: scalar int32 — occupied prefix (survivors are left-compacted,
  so slot order == age order, the invariant iterative compaction relies on),
* ``scores``: ``[n_slots]`` accumulated attention mass (score-based
  policies, i.e. those with ``EvictionPolicy.needs_scores``: H2O/TOVA).

Which slots survive a compaction is delegated to the
:class:`repro.core.policy.EvictionPolicy` objects; string names are
accepted everywhere for backwards compatibility and resolved once via
:func:`repro.core.policy.get_policy`.

This module is per-layer; the model stacks layer caches as scan xs/ys.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import ladder
from repro.core.ladder import LadderSpec
from repro.core.policy import PolicyLike, get_policy


class KVCache(NamedTuple):
    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray
    length: jnp.ndarray
    scores: Optional[jnp.ndarray] = None

    @property
    def n_slots(self) -> int:
        return self.k.shape[1]

    def valid_mask(self) -> jnp.ndarray:
        return jnp.arange(self.n_slots) < self.length


class CrossKVCache(NamedTuple):
    """Static (never-evicted) cross-attention cache (whisper)."""

    k: jnp.ndarray  # [batch, n_frames, kv_heads, head_dim]
    v: jnp.ndarray


class MambaState(NamedTuple):
    conv: jnp.ndarray  # [batch, d_conv - 1, d_inner]
    ssm: jnp.ndarray   # [batch, d_inner, d_state]


def init_cache(batch: int, n_slots: int, kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16, with_scores: bool = False) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, n_slots, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, n_slots, kv_heads, head_dim), dtype),
        pos=jnp.full((n_slots,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
        scores=jnp.zeros((n_slots,), jnp.float32) if with_scores else None,
    )


def append(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray,
           pos_new: jnp.ndarray) -> KVCache:
    """Append ``T_new`` tokens at the occupied prefix end.

    Caller must guarantee ``length + T_new <= n_slots`` (via compaction).
    k_new/v_new: [batch, T_new, kv_heads, head_dim]; pos_new: [T_new] int32.
    """
    t_new = k_new.shape[1]
    at = cache.length
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, at, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, at, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache.pos, pos_new.astype(jnp.int32), (at,))
    return cache._replace(k=k, v=v, pos=pos, length=cache.length + t_new)


# --------------------------------------------------------------------------- #
# Policies: which slots survive a compaction pass
# --------------------------------------------------------------------------- #
def keep_mask(policy: PolicyLike, spec: LadderSpec, cache: KVCache,
              layer) -> jnp.ndarray:
    """Survivor mask of one compaction pass (policy object or legacy name)."""
    return get_policy(policy).keep_mask(spec, cache, layer)


def compact(cache: KVCache, spec: LadderSpec, layer, policy: PolicyLike,
            gather_fn=None, rope_theta=None) -> KVCache:
    """One compaction pass: drop non-kept slots, left-compact survivors.

    ``rope_theta``: when keys are stored rotated by their *slot* index
    (cache-relative RoPE, §Perf iter 1c), compaction must re-rotate moved
    keys by the slot delta. R(a)R(b) = R(a+b), so applying RoPE with
    position (new_slot - old_slot) is exact — O(budget) work only on the
    rare compaction step instead of O(budget) re-rotation every step."""
    keep = get_policy(policy).keep_mask(spec, cache, layer)
    perm, new_len = ladder.compaction_perm(keep)
    if gather_fn is None:
        from repro.kernels import ops as kops
        gather_fn = kops.gather_compact
    slot = jnp.arange(cache.n_slots)
    live = slot < new_len
    k = gather_fn(cache.k, perm, new_len)
    if rope_theta is not None:
        from repro.models.common import apply_rope
        delta = jnp.where(live, slot - perm, 0)
        k = apply_rope(k, delta[None], rope_theta)
    v = gather_fn(cache.v, perm, new_len)
    pos = jnp.where(live, cache.pos[perm], -1)
    scores = None
    if cache.scores is not None:
        scores = jnp.where(live, cache.scores[perm], 0.0)
    return KVCache(k=k, v=v, pos=pos, length=new_len, scores=scores)


def _force_evict(cache: KVCache, spec: LadderSpec, n_free: int,
                 rope_theta=None) -> KVCache:
    """Recency-truncation fallback: guarantee >= n_free free slots (degenerate
    geometries where a ladder pass frees nothing, e.g. span == n_layers)."""
    slot = jnp.arange(cache.n_slots)
    target = cache.n_slots - n_free
    keep = ((slot < spec.n_sink)
            | (slot >= cache.length - (target - spec.n_sink))) \
        & (slot < cache.length)
    perm, new_len = ladder.compaction_perm(keep)
    live = slot < new_len
    from repro.kernels import ops as kops
    k = kops.gather_compact(cache.k, perm, new_len)
    if rope_theta is not None:
        from repro.models.common import apply_rope
        k = apply_rope(k, jnp.where(live, slot - perm, 0)[None], rope_theta)
    return KVCache(
        k=k, v=kops.gather_compact(cache.v, perm, new_len),
        pos=jnp.where(live, cache.pos[perm], -1), length=new_len,
        scores=None if cache.scores is None
        else jnp.where(live, cache.scores[perm], 0.0))


def maybe_compact(cache: KVCache, spec: LadderSpec, layer, policy: PolicyLike,
                  n_incoming: int = 1, rope_theta=None) -> KVCache:
    """Compact iff the incoming tokens would overflow the buffer (lax.cond).
    A second forced recency pass guarantees space even when the policy pass
    frees nothing."""
    policy = get_policy(policy)
    if not policy.evicts:
        return cache
    need = cache.length + n_incoming > cache.n_slots

    def do(c):
        c = compact(c, spec, layer, policy, rope_theta=rope_theta)
        still = c.length + n_incoming > c.n_slots
        return jax.lax.cond(
            still,
            lambda cc: _force_evict(cc, spec, n_incoming, rope_theta),
            lambda cc: cc, c)

    return jax.lax.cond(need, do, lambda c: c, cache)


def compact_to_budget(cache: KVCache, spec: LadderSpec, layer,
                      policy: PolicyLike, target: int, max_passes: int = 8,
                      rope_theta=None) -> KVCache:
    """Iterated compaction until ``length <= target`` (dense-prefill path).

    A final recency-truncation pass guarantees termination (needed only in
    degenerate geometries where the ladder fixed point exceeds the target).
    """
    def cond(state):
        c, i = state
        return (c.length > target) & (i < max_passes)

    def body(state):
        c, i = state
        return compact(c, spec, layer, policy, rope_theta=rope_theta), i + 1

    cache, _ = jax.lax.while_loop(cond, body, (cache, jnp.zeros((), jnp.int32)))

    # hard guarantee: keep sinks + newest (target - n_sink)
    def hard_truncate(c):
        slot = jnp.arange(c.n_slots)
        keep = ((slot < spec.n_sink) | (slot >= c.length - (target - spec.n_sink))) \
            & (slot < c.length)
        perm, new_len = ladder.compaction_perm(keep)
        live = slot < new_len
        from repro.kernels import ops as kops
        k = kops.gather_compact(c.k, perm, new_len)
        if rope_theta is not None:
            from repro.models.common import apply_rope
            k = apply_rope(k, jnp.where(live, slot - perm, 0)[None], rope_theta)
        return KVCache(
            k=k,
            v=kops.gather_compact(c.v, perm, new_len),
            pos=jnp.where(live, c.pos[perm], -1),
            length=new_len,
            scores=None if c.scores is None else jnp.where(live, c.scores[perm], 0.0),
        )

    return jax.lax.cond(cache.length > target, hard_truncate,
                        lambda c: c, cache)


def truncate(cache: KVCache, length) -> KVCache:
    """Mark every slot at or past ``length`` empty (pos = -1, scores = 0).

    Bucketed prefill appends a right-padded token block in one shot; the
    pad slots are dead weight that must not survive into compaction or
    attention. ``length`` may be traced; k/v payloads beyond ``length`` are
    left in place — everything masks by ``length``/``pos`` and the next
    append overwrites them.
    """
    length = jnp.minimum(cache.length, jnp.asarray(length, jnp.int32))
    live = jnp.arange(cache.n_slots) < length
    return cache._replace(
        length=length,
        pos=jnp.where(live, cache.pos, -1),
        scores=None if cache.scores is None
        else jnp.where(live, cache.scores, 0.0))


def crop(cache: KVCache, n_slots: int) -> KVCache:
    """Static crop of the slot buffer (prefill buffer -> decode budget)."""
    return KVCache(
        k=cache.k[:, :n_slots], v=cache.v[:, :n_slots], pos=cache.pos[:n_slots],
        length=jnp.minimum(cache.length, n_slots),
        scores=None if cache.scores is None else cache.scores[:n_slots])


def add_scores(cache: KVCache, probs: jnp.ndarray) -> KVCache:
    """Legacy shim: accumulate attention mass (H2O). Prefer
    ``policy.observe(cache, probs)``. probs: [batch, heads, q, n_slots]."""
    return get_policy("h2o").observe(cache, probs)


def set_scores(cache: KVCache, probs: jnp.ndarray) -> KVCache:
    """Legacy shim: last-query attention scores (TOVA). Prefer
    ``policy.observe(cache, probs)``."""
    return get_policy("tova").observe(cache, probs)
