"""Paged KV memory: global block pool, per-request block tables, CoW sharing.

The dense :class:`~repro.core.cache.KVCache` gives every sequence its own
contiguous ``[batch, n_slots, ...]`` buffer, so snapshotting a state (prefix
cache) or parking a preempted request means copying whole buffers. This
module is the standard remedy from the KV-cache-serving literature (vLLM-style
paged attention, arXiv:2412.19442 survey): KV lives in one **global physical
pool** of fixed-size blocks and each logical cache is a **block table** that
maps logical slot ranges onto pool blocks. Two tables may point at the same
physical block (shared prompt prefix); blocks are reference-counted and
**copy-on-write** — writing into a block with ``ref > 1`` transparently
allocates a fresh block from the free list and redirects the writer's table.

Everything is a jit-compatible pytree of fixed-shape arrays:

* :class:`PagedPool` — ``k``/``v`` ``[n_blocks, block_size, kv_heads,
  head_dim]`` physical storage, ``ref`` ``[n_blocks]`` refcounts (0 = free)
  and a ``free``/``n_free`` free-list stack (``free[:n_free]`` are free ids).
* :class:`BlockTable` — ``blocks`` ``[max_blocks]`` physical ids (-1 =
  unmapped) plus the same logical metadata a dense cache carries (``pos``,
  ``length``, ``scores``) so eviction policies keep working unchanged.

The dense-cache API is mirrored by shims (:func:`append`, :func:`truncate`,
:func:`compact`, :func:`keep_mask`) that gather the logical view through the
block table, run the exact dense computation (including
``EvictionPolicy.keep_mask`` and ladder compaction with the cache-relative
RoPE fixup) and write survivors back block-wise — CoW-allocating only the
blocks whose content actually changes.

:class:`PagedStateStore` lifts the pool to whole decode-state pytrees: every
``KVCache`` node is swapped for block tables (structural sharing between
snapshots that extend one another — verified by pos-prefix equality, so
compaction reordering safely disables sharing instead of corrupting it) and
all other leaves (ring windows, SSM states, positions) ride along dense.
The serving layer builds the prefix cache and request preemption on top.

All ops are pure functions (pool in, pool out) and traceable; when called
eagerly (the serving layer's mode) they additionally raise
:class:`PoolExhausted` instead of silently corrupting the free list.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cachelib
from repro.core import ladder
from repro.core.cache import KVCache
from repro.core.ladder import LadderSpec
from repro.core.policy import PolicyLike, get_policy


class PoolExhausted(RuntimeError):
    """The free list cannot satisfy an allocation (caller should evict).

    Raised through :func:`exhausted`, the message carries pool utilization
    and a suggested ``pool_blocks`` so serving OOMs are actionable; the
    same numbers ride along as structured fields (``need`` / ``free`` /
    ``in_use`` / ``total`` / ``cache_blocks`` / ``suggested_pool_blocks``,
    ``None`` when unknown) for programmatic handling."""

    def __init__(self, msg: str, *, need: Optional[int] = None,
                 free: Optional[int] = None, in_use: Optional[int] = None,
                 total: Optional[int] = None,
                 cache_blocks: Optional[int] = None,
                 suggested_pool_blocks: Optional[int] = None):
        super().__init__(msg)
        self.need = need
        self.free = free
        self.in_use = in_use
        self.total = total
        self.cache_blocks = cache_blocks
        self.suggested_pool_blocks = suggested_pool_blocks


def exhausted(pool: "PagedPool", need: int, *, what: str = "",
              cache_blocks: Optional[int] = None) -> PoolExhausted:
    """Build an actionable :class:`PoolExhausted` for ``pool``.

    ``cache_blocks`` (when the caller can attribute them — the engine
    registers a provider on the store) is the number of distinct blocks
    held by prefix-cache entries, the knob a serving operator can actually
    turn (smaller ``prefix_cache_bytes``) besides growing the pool.
    ``total`` comes from the refcount array, which keeps its full size
    even after ``detach_planes`` shrinks the K/V planes to a stub."""
    free = int(pool.n_free)
    total = int(pool.ref.shape[0])
    in_use = int((np.asarray(pool.ref) > 0).sum())
    suggested = total + max(int(need) - free, 1)
    cache_part = ("" if cache_blocks is None
                  else f", {int(cache_blocks)} held by prefix cache")
    return PoolExhausted(
        f"{what}need {int(need)} blocks, {free} free "
        f"({in_use}/{total} in use{cache_part}); "
        f"retry with pool_blocks >= {suggested} or shrink the prefix "
        "cache", need=int(need), free=free, in_use=in_use, total=total,
        cache_blocks=cache_blocks, suggested_pool_blocks=suggested)


class PagedPool(NamedTuple):
    """Global physical block pool (one per served model / layer group)."""

    k: jnp.ndarray        # [n_blocks, block_size, kv_heads, head_dim]
    v: jnp.ndarray        # [n_blocks, block_size, kv_heads, head_dim]
    ref: jnp.ndarray      # [n_blocks] int32 refcount, 0 = free
    free: jnp.ndarray     # [n_blocks] int32 free-list stack
    n_free: jnp.ndarray   # scalar int32: free[:n_free] are free ids

    @property
    def n_blocks(self) -> int:
        return self.k.shape[0]

    @property
    def block_size(self) -> int:
        return self.k.shape[1]

    @property
    def block_bytes(self) -> int:
        """Bytes of one physical block (K and V planes together)."""
        per = self.block_size * self.k.shape[2] * self.k.shape[3]
        return 2 * per * self.k.dtype.itemsize


class BlockTable(NamedTuple):
    """One logical cache: physical block ids + dense-cache metadata."""

    blocks: jnp.ndarray             # [max_blocks] int32, -1 = unmapped
    pos: jnp.ndarray                # [n_slots] int32 (-1 = empty), as KVCache
    length: jnp.ndarray             # scalar int32 occupied prefix
    scores: Optional[jnp.ndarray] = None   # [n_slots] float32 (H2O/TOVA)

    @property
    def n_slots(self) -> int:
        return self.pos.shape[0]

    @property
    def max_blocks(self) -> int:
        return self.blocks.shape[0]


def init_pool(n_blocks: int, block_size: int, kv_heads: int, head_dim: int,
              dtype=jnp.bfloat16) -> PagedPool:
    if n_blocks < 1 or block_size < 1:
        raise ValueError("pool needs at least one block of at least one slot")
    return PagedPool(
        k=jnp.zeros((n_blocks, block_size, kv_heads, head_dim), dtype),
        v=jnp.zeros((n_blocks, block_size, kv_heads, head_dim), dtype),
        ref=jnp.zeros((n_blocks,), jnp.int32),
        # stack holds ids top-down so block 0 is allocated first
        free=jnp.arange(n_blocks - 1, -1, -1, dtype=jnp.int32),
        n_free=jnp.asarray(n_blocks, jnp.int32))


def blocks_for(n_slots: int, block_size: int) -> int:
    """Logical blocks needed to cover ``n_slots`` slots (static)."""
    return -(-n_slots // block_size)


def new_table(n_slots: int, block_size: int,
              with_scores: bool = False) -> BlockTable:
    mb = blocks_for(n_slots, block_size)
    return BlockTable(
        blocks=jnp.full((mb,), -1, jnp.int32),
        pos=jnp.full((n_slots,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
        scores=jnp.zeros((n_slots,), jnp.float32) if with_scores else None)


# --------------------------------------------------------------------------- #
# Refcount / free-list primitives (pure, traceable)
# --------------------------------------------------------------------------- #
def _concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def _push_free(pool: PagedPool, freed_mask: jnp.ndarray) -> PagedPool:
    """Push every block flagged in ``freed_mask`` onto the free stack."""
    # sized off the refcount array, not the planes: the in-model engine may
    # have detached the pool's K/V planes (see PagedStateStore.detach_planes)
    nb = pool.ref.shape[0]
    n_freed = freed_mask.sum().astype(jnp.int32)
    # freed ids ascending, padded with the OOB sentinel nb
    freed_sorted = jnp.sort(jnp.where(freed_mask, jnp.arange(nb), nb))
    idx = jnp.arange(nb)
    src = jnp.clip(idx - pool.n_free, 0, nb - 1)
    new_free = jnp.where((idx >= pool.n_free) & (idx < pool.n_free + n_freed),
                         freed_sorted[src], pool.free)
    return pool._replace(free=new_free, n_free=pool.n_free + n_freed)


def _decref(pool: PagedPool, ids: jnp.ndarray) -> PagedPool:
    """Drop one reference per id (-1 entries are skipped); blocks reaching
    refcount 0 return to the free list."""
    nb = pool.ref.shape[0]
    valid = ids >= 0
    idc = jnp.where(valid, ids, 0)
    dec = jnp.zeros((nb,), jnp.int32).at[idc].add(valid.astype(jnp.int32))
    ref = pool.ref - dec
    freed = (dec > 0) & (ref <= 0) & (pool.ref > 0)
    pool = pool._replace(ref=jnp.maximum(ref, 0))
    return _push_free(pool, freed)


def _incref(pool: PagedPool, ids: jnp.ndarray) -> PagedPool:
    nb = pool.ref.shape[0]
    valid = ids >= 0
    idc = jnp.where(valid, ids, 0)
    inc = jnp.zeros((nb,), jnp.int32).at[idc].add(valid.astype(jnp.int32))
    return pool._replace(ref=pool.ref + inc)


def retain(pool: PagedPool, table: BlockTable) -> PagedPool:
    """Add one reference to every block the table maps (sharing)."""
    return _incref(pool, table.blocks)


def release(pool: PagedPool, table: BlockTable) -> PagedPool:
    """Drop the table's references; fully unreferenced blocks become free."""
    return _decref(pool, table.blocks)


# --------------------------------------------------------------------------- #
# The write primitive: scatter a logical view into (possibly shared) blocks
# --------------------------------------------------------------------------- #
def _write(pool: PagedPool, blocks: jnp.ndarray, view_k: jnp.ndarray,
           view_v: jnp.ndarray, start, length
           ) -> Tuple[PagedPool, jnp.ndarray]:
    """Write logical slots ``[start, length)`` of a padded view into blocks.

    view_k/view_v: [max_blocks * block_size, kv_heads, head_dim] (no batch).
    Per logical block: untouched blocks (fully before ``start``) keep their
    mapping; written blocks are CoW-allocated when shared (ref > 1) or
    unmapped; blocks fully at or past ``length`` are released. ``start`` /
    ``length`` may be traced.
    """
    nb, bs = pool.n_blocks, pool.block_size
    mb = blocks.shape[0]
    bi = jnp.arange(mb)
    lo, hi = bi * bs, (bi + 1) * bs
    length = jnp.asarray(length, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    written = (lo < length) & (hi > start)
    released = (lo >= length) & (blocks >= 0)
    mapped = blocks >= 0
    shared = mapped & (pool.ref[jnp.clip(blocks, 0)] > 1)
    need_new = written & (~mapped | shared)

    n_new = jnp.sum(need_new.astype(jnp.int32))
    if _concrete(n_new) and _concrete(pool.n_free) \
            and int(n_new) > int(pool.n_free):
        raise exhausted(pool, int(n_new), what="block write: ")
    rank = jnp.cumsum(need_new.astype(jnp.int32)) - 1
    new_ids = pool.free[jnp.clip(pool.n_free - 1 - rank, 0, nb - 1)]
    new_blocks = jnp.where(written,
                           jnp.where(need_new, new_ids, blocks),
                           jnp.where(released, -1, blocks))
    # fresh allocations start at ref 1; CoW'd originals and released blocks
    # each lose one reference
    ref = pool.ref.at[jnp.where(need_new, new_ids, nb)].set(1, mode="drop")
    pool = pool._replace(ref=ref, n_free=pool.n_free - n_new)
    pool = _decref(pool, jnp.where((written & shared) | released, blocks, -1))

    tgt = jnp.where(written, new_blocks, nb)     # OOB sentinel drops the row
    ck = view_k.reshape(mb, bs, *view_k.shape[1:])
    cv = view_v.reshape(mb, bs, *view_v.shape[1:])
    pool = pool._replace(
        k=pool.k.at[tgt].set(ck.astype(pool.k.dtype), mode="drop"),
        v=pool.v.at[tgt].set(cv.astype(pool.v.dtype), mode="drop"))
    return pool, new_blocks


def _padded_view(pool: PagedPool, table: BlockTable
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gather [max_blocks * block_size, kv, hd] K/V through the table."""
    ids = jnp.clip(table.blocks, 0)
    shp = (table.max_blocks * pool.block_size,) + pool.k.shape[2:]
    return pool.k[ids].reshape(shp), pool.v[ids].reshape(shp)


def _pad_slots(x: jnp.ndarray, padded: int) -> jnp.ndarray:
    """Right-pad axis 0 (slots) with zeros up to ``padded``."""
    if x.shape[0] == padded:
        return x
    pad = [(0, padded - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


# --------------------------------------------------------------------------- #
# Dense-cache bridge: the KVCache API mirrored through the block table
# --------------------------------------------------------------------------- #
def gather(pool: PagedPool, table: BlockTable) -> KVCache:
    """Materialize the logical dense view (batch 1) of a block table.

    Exact for every slot the dense semantics can observe (slots < length,
    plus pos/scores metadata, which live in the table verbatim)."""
    vk, vv = _padded_view(pool, table)
    n = table.n_slots
    return KVCache(k=vk[None, :n], v=vv[None, :n], pos=table.pos,
                   length=table.length, scores=table.scores)


def from_dense(pool: PagedPool, cache: KVCache, *,
               parent: Optional[BlockTable] = None, shared_blocks: int = 0
               ) -> Tuple[PagedPool, BlockTable]:
    """Page a dense (batch-1) cache into the pool.

    ``parent``/``shared_blocks``: the first ``shared_blocks`` logical blocks
    are known-identical to the parent's (prefix lineage) — they are shared by
    bumping refcounts instead of being copied. The caller is responsible for
    the content claim; :func:`shared_prefix_blocks` computes the safe count.
    """
    if cache.k.shape[0] != 1:
        raise ValueError("from_dense pages batch-1 caches (one table per "
                         f"sequence); got batch {cache.k.shape[0]}")
    bs = pool.block_size
    n_slots = cache.pos.shape[0]
    mb = blocks_for(n_slots, bs)
    blocks = jnp.full((mb,), -1, jnp.int32)
    if parent is not None and shared_blocks:
        shared_blocks = min(shared_blocks, mb, parent.max_blocks)
        # pre-check capacity before retaining parent blocks, so an
        # exhausted pool raises without leaking references
        if _concrete(cache.length) and _concrete(pool.n_free) and \
                blocks_for(int(cache.length), bs) - shared_blocks \
                > int(pool.n_free):
            raise exhausted(
                pool, blocks_for(int(cache.length), bs) - shared_blocks,
                what="page-in of a dense cache: ")
        blocks = blocks.at[:shared_blocks].set(parent.blocks[:shared_blocks])
        pool = _incref(pool, parent.blocks[:shared_blocks])
    padded = mb * bs
    vk = _pad_slots(cache.k[0], padded)
    vv = _pad_slots(cache.v[0], padded)
    pool, blocks = _write(pool, blocks, vk, vv,
                          start=shared_blocks * bs, length=cache.length)
    return pool, BlockTable(blocks=blocks, pos=cache.pos,
                            length=cache.length, scores=cache.scores)


def shared_prefix_blocks(parent: BlockTable, cache: KVCache,
                         block_size: int) -> int:
    """Longest safely-shareable whole-block prefix of ``cache`` vs ``parent``.

    A block is shareable iff it is entirely inside both occupied prefixes and
    the per-slot positions agree over it — compaction that reorders slots
    changes ``pos`` and therefore disables sharing for the affected blocks
    instead of splicing stale content. Host-side (concrete arrays only).
    """
    limit = min(int(parent.length), int(cache.length)) // block_size
    if limit <= 0:
        return 0
    ppos = np.asarray(parent.pos[:limit * block_size])
    cpos = np.asarray(cache.pos[:limit * block_size])
    agree = ppos == cpos
    if agree.all():
        return limit
    first_bad = int(np.argmin(agree))
    return first_bad // block_size


def append(pool: PagedPool, table: BlockTable, k_new: jnp.ndarray,
           v_new: jnp.ndarray, pos_new: jnp.ndarray
           ) -> Tuple[PagedPool, BlockTable]:
    """Append ``T_new`` tokens at the occupied prefix end (CoW-aware).

    Mirrors :func:`repro.core.cache.append`; blocks before the append point
    are untouched, the (possibly shared) straddled tail block is
    copy-on-write'd, and new blocks come off the free list.
    """
    t_new = k_new.shape[1]
    at = table.length
    vk, vv = _padded_view(pool, table)
    vk = jax.lax.dynamic_update_slice(vk, k_new[0].astype(vk.dtype), (at, 0, 0))
    vv = jax.lax.dynamic_update_slice(vv, v_new[0].astype(vv.dtype), (at, 0, 0))
    pos = jax.lax.dynamic_update_slice(table.pos,
                                       pos_new.astype(jnp.int32), (at,))
    new_len = at + t_new
    pool, blocks = _write(pool, table.blocks, vk, vv, start=at, length=new_len)
    return pool, table._replace(blocks=blocks, pos=pos, length=new_len)


def truncate(pool: PagedPool, table: BlockTable, length
              ) -> Tuple[PagedPool, BlockTable]:
    """Mirror of :func:`repro.core.cache.truncate`: drop slots >= length and
    release blocks that fall entirely past the new occupied prefix."""
    length = jnp.minimum(table.length, jnp.asarray(length, jnp.int32))
    live = jnp.arange(table.n_slots) < length
    bi = jnp.arange(table.max_blocks)
    dead = (bi * pool.block_size >= length) & (table.blocks >= 0)
    pool = _decref(pool, jnp.where(dead, table.blocks, -1))
    return pool, table._replace(
        blocks=jnp.where(dead, -1, table.blocks),
        pos=jnp.where(live, table.pos, -1),
        length=length,
        scores=None if table.scores is None
        else jnp.where(live, table.scores, 0.0))


def keep_mask(policy: PolicyLike, spec: LadderSpec, pool: PagedPool,
              table: BlockTable, layer) -> jnp.ndarray:
    """Eviction-policy survivor mask, evaluated on the gathered view —
    policies keep working against paged storage with zero changes."""
    return get_policy(policy).keep_mask(spec, gather(pool, table), layer)


def compact(pool: PagedPool, table: BlockTable, spec: LadderSpec, layer,
            policy: PolicyLike, rope_theta=None
            ) -> Tuple[PagedPool, BlockTable]:
    """One ladder compaction pass through the block table.

    Gathers the logical view, runs the exact dense compaction (policy keep
    mask, left-compaction, cache-relative RoPE fixup), then rewrites the
    surviving prefix block-wise: uniquely-owned blocks are updated in place
    (same physical id), shared blocks are CoW'd, and blocks past the new
    length go back to the free list.
    """
    dense = gather(pool, table)
    newc = cachelib.compact(dense, spec, layer, policy, rope_theta=rope_theta)
    padded = table.max_blocks * pool.block_size
    pool, blocks = _write(pool, table.blocks,
                          _pad_slots(newc.k[0], padded),
                          _pad_slots(newc.v[0], padded),
                          start=0, length=newc.length)
    return pool, BlockTable(blocks=blocks, pos=newc.pos, length=newc.length,
                            scores=newc.scores)


def fork(pool: PagedPool, table: BlockTable) -> Tuple[PagedPool, BlockTable]:
    """Zero-copy clone: the clone shares every block (refcounts bumped);
    subsequent appends/compactions CoW on first write."""
    return retain(pool, table), table


# --------------------------------------------------------------------------- #
# Telemetry / invariants
# --------------------------------------------------------------------------- #
def blocks_in_use(pool: PagedPool) -> int:
    return int((np.asarray(pool.ref) > 0).sum())


def bytes_in_use(pool: PagedPool) -> int:
    return blocks_in_use(pool) * pool.block_bytes


def bytes_shared(pool: PagedPool) -> int:
    """Bytes saved by sharing: every reference beyond the first to a block
    is a dense copy that was never materialized."""
    extra = np.clip(np.asarray(pool.ref) - 1, 0, None).sum()
    return int(extra) * pool.block_bytes


def check_invariants(pool: PagedPool) -> None:
    """Host-side allocator invariants (tests): refcounts non-negative, the
    free stack holds exactly the refcount-0 blocks, no duplicates."""
    ref = np.asarray(pool.ref)
    n_free = int(pool.n_free)
    free = np.asarray(pool.free)[:n_free]
    assert (ref >= 0).all(), "negative refcount"
    assert len(np.unique(free)) == n_free, "duplicate ids on the free stack"
    assert (ref[free] == 0).all(), "free-stack block with live references"
    assert int((ref > 0).sum()) + n_free == ref.shape[0], \
        "leaked block: neither referenced nor on the free stack"


# =========================================================================== #
# In-model paged decode: traced table ops over the pool's K/V planes
# =========================================================================== #
# The serving-layer shims above run eagerly (refcount bookkeeping, free-list
# pops, PoolExhausted). The decode hot loop cannot afford any of that: it is
# one jitted step, so every op below is a *pure traced function* over
#
#   * :class:`PoolKV`       — just the pool's K/V planes (refcounts and the
#     free list stay host-side in :class:`PagedStateStore`, the allocator),
#   * :class:`PagedKVCache` — one attention layer's *batched* per-lane block
#     tables plus the dense-cache metadata (per-lane ``pos``/``length``/
#     ``scores``).
#
# Allocation is pre-staged: each engine lane owns a fixed set of ``owned``
# physical blocks (reserved host-side, refcount 1, for the lane's lifetime).
# A table entry is writable iff ``blocks[i] == owned[i]``; entries spliced
# from a prefix snapshot (or handed over by preemption) fail the test and are
# **copy-on-write redirected** to the lane's reserved block on first write —
# all inside the trace, with zero free-list traffic. Compaction rewrites the
# block table and applies the cache-relative RoPE slot-delta fixup through
# pool-row gather/scatter (never materializing a dense working copy), gated
# behind ``lax.cond(any(need))`` so steps without overflow pay nothing.
class PoolKV(NamedTuple):
    """The pool's traced K/V planes (allocator state stays host-side)."""

    k: jnp.ndarray        # [n_blocks, block_size, kv_heads, head_dim]
    v: jnp.ndarray

    @property
    def n_blocks(self) -> int:
        return self.k.shape[0]

    @property
    def block_size(self) -> int:
        return self.k.shape[1]


class PagedKVCache(NamedTuple):
    """Batched in-model paged layer cache: per-lane tables + metadata.

    Leaves carry a leading lane axis (and optionally a stacked-layer axis in
    front of it, for the lax.scan over periods): ``blocks``/``owned``
    ``[..., b, max_blocks]``, ``pos``/``scores`` ``[..., b, n_slots]``,
    ``length`` ``[..., b]``. ``owned`` never changes inside the trace — it
    is the lane's reserved CoW destination set, managed by the engine.
    """

    blocks: jnp.ndarray               # [..., b, max_blocks] int32, -1 unmapped
    owned: jnp.ndarray                # [..., b, max_blocks] int32 reserved ids
    pos: jnp.ndarray                  # [..., b, n_slots] int32, -1 empty
    length: jnp.ndarray               # [..., b] int32 occupied prefix
    scores: Optional[jnp.ndarray] = None   # [..., b, n_slots] float32

    @property
    def n_slots(self) -> int:
        return self.pos.shape[-1]

    @property
    def max_blocks(self) -> int:
        return self.blocks.shape[-1]


class PagedRingCache(NamedTuple):
    """Batched in-model paged sliding-window (ring) layer cache.

    The ring invariant ``slot == pos % window`` is carried as *per-lane
    metadata alongside a block table* into the shared pool: logical ring
    slot ``j`` lives at pool row ``blocks[j // bs] * bs + j % bs`` (the
    residue-class index map), so windowed attention reads straight from
    the pool planes with no separate dense ring buffer. Two structural
    facts the ops below rely on:

    * occupied slots always form the prefix ``[0, min(next_pos, window))``
      (slot ``j`` is occupied iff some position ``p ≡ j (mod w)`` with
      ``p < next_pos`` exists, i.e. iff ``j < min(next_pos, w)``),
    * after the in-step append, every occupied slot is inside the window
      (the append overwrote exactly the slot whose entry fell out).

    ``owned`` is the lane's reserved copy-on-write destination set, exactly
    as in :class:`PagedKVCache`; a table entry is writable iff
    ``blocks[i] == owned[i]``, so entries spliced from a prefix snapshot
    (or a preemption parcel) are CoW-redirected on first write.
    """

    blocks: jnp.ndarray     # [..., b, max_blocks] int32, -1 unmapped
    owned: jnp.ndarray      # [..., b, max_blocks] int32 reserved ids
    pos: jnp.ndarray        # [..., b, window] int32, -1 empty
    next_pos: jnp.ndarray   # [..., b] int32: global position of next token

    @property
    def window(self) -> int:
        return self.pos.shape[-1]

    @property
    def max_blocks(self) -> int:
        return self.blocks.shape[-1]


def _flat_rows(x: jnp.ndarray) -> jnp.ndarray:
    """[n_blocks, bs, ...] -> [n_blocks * bs, ...] row-addressable view."""
    return x.reshape((-1,) + x.shape[2:])


def paged_gather_view(kv: PoolKV, st: PagedKVCache, n_slots: Optional[int] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Logical [b, n_slots, kv, hd] K/V view through the tables (traced).

    Unmapped slots read block 0 garbage — callers mask by ``length``."""
    bs = kv.block_size
    n_slots = n_slots if n_slots is not None else st.n_slots
    slot = jnp.arange(n_slots)
    blk = jnp.take(st.blocks, slot // bs, axis=-1)          # [b, n_slots]
    row = jnp.clip(blk, 0) * bs + slot % bs
    return _flat_rows(kv.k)[row], _flat_rows(kv.v)[row]


def paged_append(kv: PoolKV, st: PagedKVCache, k_new: jnp.ndarray,
                 v_new: jnp.ndarray, pos_new: jnp.ndarray
                 ) -> Tuple[PoolKV, PagedKVCache]:
    """Append ``T`` tokens per lane at each lane's occupied prefix end.

    k_new/v_new: [b, T, kv, hd]; pos_new: [b, T] int32. Mirrors
    :func:`repro.core.cache.append` lane-wise: the caller (maybe-compact)
    guarantees ``length + T <= n_slots``. Blocks touched by the append are
    redirected to the lane's ``owned`` reserved blocks; a shared straddled
    first block gets its live prefix rows copied (copy-on-write) before the
    new rows land. All scatters hit lane-owned blocks only, so concurrent
    lanes never collide.
    """
    b, t = pos_new.shape
    bs = kv.block_size
    mb = st.max_blocks
    nrows = kv.k.shape[0] * bs                       # OOB scatter sentinel
    L = st.length                                    # [b]
    # the dense twin's dynamic_update_slice clamps its start so the write
    # fits the buffer (an overflowing append — a never-evicting policy at
    # capacity, or a retired lane still ticking — overwrites the newest
    # slots instead of escaping). Mirror that clamp exactly: it keeps the
    # two backends token-for-token equal in the degenerate regime, and the
    # copy-on-write redirect below keeps the clamped write safe — it can
    # only ever land in the lane's own reserved blocks, never in a block a
    # prefix snapshot shares.
    start = jnp.clip(L, 0, max(st.n_slots - t, 0))   # [b]
    kflat, vflat = _flat_rows(kv.k), _flat_rows(kv.v)

    # --- copy-on-write the straddled first block when it is not ours ------ #
    bi0 = jnp.clip(start // bs, 0, mb - 1)
    off0 = start % bs
    cur0 = jnp.take_along_axis(st.blocks, bi0[:, None], axis=1)[:, 0]  # [b]
    own0 = jnp.take_along_axis(st.owned, bi0[:, None], axis=1)[:, 0]
    r = jnp.arange(bs)
    cow = (cur0 != own0)[:, None] & (r[None] < off0[:, None]) \
        & (cur0 >= 0)[:, None]                                      # [b, bs]
    src = jnp.clip(cur0, 0)[:, None] * bs + r[None]
    dst = jnp.where(cow, own0[:, None] * bs + r[None], nrows)
    copied_k, copied_v = kflat[src], vflat[src]
    kflat = kflat.at[dst].set(copied_k, mode="drop")
    vflat = vflat.at[dst].set(copied_v, mode="drop")

    # --- redirect every touched logical block to the reserved set --------- #
    bidx = jnp.arange(mb)
    touched = (bidx[None] * bs < (start + t)[:, None]) \
        & ((bidx[None] + 1) * bs > start[:, None])                  # [b, mb]
    blocks = jnp.where(touched, st.owned, st.blocks)

    # --- write the new rows ------------------------------------------------ #
    slots = start[:, None] + jnp.arange(t)[None]                    # [b, T]
    wblk = jnp.take_along_axis(blocks, jnp.clip(slots // bs, 0, mb - 1),
                               axis=1)
    wrow = jnp.where(slots < st.n_slots, wblk * bs + slots % bs, nrows)
    kflat = kflat.at[wrow].set(k_new.astype(kflat.dtype), mode="drop")
    vflat = vflat.at[wrow].set(v_new.astype(vflat.dtype), mode="drop")

    lane = jnp.arange(b)[:, None]
    pos = st.pos.at[lane, slots].set(pos_new.astype(jnp.int32), mode="drop")
    return (PoolKV(k=kflat.reshape(kv.k.shape), v=vflat.reshape(kv.v.shape)),
            st._replace(blocks=blocks, pos=pos, length=L + t))


def paged_ring_append(kv: PoolKV, st: PagedRingCache, k_new: jnp.ndarray,
                      v_new: jnp.ndarray) -> Tuple[PoolKV, PagedRingCache]:
    """Append one token per lane at ring slot ``next_pos % window``.

    The lane-batched twin of :func:`repro.models.layers.ring_append`:
    k_new/v_new are [b, 1, kv, hd]. The written block is CoW-redirected to
    the lane's ``owned`` reserved block when it is shared (spliced from a
    snapshot / preemption parcel): the block's other rows are copied first,
    so the snapshot's view stays bit-intact while the lane's view carries
    the new token. All scatters hit lane-owned blocks only.
    """
    b = st.next_pos.shape[0]
    w = st.window
    bs = kv.block_size
    mb = st.max_blocks
    nrows = kv.k.shape[0] * bs                       # OOB scatter sentinel
    slot = st.next_pos % w                           # [b]
    bi = jnp.clip(slot // bs, 0, mb - 1)
    off = slot % bs
    kflat, vflat = _flat_rows(kv.k), _flat_rows(kv.v)

    # --- copy-on-write the written block when it is not ours -------------- #
    cur = jnp.take_along_axis(st.blocks, bi[:, None], axis=1)[:, 0]   # [b]
    own = jnp.take_along_axis(st.owned, bi[:, None], axis=1)[:, 0]
    r = jnp.arange(bs)
    cow = ((cur != own) & (cur >= 0))[:, None] & (r[None] != off[:, None])
    src = jnp.clip(cur, 0)[:, None] * bs + r[None]
    dst = jnp.where(cow, own[:, None] * bs + r[None], nrows)
    copied_k, copied_v = kflat[src], vflat[src]
    kflat = kflat.at[dst].set(copied_k, mode="drop")
    vflat = vflat.at[dst].set(copied_v, mode="drop")
    lane = jnp.arange(b)
    blocks = st.blocks.at[lane, bi].set(own)

    # --- write the new row ------------------------------------------------ #
    wrow = own * bs + off                            # [b]
    kflat = kflat.at[wrow].set(k_new[:, 0].astype(kflat.dtype))
    vflat = vflat.at[wrow].set(v_new[:, 0].astype(vflat.dtype))
    pos = st.pos.at[lane, slot].set(st.next_pos)
    return (PoolKV(k=kflat.reshape(kv.k.shape), v=vflat.reshape(kv.v.shape)),
            st._replace(blocks=blocks, pos=pos, next_pos=st.next_pos + 1))


def paged_ring_rebuild(kv: PoolKV, st: PagedRingCache, rows_k: jnp.ndarray,
                       rows_v: jnp.ndarray, new_pos: jnp.ndarray,
                       new_next: jnp.ndarray) -> Tuple[PoolKV, PagedRingCache]:
    """Scatter a fully-rebuilt ring into the lane's ``owned`` blocks.

    The chunked (streaming-prefill) path rewrites every live ring slot by
    residue-class gather from ``[old ring || chunk]``; since the rebuild
    touches all live slots anyway, the whole table simply redirects to the
    reserved set (no partial CoW needed — shared blocks are left intact for
    their snapshots). rows_k/rows_v: [b, window, kv, hd] rebuilt content;
    new_pos: [b, window] (-1 = empty); new_next: [b].
    """
    b, w = new_pos.shape
    bs = kv.block_size
    mb = st.max_blocks
    nrows = kv.k.shape[0] * bs
    slot = jnp.arange(w)
    live = new_pos >= 0                                        # [b, w]
    dst_blk = jnp.take(st.owned, jnp.clip(slot // bs, 0, mb - 1), axis=1)
    dst = jnp.where(live, dst_blk * bs + slot[None] % bs, nrows)
    kflat, vflat = _flat_rows(kv.k), _flat_rows(kv.v)
    kflat = kflat.at[dst].set(rows_k.astype(kflat.dtype), mode="drop")
    vflat = vflat.at[dst].set(rows_v.astype(vflat.dtype), mode="drop")
    occ = jnp.minimum(new_next, w)                             # [b]
    blocks = jnp.where(jnp.arange(mb)[None] * bs < occ[:, None],
                       st.owned, -1)
    return (PoolKV(k=kflat.reshape(kv.k.shape), v=vflat.reshape(kv.v.shape)),
            st._replace(blocks=blocks, pos=new_pos.astype(jnp.int32),
                        next_pos=new_next.astype(jnp.int32)))


def paged_truncate(st: PagedKVCache, length, block_size: int) -> PagedKVCache:
    """Lane-wise mirror of :func:`repro.core.cache.truncate` (metadata only:
    blocks past the new occupied prefix are unmapped from the table; the
    host reconciles any shared-block references at lane retirement)."""
    length = jnp.minimum(st.length, jnp.asarray(length, jnp.int32))
    live = jnp.arange(st.n_slots)[None] < length[:, None]
    return st._replace(
        blocks=jnp.where(_dead_blocks(st, length, block_size), -1, st.blocks),
        pos=jnp.where(live, st.pos, -1),
        length=length,
        scores=None if st.scores is None
        else jnp.where(live, st.scores, 0.0))


def paged_rollback(st: PagedKVCache, drop, block_size: int) -> PagedKVCache:
    """Drop the newest ``drop`` slots per lane (speculative rollback).

    Relative twin of :func:`paged_truncate` that broadcasts over any
    leading axes, so it applies both to flat ``[b, ...]`` tables and to the
    stacked ``[n_full, b, ...]`` leaves of a decode state. Metadata only:
    rejected rows stay in the lane's owned blocks but are unmapped, so the
    next append overwrites them and the valid region is bit-identical to a
    lane that never appended them.
    """
    length = jnp.maximum(st.length - jnp.asarray(drop, jnp.int32), 0)
    live = jnp.arange(st.n_slots) < length[..., None]
    dead = jnp.arange(st.max_blocks) * block_size >= length[..., None]
    return st._replace(
        blocks=jnp.where(dead, -1, st.blocks),
        pos=jnp.where(live, st.pos, -1),
        length=length,
        scores=None if st.scores is None
        else jnp.where(live, st.scores, 0.0))


def _dead_blocks(st: PagedKVCache, length, block_size: int) -> jnp.ndarray:
    """bool[b, max_blocks]: logical blocks entirely past ``length``."""
    return jnp.arange(st.max_blocks)[None] * block_size >= length[:, None]


def _lane_keep_masks(policy, spec: LadderSpec, st: PagedKVCache, layer
                     ) -> jnp.ndarray:
    """vmap the (metadata-only) policy keep mask over lanes: bool[b, s]."""
    dummy = jnp.zeros((1, st.n_slots, 1, 1), jnp.float32)

    def one(pos, length, scores):
        c = KVCache(k=dummy, v=dummy, pos=pos, length=length, scores=scores)
        return policy.keep_mask(spec, c, layer)

    if st.scores is None:
        return jax.vmap(lambda p, l: one(p, l, None))(st.pos, st.length)
    return jax.vmap(one)(st.pos, st.length, st.scores)


def _force_keep_masks(spec: LadderSpec, st: PagedKVCache, n_incoming: int
                      ) -> jnp.ndarray:
    """Lane-wise mirror of the dense recency-truncation fallback."""
    slot = jnp.arange(st.n_slots)[None]
    target = st.n_slots - n_incoming
    return ((slot < spec.n_sink)
            | (slot >= (st.length - (target - spec.n_sink))[:, None])) \
        & (slot < st.length[:, None])


def _compact_pass(kv: PoolKV, st: PagedKVCache, keep: jnp.ndarray,
                  active: jnp.ndarray, rope_theta
                  ) -> Tuple[PoolKV, PagedKVCache]:
    """One physical compaction pass for the lanes flagged ``active``.

    Survivor rows are gathered through the *old* table, re-rotated by the
    slot delta when keys are stored cache-relative (R(a)R(b) = R(a+b) — the
    same fixup the dense path applies), and scattered into the lane's
    ``owned`` blocks, which become the new table. Inactive lanes are
    untouched (their scatter rows drop, their metadata passes through).
    """
    b, n_slots = st.pos.shape[0], st.n_slots
    bs = kv.block_size
    nrows = kv.n_blocks * bs
    perm, new_len = jax.vmap(ladder.compaction_perm)(keep)   # [b, s], [b]
    slot = jnp.arange(n_slots)[None]                         # [1, s]
    live = slot < new_len[:, None]

    src_blk = jnp.take_along_axis(st.blocks, perm // bs, axis=1)
    src_row = jnp.clip(src_blk, 0) * bs + perm % bs
    kflat, vflat = _flat_rows(kv.k), _flat_rows(kv.v)
    rows_k = kflat[src_row]                                  # [b, s, kv, hd]
    rows_v = vflat[src_row]
    if rope_theta is not None:
        from repro.models.common import apply_rope
        delta = jnp.where(live, slot - perm, 0)
        rows_k = apply_rope(rows_k, delta, rope_theta)

    dst_blk = jnp.take(st.owned, slot[0] // bs, axis=1)      # [b, s]
    write = live & (src_blk >= 0) & active[:, None]
    dst_row = jnp.where(write, dst_blk * bs + slot % bs, nrows)
    kflat = kflat.at[dst_row].set(rows_k.astype(kflat.dtype), mode="drop")
    vflat = vflat.at[dst_row].set(rows_v.astype(vflat.dtype), mode="drop")

    blocks = jnp.where(active[:, None],
                       jnp.where(_dead_blocks(st, new_len, bs),
                                 -1, st.owned),
                       st.blocks)
    pos = jnp.where(active[:, None],
                    jnp.where(live, jnp.take_along_axis(st.pos, perm, axis=1),
                              -1),
                    st.pos)
    scores = st.scores
    if scores is not None:
        scores = jnp.where(active[:, None],
                           jnp.where(live,
                                     jnp.take_along_axis(scores, perm, axis=1),
                                     0.0),
                           scores)
    length = jnp.where(active, new_len, st.length)
    return (PoolKV(k=kflat.reshape(kv.k.shape), v=vflat.reshape(kv.v.shape)),
            st._replace(blocks=blocks, pos=pos, length=length, scores=scores))


def paged_maybe_compact(kv: PoolKV, st: PagedKVCache, spec: LadderSpec, layer,
                        policy: PolicyLike, n_incoming: int = 1,
                        rope_theta=None) -> Tuple[PoolKV, PagedKVCache]:
    """Lane-wise mirror of :func:`repro.core.cache.maybe_compact`.

    Lanes whose buffer would overflow run the policy compaction pass (and,
    when that frees nothing, the forced recency pass) — the identical
    two-stage composition the dense path applies, so paged and dense decode
    stay token-for-token equal. Gated on ``lax.cond(any(need))``: the common
    no-overflow step skips the gather/scatter entirely.
    """
    policy = get_policy(policy)
    if not policy.evicts:
        return kv, st
    need = st.length + n_incoming > st.n_slots               # [b]

    def do(args):
        kv, st = args
        keep = _lane_keep_masks(policy, spec, st, layer)
        kv, st = _compact_pass(kv, st, keep, need, rope_theta)
        still = st.length + n_incoming > st.n_slots

        def force(args2):
            kv2, st2 = args2
            keep2 = _force_keep_masks(spec, st2, n_incoming)
            return _compact_pass(kv2, st2, keep2, still, rope_theta)

        return jax.lax.cond(jnp.any(still), force, lambda a: a, (kv, st))

    return jax.lax.cond(jnp.any(need), do, lambda a: a, (kv, st))


def paged_draft_compact(kv: PoolKV, st: PagedKVCache, spec: LadderSpec, layer,
                        policy: PolicyLike, rope_theta=None
                        ) -> Tuple[PoolKV, PagedKVCache]:
    """Compact a forked draft view down to ``spec.budget`` live slots.

    The draft fork of a live lane reuses the exact keep-mask + RoPE
    slot-delta machinery of :func:`paged_maybe_compact`, but targets the
    (much smaller) draft budget and runs the copy pass for EVERY lane —
    lanes already under the draft budget keep all their rows, but those
    rows are still scattered into the draft's ``owned`` blocks. The
    resulting draft view never aliases a live block, which is what lets
    it outlive the wave that forked it: live appends, compactions and
    block releases cannot touch draft-owned storage, so no refcounts need
    to be held on the live tables and the CoW discipline ("a writable
    table entry is never shared") keeps holding for the live lanes. ``st``
    must carry the draft's own fully-covering ``owned`` reservation.
    """
    policy = get_policy(policy)
    copy = jnp.ones_like(st.length, dtype=bool)     # every lane copies
    if policy.evicts:
        keep = _lane_keep_masks(policy, spec, st, layer)
    else:
        keep = _force_keep_masks(spec, st, st.n_slots - spec.budget)
    # lanes under budget must keep everything (their policy mask may
    # assume an over-budget lane); the copy still detaches them
    under = st.length <= spec.budget
    keep = jnp.where(under[:, None], st.pos >= 0, keep)
    kv, st = _compact_pass(kv, st, keep, copy, rope_theta)
    still = st.length > spec.budget

    def force(args2):
        kv2, st2 = args2
        keep2 = _force_keep_masks(spec, st2, st2.n_slots - spec.budget)
        return _compact_pass(kv2, st2, keep2, still, rope_theta)

    return jax.lax.cond(jnp.any(still), force, lambda a: a, (kv, st))


def paged_observe(policy, st: PagedKVCache, probs: jnp.ndarray
                  ) -> PagedKVCache:
    """Lane-wise ``policy.observe``: fold per-lane attention probabilities
    ``[b, heads, q, n_slots]`` into per-lane score accumulators — the exact
    per-lane computation the vmapped dense path performs."""
    if st.scores is None:
        return st
    dummy = jnp.zeros((1, st.n_slots, 1, 1), jnp.float32)
    dpos = jnp.full((st.n_slots,), -1, jnp.int32)

    def one(sc, p):
        c = KVCache(k=dummy, v=dummy, pos=dpos,
                    length=jnp.zeros((), jnp.int32), scores=sc)
        return policy.observe(c, p[None]).scores

    return st._replace(scores=jax.vmap(one)(st.scores, probs))


# =========================================================================== #
# PagedStateStore: whole decode-state snapshots with structural sharing
# =========================================================================== #
@dataclasses.dataclass(eq=False)
class _TableSet:
    """Block tables replacing one KVCache node (len > 1 <=> stacked node)."""

    tables: List[BlockTable]
    stacked: bool


@dataclasses.dataclass(eq=False)
class PagedSnapshot:
    """One stored pytree: dense leaves by reference, KV content as tables."""

    leaves: List[Any]
    treedef: Any
    owned_bytes: int          # newly-allocated block bytes + dense leaf bytes
    dense_bytes: int = 0      # the dense (non-KV-block) share of owned_bytes
    released: bool = False


@dataclasses.dataclass(eq=False)
class TableSnapshot:
    """An in-model snapshot: a refcount *fork* of a live lane's block tables.

    No K/V bytes are copied at snapshot time — the snapshot is the concrete
    per-layer table/metadata arrays plus one pool reference per mapped block
    (taken by the engine via :meth:`PagedStateStore.retain_blocks`). The
    structure of ``tables`` mirrors the decode state: ``{"blocks": {key:
    layer}, "tail": {key: layer}}`` where each layer is a dict of numpy
    arrays ``blocks``/``pos``/``length``/``scores`` (stacked over the
    period-scan instances for "blocks" entries).
    """

    tables: dict
    state_pos: "np.ndarray"       # the lane's absolute next-token position
    dense_bytes: int = 0          # bytes riding along dense: table metadata
    #                               (pos/scores/next_pos) AND whole SSM
    #                               states (conv/ssm) — pool blocks carry
    #                               only KV content, so per-lane SSM leaves
    #                               must be charged here or hybrid
    #                               snapshots are under-accounted
    released: bool = False

    def block_ids(self) -> "np.ndarray":
        ids: List[int] = []
        for section in self.tables.values():
            for layer in section.values():
                blk = layer.get("blocks")
                if blk is None:           # SSM layers page nothing
                    continue
                blk = np.asarray(blk).reshape(-1)
                ids.extend(blk[blk >= 0].tolist())
        return np.asarray(ids, np.int64)


def _is_kv(x) -> bool:
    return isinstance(x, KVCache)


def _unstack_kv(node: KVCache) -> Tuple[List[KVCache], bool]:
    """A stacked node (leaves [n_full, 1, n_slots, ...]) -> unit caches."""
    if node.length.ndim == 0:
        return [node], False
    n = node.length.shape[0]
    units = [KVCache(
        k=node.k[i], v=node.v[i], pos=node.pos[i], length=node.length[i],
        scores=None if node.scores is None else node.scores[i])
        for i in range(n)]
    return units, True


def _restack_kv(units: List[KVCache], stacked: bool) -> KVCache:
    if not stacked:
        return units[0]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *units)


class PagedStateStore:
    """Content store for decode-state pytrees over one global block pool.

    ``put`` swaps every :class:`KVCache` node for block tables (sharing
    whole-block prefixes with a parent snapshot when the positions agree —
    the lineage produced by chunked prefill snapshots), ``get`` gathers a
    dense state back (bit-exact for everything the dense semantics observe),
    ``release`` returns the snapshot's references to the pool. Raises
    :class:`PoolExhausted` (pre-checked, no partial mutation) when the free
    list cannot hold a snapshot — callers evict and retry.
    """

    def __init__(self, n_blocks: int, block_size: int, kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16):
        self.pool = init_pool(n_blocks, block_size, kv_heads, head_dim, dtype)
        self.puts = 0
        self.gets = 0
        self.peak_bytes = 0
        self.planes_detached = False
        #: optional () -> int: distinct blocks held by prefix-cache
        #: entries, for actionable PoolExhausted messages (the engine
        #: registers this — the store cannot see the cache)
        self.pressure_context = None
        self._sanitizer = None
        # published metric handles (no-ops until bind_metrics)
        from repro.obs.metrics import NULL_INSTRUMENT
        self._m_alloc = NULL_INSTRUMENT
        self._m_release = NULL_INSTRUMENT
        self._m_exhausted = NULL_INSTRUMENT
        from repro.analysis import sanitizer as _sanlib
        if _sanlib.enabled():
            _sanlib.attach_store(self)

    def bind_metrics(self, registry) -> None:
        """Publish allocator activity into a metrics registry (the engine
        calls this at construction): block alloc/release event counters and
        :class:`PoolExhausted` pressure, plus snapshot-time callback gauges
        for free blocks / bytes in use / utilization (sampled only at
        export, so the allocator hot path never reads the device)."""
        self._m_alloc = registry.counter(
            "pool_blocks_allocated_total", "fresh blocks popped (refcount 1)")
        self._m_release = registry.counter(
            "pool_block_releases_total",
            "block references dropped (frees when the refcount reaches 0)")
        self._m_exhausted = registry.counter(
            "pool_exhausted_total",
            "allocations refused by an empty free list (callers evict "
            "prefix entries and retry)")
        if registry.enabled:
            registry.gauge_fn("pool_blocks_free",
                              lambda: int(self.pool.n_free),
                              "blocks on the free stack")
            registry.gauge_fn("pool_blocks_total",
                              lambda: int(self.pool.ref.shape[0]),
                              "physical blocks in the pool")
            registry.gauge_fn("pool_bytes_in_use",
                              lambda: self.bytes_in_use,
                              "physical bytes of live blocks")
            registry.gauge_fn(
                "pool_utilization",
                lambda: 1.0 - int(self.pool.n_free)
                / max(1, int(self.pool.ref.shape[0])),
                "fraction of pool blocks live")

    def _cache_blocks(self) -> Optional[int]:
        if self.pressure_context is None:
            return None
        try:
            return int(self.pressure_context())
        except Exception:       # telemetry must never mask the real error
            return None

    @property
    def block_size(self) -> int:
        return self.pool.block_size

    @property
    def bytes_in_use(self) -> int:
        return bytes_in_use(self.pool)

    @property
    def bytes_shared(self) -> int:
        return bytes_shared(self.pool)

    @property
    def free_blocks(self) -> int:
        return int(self.pool.n_free)

    # -- host-side allocator API for the in-model paged path --------------- #
    # The traced decode step never touches refcounts or the free list; the
    # engine pre-stages ownership through these eager primitives (lane
    # reserved sets, snapshot forks, preemption handoffs).
    def detach_planes(self, sharding=None) -> "PoolKV":
        """Hand the pool's K/V planes over to the in-model decode state.

        The in-model path keeps all KV content in the traced
        :class:`PoolKV` (updated in place via buffer donation) and uses the
        store purely as the allocator — keeping a second full-size set of
        planes here would silently double the largest allocation in the
        system. The store retains a 1-block stub (shape metadata for
        ``block_bytes``); the content paths (:meth:`put`/:meth:`get`)
        refuse afterwards.

        ``sharding`` (a :class:`jax.sharding.NamedSharding` for one plane,
        mesh serving) places the detached planes across the mesh at the
        handoff — the single point where the system's largest allocation
        changes owner, so no full-size replicated copy ever needs to exist
        on one device afterwards. The allocator state the store keeps
        (refcounts, free list) stays host-global regardless: sharding
        never touches it.
        """
        if self.planes_detached:
            raise RuntimeError("pool planes already detached")
        k, v = self.pool.k, self.pool.v
        if sharding is not None:
            k = jax.device_put(k, sharding)
            v = jax.device_put(v, sharding)
        kvp = PoolKV(k=k, v=v)
        self.pool = self.pool._replace(k=self.pool.k[:1], v=self.pool.v[:1])
        self.planes_detached = True
        return kvp

    def alloc_blocks(self, n: int) -> np.ndarray:
        """Pop ``n`` fresh block ids off the free stack (refcount 1)."""
        if n == 0:
            return np.zeros((0,), np.int64)
        free = int(self.pool.n_free)
        if n > free:
            self._m_exhausted.inc()
            raise exhausted(self.pool, n, what="lane block reservation: ",
                            cache_blocks=self._cache_blocks())
        self._m_alloc.inc(n)
        ids = np.asarray(self.pool.free)[free - n:free][::-1].astype(np.int64)
        self.pool = self.pool._replace(
            ref=self.pool.ref.at[jnp.asarray(ids)].set(1),
            n_free=jnp.asarray(free - n, jnp.int32))
        self.peak_bytes = max(self.peak_bytes, self.bytes_in_use)
        return ids

    def retain_blocks(self, ids) -> None:
        """Add one reference per id (snapshot fork / prefix splice)."""
        ids = np.asarray(ids, np.int64)
        if ids.size:
            self.pool = _incref(self.pool, jnp.asarray(ids, jnp.int32))

    def release_blocks(self, ids) -> None:
        """Drop one reference per id; blocks reaching 0 return to the
        free stack."""
        ids = np.asarray(ids, np.int64)
        if ids.size:
            self._m_release.inc(ids.size)
            self.pool = _decref(self.pool, jnp.asarray(ids, jnp.int32))

    def put(self, tree, parent: Optional[PagedSnapshot] = None
            ) -> Tuple[PagedSnapshot, int]:
        """Store a pytree; returns (snapshot, owned_bytes). ``owned_bytes``
        counts only newly-allocated blocks plus dense (non-KV) leaves — the
        unique cost of this snapshot at insert time."""
        if self.planes_detached:
            raise RuntimeError("pool planes were detached (in-model paged "
                               "decode owns the content); put/get are the "
                               "store-backed fallback's API")
        leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_kv)
        pleaves = None
        if parent is not None and not parent.released \
                and treedef == parent.treedef:
            pleaves = parent.leaves
        bs = self.pool.block_size
        # plan pass: compute sharing + total demand before touching the pool
        plan, needed = [], 0
        for i, leaf in enumerate(leaves):
            if not _is_kv(leaf):
                continue
            units, stacked = _unstack_kv(leaf)
            ptabs = None
            if pleaves is not None and isinstance(pleaves[i], _TableSet) \
                    and len(pleaves[i].tables) == len(units):
                ptabs = pleaves[i].tables
            entry = []
            for j, unit in enumerate(units):
                shared = 0
                if ptabs is not None:
                    shared = shared_prefix_blocks(ptabs[j], unit, bs)
                want = blocks_for(max(int(unit.length), 0), bs) if \
                    int(unit.length) > 0 else 0
                needed += max(want - shared, 0)
                entry.append((unit, None if ptabs is None else ptabs[j],
                              shared))
            plan.append((i, entry, stacked))
        if needed > self.free_blocks:
            self._m_exhausted.inc()
            raise exhausted(self.pool, needed, what="state snapshot: ",
                            cache_blocks=self._cache_blocks())

        out = list(leaves)
        for i, entry, stacked in plan:
            tables = []
            for unit, ptab, shared in entry:
                self.pool, table = from_dense(
                    self.pool, unit, parent=ptab, shared_blocks=shared)
                tables.append(table)
            out[i] = _TableSet(tables=tables, stacked=stacked)
        dense_bytes = sum(int(leaf.size) * leaf.dtype.itemsize
                          for leaf in leaves
                          if not _is_kv(leaf) and hasattr(leaf, "dtype"))
        owned = needed * self.pool.block_bytes + dense_bytes
        self.puts += 1
        self.peak_bytes = max(self.peak_bytes, self.bytes_in_use)
        return PagedSnapshot(leaves=out, treedef=treedef, owned_bytes=owned,
                             dense_bytes=dense_bytes), owned

    def get(self, snap: PagedSnapshot):
        """Materialize the stored pytree (gathers KV through the tables)."""
        if self.planes_detached:
            raise RuntimeError("pool planes were detached (in-model paged "
                               "decode owns the content)")
        if snap.released:
            raise ValueError("snapshot was released back to the pool")
        leaves = [
            _restack_kv([gather(self.pool, t) for t in leaf.tables],
                        leaf.stacked)
            if isinstance(leaf, _TableSet) else leaf
            for leaf in snap.leaves]
        self.gets += 1
        return jax.tree.unflatten(snap.treedef, leaves)

    def release(self, snap) -> None:
        """Return the snapshot's block references to the pool (idempotent).

        Accepts both :class:`PagedSnapshot` (paged-out pytrees) and
        :class:`TableSnapshot` (in-model lane forks)."""
        if snap.released:
            return
        if isinstance(snap, TableSnapshot):
            self.release_blocks(snap.block_ids())
            snap.released = True
            return
        for leaf in snap.leaves:
            if isinstance(leaf, _TableSet):
                for t in leaf.tables:
                    self.pool = release(self.pool, t)
        snap.released = True

    def snapshot_refcounts(self, snap: PagedSnapshot) -> np.ndarray:
        """Pool refcounts of every block the snapshot maps (telemetry)."""
        ids: List[int] = []
        for leaf in snap.leaves:
            if isinstance(leaf, _TableSet):
                for t in leaf.tables:
                    b = np.asarray(t.blocks)
                    ids.extend(b[b >= 0].tolist())
        return np.asarray(self.pool.ref)[np.asarray(ids, np.int64)] \
            if ids else np.zeros((0,), np.int32)
