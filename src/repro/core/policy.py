"""First-class eviction policies for the budgeted KV cache.

LaCache's contribution is a *policy* — which slots survive a compaction
pass — so policies are objects, not strings dispatched ad hoc. An
:class:`EvictionPolicy` bundles everything the cache/model/serving layers
need to run a policy:

* :meth:`keep_mask`  — bool[n_slots] survivor mask for one compaction pass,
* ``needs_scores``   — whether the attention kernel must hand back
  attention probabilities (H2O/TOVA; the paper's FlashAttention-
  incompatibility argument),
* :meth:`observe`    — fold a step's attention probabilities into the
  cache's score accumulator (no-op for score-free policies),
* ``evicts``         — False for the full-cache baseline, letting the
  cache skip the compaction cond entirely.

A registry maps the legacy string names (``"lacache"``, ``"streaming"``,
``"h2o"``, ``"tova"``, ``"full"``) to singleton policy instances so every
existing config / CLI call site keeps working: :func:`get_policy` accepts
either a name or an already-constructed policy object. New policies plug in
via :func:`register_policy` without touching the model core::

    @register_policy
    class MyPolicy(EvictionPolicy):
        name = "mine"
        def keep_mask(self, spec, cache, layer):
            ...

Policy instances are stateless (all running state lives in the cache
pytree), hashable, and compared by identity — safe to close over in jitted
functions and to pass as static arguments.
"""
from __future__ import annotations

from typing import Dict, List, Union

import jax.numpy as jnp

from repro.core import ladder
from repro.core.ladder import LadderSpec


class EvictionPolicy:
    """Base class / protocol for KV-cache eviction policies.

    Subclasses set ``name`` and implement :meth:`keep_mask`; policies that
    rank slots by attention mass additionally set ``needs_scores = True``
    and implement :meth:`observe`.
    """

    name: str = ""
    #: attention kernels must return probabilities for this policy
    needs_scores: bool = False
    #: False => the cache never compacts (full-cache baseline)
    evicts: bool = True

    def keep_mask(self, spec: LadderSpec, cache, layer) -> jnp.ndarray:
        """bool[n_slots] — True for slots surviving this compaction pass.

        ``cache`` is a :class:`repro.core.cache.KVCache`; ``layer`` is the
        cache-bearing layer ordinal (traced or static int).
        """
        raise NotImplementedError

    def observe(self, cache, probs):
        """Fold one step's attention probabilities into the cache scores.

        probs: [batch, heads, q, n_slots]. Returns the (possibly updated)
        cache; the default is a no-op for score-free policies.
        """
        return cache

    def keep_mask_np(self, spec: LadderSpec, length: int, layer: int):
        """Numpy twin of :meth:`keep_mask` over ``length`` occupied slots,
        for the pure-python stream simulation (analysis benchmarks /
        property tests). Optional — score-based policies have no
        closed-form simulation."""
        raise NotImplementedError(
            f"policy {self.name!r} has no numpy stream simulation")

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
_REGISTRY: Dict[str, EvictionPolicy] = {}

PolicyLike = Union[str, EvictionPolicy]


def register_policy(policy) -> EvictionPolicy:
    """Register a policy instance (or class, which is instantiated).

    Usable as a decorator on an ``EvictionPolicy`` subclass. Re-registering
    a name overwrites it (latest wins), so tests can shadow built-ins.
    Returns the registered instance (or the class when used as a decorator).
    """
    obj = policy() if isinstance(policy, type) else policy
    if not isinstance(obj, EvictionPolicy):
        raise TypeError(f"not an EvictionPolicy: {policy!r}")
    if not obj.name:
        raise ValueError(f"policy {policy!r} has no name")
    _REGISTRY[obj.name] = obj
    return policy


def get_policy(policy: PolicyLike) -> EvictionPolicy:
    """Resolve a policy name (or pass through a policy object).

    The single string->object shim: every other module consumes
    EvictionPolicy objects and calls this once at its boundary.
    """
    if isinstance(policy, EvictionPolicy):
        return policy
    try:
        return _REGISTRY[policy]
    except KeyError:
        raise ValueError(
            f"unknown eviction policy {policy!r}; "
            f"registered: {sorted(_REGISTRY)}") from None


def policy_names() -> List[str]:
    """Registered policy names (CLI choices derive from this)."""
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------- #
# Built-in policies
# --------------------------------------------------------------------------- #
@register_policy
class LaCachePolicy(EvictionPolicy):
    """The paper's ladder keep-pattern (Sec. 3.2-3.3)."""

    name = "lacache"

    def keep_mask(self, spec, cache, layer):
        return ladder.ladder_keep_mask(spec, cache.n_slots, cache.length, layer)

    def keep_mask_np(self, spec, length, layer):
        return ladder.ladder_keep_mask_np(spec, length, layer)


@register_policy
class StreamingPolicy(EvictionPolicy):
    """StreamingLLM-as-block-eviction: sinks + newest fraction of middle."""

    name = "streaming"

    def keep_mask(self, spec, cache, layer):
        return ladder.streaming_keep_mask(spec, cache.n_slots, cache.length,
                                          layer)

    def keep_mask_np(self, spec, length, layer):
        import numpy as np
        slot = np.arange(length)
        middle = length - spec.n_sink
        n_keep = max(int(middle * 0.5), spec.n_recent)
        return (slot < spec.n_sink) | (slot >= length - n_keep)


def _score_topk_keep_mask(spec: LadderSpec, cache) -> jnp.ndarray:
    """Shared H2O/TOVA rule: sinks + recent window + top-scored middle half.

    Requires ``cache.scores`` (attention probabilities — the XLA attention
    path only; this is the paper's FlashAttention-incompatibility argument).
    """
    assert cache.scores is not None, \
        "score-based policies require attention scores"
    n_slots = cache.n_slots
    slot = jnp.arange(n_slots)
    occupied = slot < cache.length
    is_sink = slot < spec.n_sink
    is_recent = slot >= (cache.length - spec.n_recent)
    middle = occupied & ~is_sink & ~is_recent
    n_middle = jnp.sum(middle)
    n_keep = n_middle // 2
    neg = jnp.finfo(jnp.float32).min
    sc = jnp.where(middle, cache.scores, neg)
    # threshold at the n_keep-th largest middle score
    order = jnp.argsort(-sc)                      # descending
    rank = jnp.argsort(order)                     # rank of each slot
    top = middle & (rank < n_keep)
    return (is_sink | is_recent | top) & occupied


@register_policy
class H2OPolicy(EvictionPolicy):
    """H2O (Zhang et al., 2024): heavy hitters by *accumulated* attention."""

    name = "h2o"
    needs_scores = True

    def keep_mask(self, spec, cache, layer):
        return _score_topk_keep_mask(spec, cache)

    def observe(self, cache, probs):
        if cache.scores is None:
            return cache
        s = probs.astype(jnp.float32).sum(axis=(0, 1, 2))
        return cache._replace(scores=cache.scores + s)


@register_policy
class TOVAPolicy(EvictionPolicy):
    """TOVA (Oren et al., 2024): importance = the LAST query's attention."""

    name = "tova"
    needs_scores = True

    def keep_mask(self, spec, cache, layer):
        return _score_topk_keep_mask(spec, cache)

    def observe(self, cache, probs):
        if cache.scores is None:
            return cache
        s = probs.astype(jnp.float32).sum(axis=(0, 1, 2))
        return cache._replace(scores=s)


@register_policy
class FullCachePolicy(EvictionPolicy):
    """Never evicts — the full-cache quality/memory baseline."""

    name = "full"
    evicts = False

    def keep_mask(self, spec, cache, layer):
        return cache.valid_mask()
