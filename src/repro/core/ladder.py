"""Ladder-shaped KV cache pattern math (LaCache, ICML 2025, Sec. 3.2-3.3).

Geometry
--------
Let ``L`` be the number of cache-bearing layers, ``S`` the *span* (layers that
retain the KV of the same token chunk), ``O`` the *overlap* between consecutive
bands, ``C`` the chunk width in tokens.  Band stride ``D = S - O >= 1``.
Rungs per ladder ``K = ceil(L / D)``; ladder token width ``W = K * C``.

A middle-region slot ``t`` (sinks and the recent window excluded) belongs to
chunk ``j = t // C`` and rung ``r = j mod K``; it is **kept at layer l iff
l in [r*D, r*D + S)`` — with the last rung's band extended to ``L-1`` (the
paper's footnote 1, "avoid bubbles").

*Iterative compaction* re-applies the same mask over **slot** indices of the
already-compacted cache, which geometrically thins old tokens (Fig. 4).

Two implementations live here:
  * jnp functions (traced; used inside jitted serve/prefill steps),
  * numpy simulation (:func:`simulate_stream`) used by analysis benchmarks
    (pattern Pareto, retention heatmaps) and property tests.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LaCacheConfig


class LadderSpec(NamedTuple):
    """Resolved static ladder geometry for one model."""

    n_layers: int   # number of cache-bearing layers L
    span: int       # S
    overlap: int    # O
    chunk: int      # C
    n_sink: int
    n_recent: int
    budget: int     # per-layer slot budget B

    @property
    def stride(self) -> int:
        return max(1, self.span - self.overlap)

    @property
    def n_rungs(self) -> int:
        return max(1, math.ceil(self.n_layers / self.stride))

    @property
    def ladder_width(self) -> int:
        return self.n_rungs * self.chunk


def make_spec(cfg: LaCacheConfig, n_layers: int) -> LadderSpec:
    r = cfg.resolve(n_layers)
    return LadderSpec(
        n_layers=n_layers, span=r.span, overlap=r.overlap, chunk=r.chunk,
        n_sink=r.n_sink, n_recent=r.n_recent, budget=r.budget)


# --------------------------------------------------------------------------- #
# Band membership
# --------------------------------------------------------------------------- #
def band_bounds(spec: LadderSpec, rung) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[lo, hi) layer band of a rung, last band extended to L (footnote 1)."""
    lo = rung * spec.stride
    hi = jnp.minimum(lo + spec.span, spec.n_layers)
    hi = jnp.where(rung == spec.n_rungs - 1, spec.n_layers, hi)
    return lo, hi


def rung_kept_at_layer(spec: LadderSpec, rung, layer) -> jnp.ndarray:
    lo, hi = band_bounds(spec, rung)
    return (layer >= lo) & (layer < hi)


# --------------------------------------------------------------------------- #
# Keep masks (jnp, traced)
# --------------------------------------------------------------------------- #
def ladder_keep_mask(spec: LadderSpec, n_slots: int, length, layer) -> jnp.ndarray:
    """Keep mask of one compaction pass at ``layer`` over a cache of ``length``
    occupied slots (out of ``n_slots``).  bool[n_slots].

    kept = sinks  |  recent window  |  ladder band membership.
    Empty slots (>= length) are never kept.
    """
    slot = jnp.arange(n_slots)
    occupied = slot < length
    is_sink = slot < spec.n_sink
    is_recent = slot >= (length - spec.n_recent)
    m = slot - spec.n_sink                    # middle-region offset
    rung = (m // spec.chunk) % spec.n_rungs
    in_band = rung_kept_at_layer(spec, rung, layer)
    keep = is_sink | is_recent | in_band
    return keep & occupied


def streaming_keep_mask(spec: LadderSpec, n_slots: int, length, layer,
                        keep_middle_frac: float = 0.5) -> jnp.ndarray:
    """StreamingLLM-as-block-eviction: keep sinks + newest fraction of middle.

    Classic StreamingLLM evicts one oldest slot per step; to share the
    amortized-compaction machinery we evict a block at a time (keeping the
    newest ``keep_middle_frac`` of the middle region), which preserves the
    sink+recency semantics exactly between compactions.
    """
    del layer
    slot = jnp.arange(n_slots)
    occupied = slot < length
    is_sink = slot < spec.n_sink
    middle = length - spec.n_sink
    n_keep = (middle.astype(jnp.float32) * keep_middle_frac).astype(jnp.int32)
    n_keep = jnp.maximum(n_keep, spec.n_recent)
    is_recent = slot >= (length - n_keep)
    return (is_sink | is_recent) & occupied


def compaction_perm(keep: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable permutation moving kept slots to the front.

    Returns (perm[n_slots], new_length). jnp.argsort is stable, so survivor
    order (= age order) is preserved — the invariant iterative compaction
    relies on.
    """
    perm = jnp.argsort(~keep)  # False(=0, kept) sorts first; stable
    new_length = jnp.sum(keep).astype(jnp.int32)
    return perm, new_length


# --------------------------------------------------------------------------- #
# Static / numpy analysis utilities
# --------------------------------------------------------------------------- #
def ladder_keep_mask_np(spec: LadderSpec, length: int, layer: int) -> np.ndarray:
    slot = np.arange(length)
    is_sink = slot < spec.n_sink
    is_recent = slot >= (length - spec.n_recent)
    m = slot - spec.n_sink
    rung = (m // spec.chunk) % spec.n_rungs
    lo = rung * spec.stride
    hi = np.minimum(lo + spec.span, spec.n_layers)
    hi = np.where(rung == spec.n_rungs - 1, spec.n_layers, hi)
    in_band = (layer >= lo) & (layer < hi)
    return is_sink | is_recent | in_band


def simulate_stream(spec: LadderSpec, n_tokens: int,
                    policy: str = "lacache") -> "StreamSim":
    """Simulate iterative compaction over a token stream.

    Returns per-layer lists of retained original token positions after
    ingesting ``n_tokens`` tokens one at a time with budget ``spec.budget``.
    Pure-python/numpy; used by analysis benchmarks and property tests.
    Any registered policy with a ``keep_mask_np`` simulation works.
    """
    # function-level import: policy.py imports this module
    from repro.core.policy import get_policy
    pol = get_policy(policy)
    L = spec.n_layers
    kept = [list(range(0)) for _ in range(L)]
    compactions = [0] * L
    for t in range(n_tokens):
        for l in range(L):
            if len(kept[l]) >= spec.budget:
                length = len(kept[l])
                mask = pol.keep_mask_np(spec, length, l)
                kept[l] = [p for p, k in zip(kept[l], mask) if k]
                compactions[l] += 1
            kept[l].append(t)
    return StreamSim(kept=kept, compactions=compactions)


class StreamSim(NamedTuple):
    kept: list       # per-layer list of retained original positions
    compactions: list

    def coverage(self) -> np.ndarray:
        """Per-layer retained counts."""
        return np.array([len(k) for k in self.kept])

    def union_span(self) -> int:
        """Number of distinct original positions retained in >=1 layer."""
        u = set()
        for k in self.kept:
            u.update(k)
        return len(u)

    def retention_of(self, pos: int) -> float:
        """Fraction of layers still holding original position ``pos``."""
        return float(np.mean([pos in set(k) for k in self.kept]))


def random_pattern_keep_mask_np(rng: np.random.Generator, n_layers: int,
                                length: int, keep_per_layer: int,
                                n_sink: int, n_recent: int) -> np.ndarray:
    """A random (layer x slot) keep pattern with the same per-layer budget —
    the Fig. 3 baseline population."""
    mask = np.zeros((n_layers, length), dtype=bool)
    mask[:, :n_sink] = True
    mask[:, length - n_recent:] = True
    middle = np.arange(n_sink, length - n_recent)
    n_extra = max(0, keep_per_layer - n_sink - n_recent)
    for l in range(n_layers):
        if len(middle) and n_extra:
            sel = rng.choice(middle, size=min(n_extra, len(middle)), replace=False)
            mask[l, sel] = True
    return mask
