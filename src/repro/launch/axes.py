"""Logical-axis sharding registry (MaxText-style logical->mesh axis rules).

Model code annotates activations with ``shard(x, "batch", None, "heads", ...)``
and parameter initializers attach logical axis tuples per leaf. The launcher
installs concrete rules (e.g. ``{"batch": ("pod", "data"), "heads": "model"}``)
before tracing; outside a mesh context everything is a no-op, so the same
model code runs on 1 CPU device and on the 512-chip mesh unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

AxisRule = Union[None, str, Tuple[str, ...]]

# Default production rules (DESIGN.md §6). "fsdp" is the parameter-sharding
# axis group; "batch" the activation batch axes.
SINGLE_POD_RULES: Dict[str, AxisRule] = {
    "batch": "data",
    "fsdp": "data",
    "model": "model",
    "seq": None,
    "experts": "model",
    "moe_dm": "data",   # expert weights: FSDP d_model dim in training
    "moe_ff": None,
    "res_seq": "model",  # Megatron-SP: residual stream sharded along seq
    "slots": None,
}


def multi_pod_rules() -> Dict[str, AxisRule]:
    return {
        "batch": ("pod", "data"),
        "fsdp": ("pod", "data"),
        "model": "model",
        "seq": None,
        "experts": "model",
        "moe_dm": ("pod", "data"),
        "moe_ff": None,
        "res_seq": "model",
        "slots": None,
    }


def serving_rules(multi_pod: bool = False) -> Dict[str, AxisRule]:
    """Weight-resident 2D tensor-parallel serving sharding (§Perf iter 1).

    Decode must not all-gather FSDP weight shards per token. Instead, weights
    stay sharded over BOTH mesh axes (row-parallel d_model over "data",
    col-parallel heads/d_ff over "model") and the per-layer collectives are
    tiny activation partial-sum all-reduces. Batch is replicated within a pod
    (decode activations are KBs); the KV cache shards its *slot* axis over
    both axes. Multi-pod: each pod serves half the batch (data-parallel
    replicas at the pod level).
    """
    return {
        "batch": "pod" if multi_pod else None,
        "fsdp": "data",            # row-parallel: contraction-dim resident
        "model": "model",
        "seq": None,
        "experts": "model",
        "residual": "data",        # activations sharded on d_model: row-
                                   # parallel matmuls do partial-sum
                                   # all-reduces instead of weight gathers
        "moe_dm": None,            # serving: shard expert d_ff over data
        "moe_ff": "data",          # instead -> tiny (e_loc,C,d) reduce
        "cache_kv": None,          # kv heads usually < |model| here
        "cache_slots": ("data", "model"),
        "cache_dinner": "model",   # match mamba activation sharding (no
                                   # di resharding between state and z-gate)
    }


def current_rules() -> Optional[Dict[str, AxisRule]]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def logical_axis_rules(rules: Dict[str, AxisRule], mesh: Mesh):
    prev_r = getattr(_state, "rules", None)
    prev_m = getattr(_state, "mesh", None)
    _state.rules, _state.mesh = dict(rules), mesh
    try:
        with mesh:
            yield
    finally:
        _state.rules, _state.mesh = prev_r, prev_m


def to_partition_spec(logical: Sequence[Optional[str]],
                      rules: Optional[Dict[str, AxisRule]] = None) -> P:
    rules = rules if rules is not None else (current_rules() or {})
    spec, used = [], set()
    for name in logical:
        r = rules.get(name) if name else None
        if r is None:
            spec.append(None)
            continue
        axes = (r,) if isinstance(r, str) else tuple(r)
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        spec.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*spec)


def _mesh_axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = (entry,) if isinstance(entry, str) else entry
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without active rules).

    Axes whose size is not divisible by the mapped mesh extent are left
    unconstrained (e.g. 12 attention heads on a 16-way model axis)."""
    rules, mesh = current_rules(), current_mesh()
    if rules is None or mesh is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs logical {logical}")
    spec = list(to_partition_spec(logical, rules))
    for i, entry in enumerate(spec):
        if entry is not None and x.shape[i] % _mesh_axis_size(mesh, entry):
            spec[i] = None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
