"""Training launcher.

On the CPU dev box this trains reduced-config models end to end; on a real
cluster the same entry point shards over the production mesh (the dry-run
proves each full config lowers).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs import get_config
from repro.data.pipeline import CorpusConfig, SyntheticCorpus, lm_batches
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab_size=args.vocab)
    print(f"training {cfg.name} ({'reduced' if args.reduced else 'FULL'}) "
          f"L={cfg.n_layers} d={cfg.d_model} vocab={cfg.vocab_size}")

    params, _ = M.init(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size,
                                          seed=args.seed))
    batches = {}
    extra = {}
    if cfg.n_patches:
        extra["patches"] = np.random.default_rng(0).normal(
            size=(args.batch, cfg.n_patches, M.PATCH_DIM)).astype(np.float32)
    if cfg.encoder_layers:
        extra["frames"] = np.random.default_rng(0).normal(
            size=(args.batch, cfg.n_audio_frames, M.FRAME_DIM)).astype(np.float32)

    def gen():
        for b in lm_batches(corpus, args.batch, args.seq, args.steps,
                            seed=args.seed):
            yield dict(b, **extra)

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps // 10),
                       total_steps=args.steps)
    params, hist = trainer.train(cfg, params, gen(), ocfg)
    if args.ckpt:
        ckpt.save(args.ckpt, params)
        print("saved", args.ckpt)
    print("final loss:", hist["loss"][-1])


if __name__ == "__main__":
    main()
