"""Concrete sharding construction: logical axes -> NamedSharding pytrees for
params, optimizer state, step inputs and decode state (DESIGN.md §6).

KV cache rule: shard kv-head axis over ``model`` when it divides evenly;
otherwise shard the *slot* axis over ``model`` (MQA/GQA-small case — XLA SPMD
inserts the partial-softmax all-reduce)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.cache import CrossKVCache, KVCache, MambaState
from repro.kernels.pool_mesh import PoolMeshSpec
from repro.launch import axes as axlib
from repro.models.layers import RingKVCache


def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = (entry,) if isinstance(entry, str) else entry
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def _safe(mesh: Mesh, spec, shape) -> P:
    """Drop partition entries whose mesh extent doesn't divide the dim
    (e.g. batch=1 long-context decode, 12-head models on 16-way TP)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is not None and (i >= len(shape)
                                  or shape[i] % _axis_size(mesh, entry)):
            out.append(None)
        else:
            out.append(entry)
    return P(*out)


def param_shardings(mesh: Mesh, rules: Dict[str, Any], logical_axes,
                    params_sds):
    """Map the per-leaf logical axis tuples from model.init to shardings."""
    def one(axes_tuple, sds):
        spec = axlib.to_partition_spec(axes_tuple, rules)
        return _ns(mesh, _safe(mesh, spec, sds.shape))
    is_axes = lambda x: isinstance(x, tuple) and \
        all(a is None or isinstance(a, str) for a in x)
    return jax.tree.map(one, logical_axes, params_sds, is_leaf=is_axes)


def opt_state_shardings(mesh, rules, logical_axes, opt_state_sds):
    """AdamW state: step replicated; m/v shadow the param shardings."""
    pshard = param_shardings(mesh, rules, logical_axes, opt_state_sds.m)
    return type(opt_state_sds)(
        step=_ns(mesh, P()), m=pshard,
        v=jax.tree.map(lambda s: s, pshard))


def batch_axes(rules) -> P:
    return axlib.to_partition_spec(("batch",), rules)


def _kv_cache_sharding(mesh, rules, cfg: ModelConfig, leading: int):
    """Sharding for KVCache leaves with ``leading`` stacked scan dims."""
    model_size = mesh.shape.get("model", 1)
    bspec = axlib.to_partition_spec(("batch",), rules)[0]
    lead = (None,) * leading
    if cfg.n_kv_heads % model_size == 0:
        kv_spec = P(*lead, bspec, None, "model", None)
    else:
        kv_spec = P(*lead, bspec, "model", None, None)   # shard slots
    return kv_spec


def decode_state_shardings(mesh, rules, cfg: ModelConfig, state_sds):
    """Pytree of NamedShardings matching an init_decode_state structure.

    Cache axes consult the rules: "cache_kv" (kv-head axis; default "model"
    when divisible), "cache_slots" (slot axis; default picks "model" when kv
    heads don't divide), "cache_dinner" (Mamba d_inner; default "model")."""
    bspec = axlib.to_partition_spec(("batch",), rules)[0]
    model_size = mesh.shape.get("model", 1)
    kv_rule = rules.get("cache_kv", "model")
    kv_ok = kv_rule is not None and cfg.n_kv_heads % _axis_size(mesh, kv_rule) == 0
    slots_rule = rules.get("cache_slots",
                           None if kv_ok else "model")
    dinner_rule = rules.get("cache_dinner", "model")

    def for_leaf(path, leaf):
        # path: tuple of keys; leading dim is the scan-stacked period dim
        # inside state["blocks"], absent in tail.
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        in_blocks = "blocks" in keys and "cross_blocks" not in keys
        lead = 1 if (in_blocks or "cross_blocks" in keys) else 0
        nd = leaf.ndim
        spec = [None] * nd
        if nd >= 2 + lead:
            spec[lead] = bspec  # batch dim right after stacking dim
        if nd == 4 + lead:      # [.., b, slots, kv, hd] KV or ring
            if kv_ok:
                spec[lead + 2] = kv_rule
            spec[lead + 1] = slots_rule
        elif nd == 3 + lead:    # mamba ssm [.., b, di, n] / conv [.., b, dc-1, di]
            if leaf.shape[-1] == cfg.d_state:
                spec[lead + 1] = dinner_rule
            else:
                spec[lead + 2] = dinner_rule
        elif nd <= 1 + lead:    # pos [slots] / length scalars
            spec = [None] * nd
        return _ns(mesh, _safe(mesh, P(*spec), leaf.shape))

    return jax.tree_util.tree_map_with_path(for_leaf, state_sds)


# --------------------------------------------------------------------------- #
# Sharded paged serving: pool-plane + paged-decode-state shardings
# --------------------------------------------------------------------------- #
# The physical pool planes are the one piece of serving state where silent
# replication is NOT acceptable: a dropped partition entry quietly re-inflates
# per-chip HBM by the model-axis factor — the exact failure the sharded pool
# exists to remove. Params keep the lenient `_safe` behaviour (a 12-head model
# on 16-way TP should train, just replicated); pool planes get a loud error.
def pool_plane_spec(mesh, cfg: ModelConfig, *, page_size: int,
                    axis: str = "model") -> P:
    """PartitionSpec for the pool's K/V planes ``[n_blocks, bs, kv, hd]``.

    Applies the KV rule (module docstring): kv-head axis over ``axis`` when
    it divides; otherwise the in-block slot axis (MQA/GQA-small — attention
    then merges per-shard partial softmaxes with an all-reduce). When
    neither divides, raises a loud :class:`ValueError` naming the axis and
    suggesting a divisible ``page_size``/``kv_heads`` pairing — never the
    silent replication ``_safe`` applies to params.
    """
    m = dict(mesh.shape).get(axis, 1)
    if m <= 1:
        return P(None, None, None, None)
    if cfg.n_kv_heads % m == 0:
        return P(None, None, axis, None)
    if page_size % m == 0:
        return P(None, axis, None, None)
    ps_up = -(-page_size // m) * m
    kv_up = -(-cfg.n_kv_heads // m) * m
    raise ValueError(
        f"cannot shard the paged KV pool over mesh axis {axis!r} "
        f"(extent {m}): neither kv_heads={cfg.n_kv_heads} nor "
        f"page_size={page_size} is divisible by it. Pick a divisible "
        f"pairing — e.g. page_size={ps_up} (slot-sharded planes) or "
        f"kv_heads={kv_up} (head-sharded planes) — or use a mesh whose "
        f"{axis!r} extent divides one of them. Silent replication is not "
        f"applied here: it would re-inflate per-chip HBM by {m}x.")


def paged_pool_mesh_spec(mesh, cfg: ModelConfig, *, page_size: int,
                         max_batch: int) -> PoolMeshSpec:
    """Resolve one engine's pool-mesh routing (kernel dispatch + placement).

    ``kv_axis``/``slot_axis`` follow :func:`pool_plane_spec` (loud on
    failure); ``lane_axis`` shards the batch-lane axis over ``data`` only
    when ``max_batch`` divides it (lanes replicate silently otherwise —
    lane metadata is small, unlike the planes).
    """
    spec = pool_plane_spec(mesh, cfg, page_size=page_size)
    kv_axis = spec[2]
    slot_axis = spec[1]
    data = dict(mesh.shape).get("data", 1)
    lane_axis = "data" if data > 1 and max_batch % data == 0 else None
    return PoolMeshSpec(mesh=mesh, kv_axis=kv_axis, slot_axis=slot_axis,
                        lane_axis=lane_axis)


def paged_state_shardings(mesh, cfg: ModelConfig, state, *, page_size: int,
                          max_batch: int):
    """NamedSharding pytree for an ``init_paged_decode_state`` structure.

    Pool planes (``state.kv_pool``) take the strict :func:`pool_plane_spec`
    (kv-head or slot axis over ``model``); every other leaf is per-lane
    metadata (block tables, slot positions, lengths, SSM states, the
    per-lane ``pos`` clock) and shards its lane axis over ``data`` when the
    batch divides — with the lenient `_safe` drop, since replicated tables
    cost KBs, not the pool's GBs. The allocator (refcounts, free list)
    never appears here: it stays host-side in :class:`PagedStateStore`.
    """
    plane_spec = pool_plane_spec(mesh, cfg, page_size=page_size)
    pm = paged_pool_mesh_spec(mesh, cfg, page_size=page_size,
                              max_batch=max_batch)
    lane = pm.lane_axis

    def for_leaf(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        if "kv_pool" in keys:
            return _ns(mesh, plane_spec)
        # PagedKVCache itself has a field named "blocks", so only the
        # state-level container position marks the scan-stacked period dim
        lead = 1 if keys and keys[0] == "blocks" else 0
        nd = getattr(leaf, "ndim", 0)
        spec = [None] * nd
        if nd > lead:
            spec[lead] = lane
        return _ns(mesh, _safe(mesh, P(*spec), leaf.shape))

    return jax.tree_util.tree_map_with_path(for_leaf, state)


def train_batch_shardings(mesh, rules, batch_sds):
    bspec = axlib.to_partition_spec(("batch",), rules)[0]

    def one(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim >= 1:
            spec[0] = bspec
        return _ns(mesh, _safe(mesh, P(*spec), leaf.shape))

    return jax.tree.map(one, batch_sds)


def replicated(mesh, tree):
    return jax.tree.map(lambda _: _ns(mesh, P()), tree)
