"""Production mesh construction (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e production mesh: 16x16 = 256 chips/pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1D (data,) mesh — smoke tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_serving_mesh(spec: str):
    """Parse a ``--mesh DxM`` spec (e.g. ``"4x2"``) into a ``(data, model)``
    mesh for ``Engine(mesh=...)``.

    ``D*M`` must equal the visible device count (on CPU, force it with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``). The ``model``
    extent is what shards the paged pool's kv-head (or in-block slot)
    axis; ``data`` shards the batch-lane axis when ``max_batch`` divides
    it."""
    parts = str(spec).lower().split("x")
    if len(parts) != 2:
        raise ValueError(
            f"--mesh expects 'DATAxMODEL' (e.g. '4x2'), got {spec!r}")
    try:
        d, m = (int(p) for p in parts)
    except ValueError:
        raise ValueError(
            f"--mesh expects 'DATAxMODEL' (e.g. '4x2'), got {spec!r}")
    if d < 1 or m < 1:
        raise ValueError(f"--mesh extents must be >= 1, got {spec!r}")
    n = len(jax.devices())
    if d * m != n:
        raise ValueError(
            f"--mesh {spec!r} needs {d * m} devices but {n} are visible "
            "(on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count)")
    return jax.make_mesh((d, m), ("data", "model"))


# v5e hardware constants for the roofline (DESIGN.md §6)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~per-direction)
