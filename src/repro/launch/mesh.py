"""Production mesh construction (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e production mesh: 16x16 = 256 chips/pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1D (data,) mesh — smoke tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


# v5e hardware constants for the roofline (DESIGN.md §6)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~per-direction)
