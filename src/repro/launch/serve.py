"""Serving launcher: batched generation / streaming scoring with LaCache.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --policy lacache --budget 128 --prompt-len 256 --max-new 64

``--policy`` choices come from the eviction-policy registry
(:mod:`repro.core.policy`) and ``--admission`` choices from the admission
registry (:mod:`repro.serving.admission`), so a newly registered policy is
servable with no launcher edits. ``--request-mode`` drives the
continuous-batching request API (Engine.submit/run) with staggered prompt
lengths instead of one lockstep batch; ``--share-prefix`` makes every
request extend one long common prompt prefix through the shared-prefix
cache; ``--bucket-prefill`` pads prompts to power-of-two buckets so mixed
lengths share prefill executables; ``--stream`` prints tokens as they are
sampled (per-request on_token callback).

Observability (:mod:`repro.obs`): ``--trace-out trace.json`` records the
request lifecycle (submit -> admit -> prefill -> decode ticks -> retire,
plus preempt/resume and spec waves) as Chrome/Perfetto ``trace_event``
JSON — load it at https://ui.perfetto.dev or ``chrome://tracing``.
``--metrics-out metrics.prom`` exports the engine's metric registry in
Prometheus text format (``.json`` extension switches to the JSON
snapshot). Either flag arms real (non-null) instrumentation.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs import get_config
from repro.core.policy import policy_names
from repro.data.pipeline import CorpusConfig, SyntheticCorpus
from repro.models import model as M
from repro.serving.admission import admission_names
from repro.serving.engine import Engine, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--policy", default="lacache", choices=policy_names())
    ap.add_argument("--admission", default="fifo", choices=admission_names(),
                    help="request admission order (registry-derived)")
    ap.add_argument("--budget", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--request-mode", action="store_true",
                    help="serve via Engine.submit/run (continuous batching, "
                         "staggered prompt lengths) instead of lockstep")
    ap.add_argument("--share-prefix", action="store_true",
                    help="request-mode: all prompts extend one common "
                         "prefix; serve it through the shared-prefix cache")
    ap.add_argument("--bucket-prefill", action="store_true",
                    help="request-mode: pad prompts to power-of-two buckets "
                         "(one prefill executable per bucket instead of "
                         "per length)")
    ap.add_argument("--stream", action="store_true",
                    help="request-mode: print tokens as they are sampled "
                         "(on_token)")
    ap.add_argument("--kv-backend", default="dense",
                    choices=("dense", "paged"),
                    help="KV memory backend: 'paged' decodes through "
                         "per-request block tables into one physical pool "
                         "(in-model paged decode on all decoder-only "
                         "archs — budgeted slots, ring windows as "
                         "residue-class tables, SSM states per-lane; "
                         "prefix hits splice shared blocks, snapshots are "
                         "refcount forks, preemption is a table handoff; "
                         "cross-attention/M-RoPE archs fall back to "
                         "store-backed snapshots with dense decode)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged backend: slots per physical block")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="paged backend: serve through a sharded pool on a "
                         "(data, model) device mesh, e.g. '4x2' (model "
                         "shards the pool's kv-head — or in-block slot — "
                         "axis; data shards the batch lanes; the host-side "
                         "allocator stays global). D*M must equal the "
                         "visible device count; on CPU force it with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON of the "
                         "request lifecycle (open at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="export the metrics registry: Prometheus text "
                         "exposition, or a JSON snapshot when PATH ends "
                         "in .json")
    args = ap.parse_args()
    if not args.request_mode and (args.share_prefix or args.bucket_prefill
                                  or args.stream):
        print("note: --share-prefix/--bucket-prefill/--stream apply only "
              "with --request-mode; ignoring")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, lacache=dataclasses.replace(
        cfg.lacache, policy=args.policy, budget=args.budget))
    params, _ = M.init(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt:
        params = ckpt.load(args.ckpt, params)

    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import Tracer
    metrics = MetricsRegistry() if (args.metrics_out
                                    or args.trace_out) else None
    tracer = Tracer() if args.trace_out else None
    mesh = None
    if args.mesh is not None:
        if args.kv_backend != "paged":
            ap.error("--mesh requires --kv-backend paged (it shards the "
                     "physical pool planes)")
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.mesh)
    eng = Engine(cfg, params, budget=args.budget, max_batch=args.batch,
                 admission=args.admission,
                 bucket_prefill=args.bucket_prefill,
                 kv_backend=args.kv_backend, page_size=args.page_size,
                 mesh=mesh, metrics=metrics, tracer=tracer)
    print(f"policy={args.policy} admission={args.admission} "
          f"kv-backend={args.kv_backend} "
          + (f"mesh={args.mesh} " if mesh is not None else "")
          + f"budget={args.budget} prompt={args.prompt_len} new={args.max_new}")

    if args.request_mode:
        on_token = None
        if args.stream:
            def on_token(req, tok):
                print(f"  [req {req.request_id}] tok {len(req.output_tokens)}"
                      f"/{req.max_new_tokens}: {tok}")
        shared = corpus.stream(args.prompt_len, seed=999)
        for i in range(args.batch):
            if args.share_prefix:
                # every request extends the same long prefix -> only the
                # first pays full prefill, the rest prefill their tail
                tail = corpus.stream(8 + 4 * i, seed=i)
                prompt = np.concatenate([shared, tail])
            else:
                prompt = corpus.stream(max(8, args.prompt_len - 16 * i),
                                       seed=i)
            # staggered priorities/deadlines give non-FIFO admission
            # policies something to reorder; deadlines are instants on the
            # engine clock so the SLO metrics read sensibly
            eng.submit(prompt, args.max_new, SamplingParams(seed=i),
                       priority=i % 3,
                       deadline=time.perf_counter()
                       + 30.0 + float(args.batch - i),
                       cache_prefix=args.share_prefix, on_token=on_token)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.output_tokens) for r in done)
        print(f"served {len(done)} requests / {n_tok} tokens in {dt:.2f}s "
              f"({n_tok/dt:.1f} tok/s incl. compile)")
        print(f"prefill: {eng.prefill_tokens} tokens in "
              f"{eng.prefill_dispatches} dispatches over "
              f"{len(eng.prefill_shapes)} distinct shapes; "
              f"prefix hit rate {eng.prefix_hit_rate:.2f} "
              f"({eng.prefix_tokens_reused} tokens reused)")
        if args.kv_backend == "paged":
            mode = ("in-model (decode through block tables)"
                    if eng._paged_in_model
                    else "store-backed (dense decode, pooled snapshots)")
            print(f"paged pool [{mode}]: {eng.kv_bytes_in_use/1e6:.2f} MB "
                  f"live ({eng.lane_owned_bytes/1e6:.2f} MB lane reserve), "
                  f"{eng.bytes_shared/1e6:.2f} MB deduplicated by block "
                  f"sharing; {eng.preemptions} preemptions")
            if mesh is not None:
                print(f"  sharded pool: "
                      f"{eng.kv_pool_bytes_per_device/1e6:.2f} MB of "
                      f"plane bytes resident per device")
        print("sample:", done[0].tokens[:32].tolist())
    else:
        prompts = np.stack([corpus.stream(args.prompt_len, seed=i)
                            for i in range(args.batch)])
        t0 = time.perf_counter()
        out = eng.generate(prompts, args.max_new)
        dt = time.perf_counter() - t0
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({args.batch*args.max_new/dt:.1f} tok/s incl. compile)")
        print("sample:", out[0, :32].tolist())
    state = eng.new_state(args.batch)
    print(f"cache bytes/layer-state: {eng.cache_bytes(state)/1e6:.2f} MB "
          f"(constant in sequence length — the paper's O(1) claim)")
    if tracer is not None:
        n = tracer.export(args.trace_out)
        print(f"trace: {n} events -> {args.trace_out} "
              f"(open at https://ui.perfetto.dev)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(metrics.to_json() if args.metrics_out.endswith(".json")
                    else metrics.to_prometheus())
        print(f"metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
