"""ShapeDtypeStruct stand-ins for every (arch x input-shape) workload.

``input_specs`` returns abstract inputs (no allocation) for the three step
kinds; decode shapes build the decode-state structure via ``jax.eval_shape``.
Decode cache budgets (DESIGN.md §5):
  * decode_32k  — full-cache baseline n_slots = 32768, LaCache variant 4096,
  * long_500k   — LaCache budget 16384 (O(1) memory is what makes this shape
                  feasible at all for attention archs — the paper's claim).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.models import model as M

DECODE_LACACHE_BUDGET = 4096
LONG_BUDGET = 16384


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def decode_budget(cfg: ModelConfig, shape: ShapeConfig, policy) -> int:
    from repro.core.policy import get_policy
    if shape.name == "long_500k":
        return LONG_BUDGET
    if not get_policy(policy).evicts:     # full-cache baseline
        return shape.seq_len
    return DECODE_LACACHE_BUDGET


def cfg_for_run(cfg: ModelConfig, shape: ShapeConfig, policy: str) -> ModelConfig:
    lc = dataclasses.replace(
        cfg.lacache, policy=policy,
        budget=decode_budget(cfg, shape, policy))
    return dataclasses.replace(cfg, lacache=lc)


def abstract_params(cfg: ModelConfig) -> Tuple[Any, Any]:
    """(param ShapeDtypeStructs, logical axes) without allocating."""
    from repro.models.common import abstract_init
    with abstract_init():
        shapes, axes = M.init(cfg, jax.random.PRNGKey(0))
    return shapes, axes


def input_specs(cfg: ModelConfig, shape: ShapeConfig, policy: str,
                params_sds=None) -> Dict[str, Any]:
    """Abstract step inputs. For decode, includes the decode-state SDS."""
    b, t = shape.global_batch, shape.seq_len
    run_cfg = cfg_for_run(cfg, shape, policy)
    extras: Dict[str, Any] = {}
    text_t = t
    if cfg.n_patches > 0:
        text_t = t - cfg.n_patches
        extras["patches"] = sds((b, cfg.n_patches, M.PATCH_DIM), "float32")
    if cfg.encoder_layers > 0:
        extras["frames"] = sds((b, cfg.n_audio_frames, M.FRAME_DIM), "float32")

    if shape.mode == "train":
        return {"cfg": run_cfg,
                "batch": dict(tokens=sds((b, text_t + 1), "int32"), **extras)}
    if shape.mode == "prefill":
        return {"cfg": run_cfg,
                "tokens": sds((b, text_t), "int32"), **extras,
                "n_slots": DECODE_LACACHE_BUDGET}
    # decode
    n_slots = decode_budget(cfg, shape, policy)
    assert params_sds is not None

    def build_state(params):
        frames = None
        if cfg.encoder_layers > 0:
            frames = jnp.zeros((b, cfg.n_audio_frames, M.FRAME_DIM), jnp.float32)
        st = M.init_decode_state(params, run_cfg, b, n_slots, frames=frames)
        return st

    state_sds = jax.eval_shape(build_state, params_sds)
    return {"cfg": run_cfg, "state": state_sds,
            "tokens": sds((b, 1), "int32")}
