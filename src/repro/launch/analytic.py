"""Analytic FLOP / HBM-byte models per (arch x shape) for the roofline.

Why analytic: XLA's HloCostAnalysis counts while bodies once (see
hlo_analysis.py), so scanned models report ~1/n_layers of true FLOPs. These
closed forms are the standard MFU accounting (6ND + attention quadratic term;
MaxText-style), extended for local windows, MoE dispatch and SSM scans.
All quantities are GLOBAL (whole step, all devices); divide by chip count
for per-device terms.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig


def _dtype_bytes(cfg: ModelConfig) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def attention_context(cfg: ModelConfig, shape: ShapeConfig, policy,
                      budget: int) -> Dict[str, float]:
    """Average attended context per query token, per layer kind."""
    from repro.core.policy import get_policy
    t = shape.seq_len
    if shape.mode == "decode":
        ctx_global = budget if get_policy(policy).evicts else t
        ctx_local = min(cfg.sliding_window or 0, t)
        return {"global": ctx_global, "local": ctx_local, "queries": 1}
    # train/prefill: causal average t/2; local: window
    return {"global": t / 2,
            "local": min(cfg.sliding_window or t, t),
            "queries": t}


def flops(cfg: ModelConfig, shape: ShapeConfig, policy: str, budget: int,
          params_active: int) -> Dict[str, float]:
    b = shape.global_batch
    ctx = attention_context(cfg, shape, policy, budget)
    tq = ctx["queries"]
    tokens = b * tq
    h, hd = cfg.n_heads, cfg.head_dim_
    n_global = cfg.n_cache_layers + (cfg.encoder_layers if shape.mode != "decode" else 0)
    n_local = cfg.n_local_layers

    # parameter matmuls: 2 FLOPs per param per token (fwd)
    f_param = 2.0 * params_active * tokens
    # attention score+value matmuls: 4 * tokens * ctx * h * hd per layer
    f_attn = 4.0 * tokens * h * hd * (
        n_global * ctx["global"] + n_local * ctx["local"])
    if cfg.cross_attention:
        f_attn += 4.0 * tokens * h * hd * cfg.n_layers * cfg.n_audio_frames
    # mamba scan: ~9 flops per (channel, state) per token
    f_ssm = 9.0 * tokens * cfg.n_mamba_layers * cfg.d_inner * cfg.d_state
    # MoE gshard dispatch/combine einsums: 4 * tokens * E*C * d, E*C ~= cf*k*S
    f_moe_disp = 0.0
    if cfg.n_experts:
        gs = cfg.moe_group_size
        s = gs if (tq >= gs and tq % gs == 0) else max(int(tq), 1) or max(b, 1)
        if tq < gs:
            s = max(int(tq), 1)
        ec = cfg.capacity_factor * cfg.top_k * s
        n_moe = sum(1 for sp in cfg.layer_specs() if sp.moe)
        f_moe_disp = 4.0 * tokens * ec * cfg.d_model * n_moe

    fwd = f_param + f_attn + f_ssm + f_moe_disp
    total = 3.0 * fwd if shape.mode == "train" else fwd
    return {"fwd": fwd, "total": total, "attn": f_attn, "param": f_param,
            "ssm": f_ssm, "moe_dispatch": f_moe_disp}


def hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, policy: str, budget: int,
              params_total: int) -> Dict[str, float]:
    """Global HBM traffic model for one step."""
    b = shape.seq_len and shape.global_batch
    t = shape.seq_len
    dt = _dtype_bytes(cfg)
    kv_b = 2 * cfg.n_kv_heads * cfg.head_dim_ * dt  # K+V bytes per tok/layer
    p_bytes = params_total * dt

    if shape.mode == "decode":
        from repro.core.policy import get_policy
        ctx = budget if get_policy(policy).evicts else t
        cache_read = (cfg.n_cache_layers * ctx
                      + cfg.n_local_layers * min(cfg.sliding_window or 0, ctx)
                      ) * b * kv_b
        ssm_state = cfg.n_mamba_layers * b * cfg.d_inner * (cfg.d_state * 4 + dt * cfg.d_conv)
        act = 40.0 * cfg.n_layers * b * cfg.d_model * dt
        return {"params": p_bytes, "cache": cache_read + ssm_state,
                "act": act, "total": p_bytes + cache_read + ssm_state + act}
    # train / prefill: weights (+grad/opt traffic for train), activations, kv
    tokens = b * t
    act_per_layer = 14.0 * tokens * cfg.d_model * dt     # coarse live-tensor traffic
    act = act_per_layer * cfg.n_layers
    kv_write = cfg.n_cache_layers * tokens * kv_b
    if shape.mode == "train":
        opt = params_total * (4 * 2 + 4 + dt)            # m,v rw + grad + weight
        total = p_bytes + opt + 2.0 * act                # fwd + bwd(recompute) traffic
        return {"params": p_bytes, "opt": float(opt), "act": 2 * act,
                "total": total + kv_write, "cache": kv_write}
    return {"params": p_bytes, "act": act, "cache": kv_write,
            "total": p_bytes + act + kv_write}
