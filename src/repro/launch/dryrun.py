"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on the production mesh and record memory/cost/collective
analyses for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""
# The next two lines MUST run before any other import (jax locks the device
# count on first init): 512 placeholder host devices for the production mesh.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import functools
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import INPUT_SHAPES
from repro.launch import axes as axlib
from repro.launch import shapes as shapeslib
from repro.launch import sharding as shardlib
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.train import trainer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_TYPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|"
                      r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum result bytes of every collective op in post-SPMD HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        for kind in _COLL_KINDS:
            # count plain and -start forms; skip -done (same tensor twice)
            tok = rhs.find(kind)
            if tok < 0:
                continue
            after = rhs[tok + len(kind):]
            if after.startswith("-done"):
                continue
            if not (after.startswith("(") or after.startswith("-start(")):
                continue
            type_part = rhs[:tok]
            b = _shape_bytes(type_part)
            out[kind]["count"] += 1
            out[kind]["bytes"] += b
            break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def count_params(params_sds, top_k: int, n_experts: int):
    """(total, active) parameter counts; expert tensors scale by k/E."""
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_sds)[0]:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "moe" in keys and any(k in ("w_up", "w_gate", "w_down")
                                 for k in keys):
            active += n * top_k // max(1, n_experts)
        else:
            active += n
    return total, active


# --------------------------------------------------------------------------- #
def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  policy: str, sharding_mode: str = "fsdp",
                  microbatches: int = 1, bf16_boundary: bool = False):
    cfg = get_config(arch)
    if bf16_boundary:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, bf16_boundary_accum=True)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if sharding_mode == "serving":
        rules = axlib.serving_rules(multi_pod)
    else:
        rules = axlib.multi_pod_rules() if multi_pod else axlib.SINGLE_POD_RULES

    with axlib.logical_axis_rules(rules, mesh):
        params_sds, axes = shapeslib.abstract_params(cfg)
        pshard = shardlib.param_shardings(mesh, rules, axes, params_sds)
        spec = shapeslib.input_specs(cfg, shape, policy, params_sds)
        run_cfg = spec["cfg"]

        if shape.mode == "train":
            ocfg = adamw.AdamWConfig()
            step = trainer.make_train_step(run_cfg, ocfg,
                                           microbatches=microbatches)
            opt_sds = jax.eval_shape(adamw.init, params_sds)
            oshard = shardlib.opt_state_shardings(mesh, rules, axes, opt_sds)
            bshard = shardlib.train_batch_shardings(mesh, rules, spec["batch"])
            jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard))
            lowered = jitted.lower(params_sds, opt_sds, spec["batch"])
        elif shape.mode == "prefill":
            n_slots = spec["n_slots"]

            def pf(params, tokens, patches=None, frames=None):
                return M.prefill(params, run_cfg, tokens, n_slots=n_slots,
                                 patches=patches, frames=frames)

            args = [params_sds, spec["tokens"]]
            shards = [pshard,
                      shardlib.train_batch_shardings(mesh, rules,
                                                     spec["tokens"])]
            kw = {}
            for name in ("patches", "frames"):
                if name in spec:
                    kw[name] = spec[name]
            if kw:
                # fold kwargs into positionals for sharding control
                names = sorted(kw)

                def pf2(params, tokens, *extra):
                    return pf(params, tokens, **dict(zip(names, extra)))

                for n in names:
                    args.append(kw[n])
                    shards.append(shardlib.train_batch_shardings(
                        mesh, rules, kw[n]))
                lowered = jax.jit(pf2, in_shardings=tuple(shards)).lower(*args)
            else:
                lowered = jax.jit(pf, in_shardings=tuple(shards)).lower(*args)
        else:  # decode
            def step(params, state, tokens):
                return M.decode_step(params, run_cfg, state, tokens)

            sshard = shardlib.decode_state_shardings(mesh, rules, run_cfg,
                                                     spec["state"])
            tshard = shardlib.train_batch_shardings(mesh, rules,
                                                    spec["tokens"])
            lowered = jax.jit(step, in_shardings=(pshard, sshard, tshard)) \
                .lower(params_sds, spec["state"], spec["tokens"])
    return lowered, params_sds, cfg, shape, mesh


def run_one(arch: str, shape_name: str, multi_pod: bool, policy: str,
            outdir: str, verbose: bool = True,
            sharding_mode: str = "fsdp", tag: str = "",
            microbatches: int = 1, bf16_boundary: bool = False) -> Dict[str, Any]:
    t0 = time.perf_counter()
    lowered, params_sds, cfg, shape, mesh = build_lowered(
        arch, shape_name, multi_pod, policy, sharding_mode, microbatches,
        bf16_boundary)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        } if mem is not None else None
    except Exception:
        mem_d = None
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    from repro.launch import analytic, hlo_analysis
    coll_weighted = hlo_analysis.analyze_collectives(hlo)
    n_dev = mesh.devices.size
    total_p, active_p = count_params(params_sds, cfg.top_k, cfg.n_experts)
    from repro.launch.shapes import decode_budget
    budget = decode_budget(cfg, shape, policy)
    fl = analytic.flops(cfg, shape, policy, budget, active_p)
    hb = analytic.hbm_bytes(cfg, shape, policy, budget, total_p)

    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * active_p * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * active_p * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * active_p * tokens

    # analytic per-device bytes of the resident state (params [+cache])
    def tree_bytes(t):
        return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(t))

    rec = {
        "arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod else "16x16",
        "policy": policy, "n_devices": int(n_dev),
        "status": "ok",
        "per_device_flops": cost.get("flops"),
        "per_device_bytes_accessed": cost.get("bytes accessed"),
        "cost_analysis_keys": sorted(cost)[:40],
        "memory_analysis": mem_d,
        "collectives_flat": coll,
        "collectives": coll_weighted,
        "analytic_flops": fl,
        "analytic_hbm_bytes": hb,
        "budget": budget,
        "params_total": int(total_p), "params_active": int(active_p),
        "params_bytes_global": tree_bytes(params_sds),
        "model_flops_global": float(model_flops),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_bytes": len(hlo),
        "sharding_mode": sharding_mode,
    }
    os.makedirs(outdir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fn = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}_{policy}{suffix}.json"
    with open(os.path.join(outdir, fn), "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        print(f"[ok] {arch} {shape_name} mesh={'2x16x16' if multi_pod else '16x16'} "
              f"policy={policy} flops/dev={cost.get('flops', 0):.3e} "
              f"coll={coll['total_bytes']:.3e}B lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="no")
    from repro.core.policy import policy_names
    ap.add_argument("--policy", default=None, choices=policy_names(),
                    help="default: lacache for decode/prefill, n/a for train")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sharding", default="fsdp", choices=["fsdp", "serving"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--bf16-boundary", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            policy = args.policy or ("lacache" if
                                     INPUT_SHAPES[shape_name].mode != "train"
                                     else "full")
            for mp in pods:
                try:
                    run_one(arch, shape_name, mp, policy, args.out,
                            sharding_mode=args.sharding, tag=args.tag,
                            microbatches=args.microbatch,
                            bf16_boundary=args.bf16_boundary)
                except Exception as e:
                    failures.append((arch, shape_name, mp, repr(e)))
                    print(f"[FAIL] {arch} {shape_name} mp={mp}: {e}",
                          flush=True)
                    traceback.print_exc()
                jax.clear_caches()
    if failures:
        print(f"{len(failures)} failures")
        raise SystemExit(1)
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
