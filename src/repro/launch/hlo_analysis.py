"""While-loop-aware post-SPMD HLO analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, not
x trip-count — for scan-over-layers models that undercounts FLOPs, bytes and
collectives by ~n_layers (verified against analytic 6ND; EXPERIMENTS.md
§Dry-run). This module parses the HLO text into computations, extracts each
while's static trip count (largest integer constant in its condition
computation — XLA canonicalizes counted loops to ``iter < K``), and sums
collective result-bytes with multipliers along the call graph.

``conditional`` branches (LaCache's lax.cond compaction) are counted at full
multiplicity on every branch — an upper bound; the compaction branch actually
runs ~1/(chunk) of steps, noted in the roofline.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1}

COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")

_TYPE_RE = re.compile(
    r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|f8e4m3fn|f8e5m2|f8e4m3)"
    r"\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_CALL_REF = re.compile(r"(?:body|condition|branch_computations|to_apply|called_computations)="
                       r"(?:{([^}]*)}|%?([\w.\-]+))")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m and s.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if s.startswith("ENTRY"):
                    entry_name = cur
        else:
            if s == "}":
                cur = None
            else:
                comps[cur].append(s)
    comps["__entry__"] = [entry_name]  # type: ignore
    return comps


def _line_collective(line: str) -> Optional[Tuple[str, int]]:
    eq = line.find(" = ")
    if eq < 0:
        return None
    rhs = line[eq + 3:]
    for kind in COLL_KINDS:
        tok = rhs.find(kind)
        if tok < 0:
            continue
        after = rhs[tok + len(kind):]
        if after.startswith("-done"):
            return None
        if not (after.startswith("(") or after.startswith("-start(")):
            continue
        return kind, _shape_bytes(rhs[:tok])
    return None


def _callees(line: str) -> List[str]:
    out = []
    for m in _CALL_REF.finditer(line):
        if m.group(1) is not None:
            for part in m.group(1).split(","):
                out.append(part.strip().lstrip("%"))
        else:
            out.append(m.group(2))
    return out


def _while_parts(line: str) -> Optional[Tuple[str, str]]:
    if re.search(r"\bwhile\(", line) is None:
        return None
    body = re.search(r"body=%?([\w.\-]+)", line)
    cond = re.search(r"condition=%?([\w.\-]+)", line)
    if body and cond:
        return body.group(1), cond.group(1)
    return None


def _trip_count(comps: Dict[str, List[str]], cond_name: str) -> int:
    """Largest small-integer constant in the condition computation."""
    best = 1
    for line in comps.get(cond_name, []):
        for m in _CONST_RE.finditer(line):
            v = int(m.group(1))
            if 1 < v <= 10_000_000:
                best = max(best, v)
    return best


def analyze_collectives(hlo: str) -> Dict[str, Any]:
    """Trip-count-weighted collective result-bytes by kind."""
    comps = split_computations(hlo)
    entry = comps.pop("__entry__")[0]
    totals = {k: {"count": 0.0, "bytes": 0.0} for k in COLL_KINDS}
    seen_guard = [0]

    def walk(name: str, mult: float, depth: int):
        if depth > 12 or seen_guard[0] > 200000:
            return
        for line in comps.get(name, []):
            seen_guard[0] += 1
            wp = _while_parts(line)
            if wp:
                body, cond = wp
                trip = _trip_count(comps, cond)
                walk(body, mult * trip, depth + 1)
                continue
            col = _line_collective(line)
            if col:
                kind, b = col
                totals[kind]["count"] += mult
                totals[kind]["bytes"] += mult * b
            for callee in _callees(line):
                if callee in comps and "while" not in line:
                    walk(callee, mult, depth + 1)

    if entry:
        walk(entry, 1.0, 0)
    out: Dict[str, Any] = {k: {"count": round(v["count"], 1),
                               "bytes": float(v["bytes"])}
                           for k, v in totals.items()}
    out["total_bytes"] = float(sum(v["bytes"] for v in totals.values()))
    # while trip counts found (for sanity display)
    trips = []
    for name, lines in comps.items():
        for line in lines:
            wp = _while_parts(line)
            if wp:
                trips.append(_trip_count(comps, wp[1]))
    out["while_trip_counts"] = sorted(trips, reverse=True)[:8]
    return out


def top_collectives(hlo: str, n: int = 12):
    """Largest trip-weighted collectives with their op_name metadata."""
    comps = split_computations(hlo)
    entry = comps.pop("__entry__")[0]
    found = []

    def walk(name: str, mult: float, depth: int):
        if depth > 12:
            return
        for line in comps.get(name, []):
            wp = _while_parts(line)
            if wp:
                walk(wp[0], mult * _trip_count(comps, wp[1]), depth + 1)
                continue
            col = _line_collective(line)
            if col:
                kind, b = col
                m = re.search(r'op_name="([^"]*)"', line)
                shape = line.split(" = ", 1)[1][:60] if " = " in line else ""
                found.append((mult * b, kind, mult, shape,
                              m.group(1)[-110:] if m else ""))
            for callee in _callees(line):
                if callee in comps and "while" not in line:
                    walk(callee, mult, depth + 1)

    if entry:
        walk(entry, 1.0, 0)
    found.sort(reverse=True)
    return found[:n]
