"""Flat-npz pytree checkpointing (no orbax offline).

Pytree structure is encoded in the key paths; restores exactly for
dict/list/tuple/NamedTuple nests of arrays.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}.{k}"))
    elif tree is None:
        pass
    else:
        out[prefix] = np.asarray(tree)
    return out


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (treedef donor)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}" if prefix else str(k))
                    for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(*(rebuild(getattr(tree, k), f"{prefix}.{k}")
                                for k in tree._fields))
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, f"{prefix}[{i}]")
                              for i, v in enumerate(tree))
        if tree is None:
            return None
        return jax.numpy.asarray(data[prefix])

    return rebuild(like)
