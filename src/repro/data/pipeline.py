"""Synthetic long-range corpus + input pipeline.

No datasets ship offline, so paper-table benchmarks train small models on a
synthetic corpus engineered to contain the statistical structure the paper's
evaluations probe:

* local structure — a sparse random bigram process (gives PPL headroom),
* mid-range structure — a Zipf-reused bank of multi-token motifs ("phrases"),
* long-range structure — copy events: a span seen earlier recurs verbatim
  after a long delay (what recency-window eviction forgets and ladder
  retention can keep), and
* needles — key->value fact pairs injected early and queried much later
  (the Needle-In-A-Haystack readout).

The stream is deterministic per seed. ``lm_batches`` yields next-token
training batches; ``needle_episode`` builds retrieval episodes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

# reserved control tokens
BOS, KEY_TOK, VAL_TOK, QUERY_TOK = 0, 1, 2, 3
N_RESERVED = 8


@dataclasses.dataclass
class CorpusConfig:
    vocab_size: int = 512
    n_motifs: int = 256
    motif_len: Tuple[int, int] = (6, 24)
    p_motif: float = 0.25
    p_copy: float = 0.03
    copy_len: Tuple[int, int] = (16, 64)
    copy_back: Tuple[int, int] = (128, 2048)
    bigram_fanout: int = 24
    seed: int = 0


class SyntheticCorpus:
    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        lo = N_RESERVED
        # sparse bigram transitions over the non-reserved vocab
        self.next_tokens = rng.integers(lo, v, size=(v, cfg.bigram_fanout))
        # Zipf-weighted motif bank
        self.motifs = [
            rng.integers(lo, v, size=rng.integers(*cfg.motif_len))
            for _ in range(cfg.n_motifs)]
        w = 1.0 / np.arange(1, cfg.n_motifs + 1)
        self.motif_p = w / w.sum()

    def stream(self, n_tokens: int, seed: int = 0) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, seed))
        out = np.empty(n_tokens + 64, dtype=np.int32)
        out[0] = BOS
        i = 1
        cur = int(rng.integers(N_RESERVED, cfg.vocab_size))
        while i < n_tokens:
            u = rng.random()
            if u < cfg.p_copy and i > cfg.copy_back[0] + cfg.copy_len[1]:
                ln = int(rng.integers(*cfg.copy_len))
                back = int(rng.integers(cfg.copy_back[0],
                                        min(cfg.copy_back[1], i - ln)))
                start = i - back
                seg = out[start:start + ln]
                n = min(ln, n_tokens + 64 - i)
                out[i:i + n] = seg[:n]
                i += n
            elif u < cfg.p_copy + cfg.p_motif:
                m = self.motifs[int(rng.choice(len(self.motifs), p=self.motif_p))]
                n = min(len(m), n_tokens + 64 - i)
                out[i:i + n] = m[:n]
                i += n
            else:
                cur = int(self.next_tokens[cur, int(rng.integers(cfg.bigram_fanout))])
                out[i] = cur
                i += 1
        return out[:n_tokens]


def lm_batches(corpus: SyntheticCorpus, batch: int, seq_len: int,
               n_steps: int, seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {"tokens": [b, seq_len+1]} next-token batches."""
    need = batch * (seq_len + 1)
    for step in range(n_steps):
        rows = [corpus.stream(seq_len + 1, seed=seed * 100003 + step * batch + r)
                for r in range(batch)]
        yield {"tokens": np.stack(rows).astype(np.int32)}


def needle_episode(corpus: SyntheticCorpus, context_len: int, depth: float,
                   seed: int = 0, needle_len: int = 8
                   ) -> Dict[str, np.ndarray]:
    """A haystack with one needle (KEY k -> VAL payload) inserted at
    fractional ``depth``; the query asks for the payload at the end.

    Returns {"tokens": [context_len], "answer": [needle_len],
             "needle_span": (start, end)} — answer tokens follow the final
    QUERY_TOK + key marker.
    """
    rng = np.random.default_rng((corpus.cfg.seed, seed, 7))
    hay = corpus.stream(context_len, seed=seed + 99991)
    key = rng.integers(N_RESERVED, corpus.cfg.vocab_size, size=2)
    payload = rng.integers(N_RESERVED, corpus.cfg.vocab_size, size=needle_len)
    needle = np.concatenate([[KEY_TOK], key, [VAL_TOK], payload]).astype(np.int32)
    pos = int(depth * (context_len - len(needle) - needle_len - 8))
    pos = max(1, pos)
    tokens = hay.copy()
    tokens[pos:pos + len(needle)] = needle
    query = np.concatenate([[QUERY_TOK], key, [VAL_TOK]]).astype(np.int32)
    qpos = context_len - len(query)
    tokens[qpos:] = query
    return {"tokens": tokens, "answer": payload.astype(np.int32),
            "needle_span": (pos, pos + len(needle))}
