"""AdamW with decoupled weight decay, global-norm clipping and a
linear-warmup cosine schedule. Pure-pytree (no optax offline)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3.0e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1.0e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, s / jnp.maximum(1, cfg.warmup_steps))
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, mm, vv):
        u = (mm / c1) / (jnp.sqrt(vv / c2) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (u + wd)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v), {
        "grad_norm": gnorm, "lr": lr}
