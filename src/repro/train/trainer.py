"""Training loop: jitted AdamW step with MoE aux loss, metrics, checkpoints."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import adamw


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        logits, aux, _ = M.forward_train(
            params, cfg, tokens[:, :-1],
            patches=batch.get("patches"), frames=batch.get("frames"),
            remat=True)
        targets = tokens[:, 1:]
        off = logits.shape[1] - targets.shape[1]   # VLM patch prefix length
        if off > 0:
            # logits at position (off-1+j) predict text token j+1
            logits = jax.lax.dynamic_slice_in_dim(
                logits, off - 1, targets.shape[1], axis=1)
        loss = M.lm_loss(logits, targets)
        total = loss + cfg.router_aux_weight * aux
        return total, {"loss": loss, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, ocfg: adamw.AdamWConfig,
                    microbatches: int = 1):
    """One optimizer step. ``microbatches`` > 1 scans gradient accumulation
    over batch slices (activation memory / m — §Perf iter 2c: what makes
    train_4k for the >=100B configs fit a 16 GB v5e chip)."""
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (total, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mb = {k: v.reshape((microbatches, v.shape[0] // microbatches)
                               + v.shape[1:]) for k, v in batch.items()}

            def acc(carry, sl):
                g_sum, t_sum = carry
                (t, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, sl)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (g_sum, t_sum + t), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, t_sum), ms = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            total = t_sum / microbatches
            metrics = jax.tree.map(lambda x: x[-1], ms)
        params, opt_state, om = adamw.update(ocfg, grads, opt_state, params)
        metrics = dict(metrics, total=total, **om)
        return params, opt_state, metrics

    return train_step


def train(cfg: ModelConfig, params, batches: Iterator[Dict[str, np.ndarray]],
          ocfg: Optional[adamw.AdamWConfig] = None, log_every: int = 20,
          log_fn: Callable[[str], None] = print
          ) -> Tuple[Any, Dict[str, list]]:
    ocfg = ocfg or adamw.AdamWConfig()
    step_fn = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    opt_state = adamw.init(params)
    hist: Dict[str, list] = {"loss": [], "step_time": []}
    t0 = time.perf_counter()
    for i, batch in enumerate(batches):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            loss = float(metrics["loss"])
            dt = (time.perf_counter() - t0)
            hist["loss"].append(loss)
            hist["step_time"].append(dt / (i + 1))
            log_fn(f"step {i+1:5d} loss {loss:.4f} "
                   f"aux {float(metrics['aux']):.4f} "
                   f"gnorm {float(metrics['grad_norm']):.3f} "
                   f"lr {float(metrics['lr']):.2e}")
    return params, hist
