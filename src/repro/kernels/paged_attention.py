"""Paged flash-decode attention: one query token vs a block-table cache.

The dense decode kernel (:mod:`repro.kernels.decode_attention`) assumes each
sequence owns a contiguous slot buffer. Under the paged KV subsystem
(:mod:`repro.core.paged`) a sequence's KV lives in non-contiguous physical
blocks of a global pool, addressed through a per-sequence block table — so
the kernel must translate logical slot blocks to physical pool blocks while
it streams.

This is the classic scalar-prefetch pattern: the block tables and lengths
ride in SMEM via ``PrefetchScalarGridSpec`` so the *index maps* can read
them — each (batch, kv_head, logical-block) grid step DMAs exactly the
physical K/V block the table names, straight from the pool, with no
gather-to-dense materialization. GQA groups fold into query rows as in the
dense kernel; online softmax accumulates across the logical-block grid
dimension; masking is per-request ``lengths[b]`` plus the table's unmapped
(-1) sentinel, so ragged batches share one launch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, sm_scale: float, block_size: int,
                  max_blocks: int, n_slots: int):
    """Grid: (batch, kv_heads, max_blocks).

    tables_ref: [b, max_blocks] SMEM; lengths_ref: [b] SMEM;
    q_ref/o_ref: [group, d]; k_ref/v_ref: [block_size, d] — the physical
    block the index map selected via the table. ``n_slots`` (static) crops
    the last logical block's padding rows (max_blocks * block_size rounds
    the slot buffer up), matching the in-model dense-path semantics.
    """
    bi = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32) * sm_scale           # [g, d]
    k = k_ref[...].astype(jnp.float32)                      # [bs, d]
    s = q @ k.T                                             # [g, bs]
    slot = si * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (slot < lengths_ref[bi]) & (slot < n_slots) \
        & (tables_ref[bi, si] >= 0)
    s = jnp.where(valid, s, NEG_INF)
    s = jnp.where(jnp.isnan(s), NEG_INF, s)  # OOB grid padding (NaN fill)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    col_valid = ((si * block_size +
                  jax.lax.broadcasted_iota(jnp.int32, (k.shape[0], 1), 0)
                  ) < lengths_ref[bi]) & (tables_ref[bi, si] >= 0)
    vv = jnp.where(col_valid, v_ref[...].astype(jnp.float32), 0.0)
    acc_scr[...] = acc_scr[...] * alpha + p @ vv
    m_scr[...] = m_new

    @pl.when(si == max_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                           lengths: jnp.ndarray, *,
                           sm_scale: Optional[float] = None,
                           n_slots: Optional[int] = None,
                           interpret: bool = True) -> jnp.ndarray:
    """q: [b, h, d]; k_pool/v_pool: [n_blocks, block_size, kv, d];
    block_tables: [b, max_blocks] int32 (-1 = unmapped);
    lengths: [b] int32 valid-prefix lengths  ->  [b, h, d].

    ``n_slots`` (static) masks the padding rows of the last logical block
    when the layer's slot buffer is not a block-size multiple.
    """
    b, h, d = q.shape
    n_blocks, block_size, kvh = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    g = h // kvh
    mb = block_tables.shape[1]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    tables = jnp.asarray(block_tables, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(b)
    qr = q.reshape(b, kvh, g, d)

    def q_map(bi, hi, si, tables_ref, lengths_ref):
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, si, tables_ref, lengths_ref):
        # translate logical block -> physical pool block through the table;
        # unmapped (-1) clamps to 0 and is masked out inside the kernel
        return (jnp.maximum(tables_ref[bi, si], 0), 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, mb),
        in_specs=[
            pl.BlockSpec((None, None, g, d), q_map),
            pl.BlockSpec((None, block_size, None, d), kv_map),
            pl.BlockSpec((None, block_size, None, d), kv_map),
        ],
        out_specs=pl.BlockSpec((None, None, g, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, sm_scale=sm_scale,
                          block_size=block_size, max_blocks=mb,
                          n_slots=n_slots if n_slots is not None
                          else mb * block_size),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(tables, lengths, qr, k_pool, v_pool)
    return out.reshape(b, h, d)
