"""Blockwise causal flash attention (prefill path) as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §4): HBM->VMEM tiles of (block_q x head_dim) /
(block_k x head_dim) feed the MXU; the online-softmax running max/sum live in
VMEM scratch across the kv-block grid dimension (innermost, sequential on TPU).
Supports GQA (q heads grouped per kv head), causal masking, sliding windows,
chunked-prefill q offsets and slot-validity masking (budgeted caches).

Validated on CPU via interpret=True against ``ref.mha_reference``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(length_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, window: int,
                  q_offset: int, block_q: int, block_k: int,
                  n_kv_blocks: int, group: int):
    """Grid: (batch * kv_heads, n_q_blocks, n_kv_blocks); kv innermost.

    Block shapes (leading grid-mapped dims squeezed by BlockSpec):
      q_ref:   [block_q * group, head_dim]   (GQA group folded into rows)
      k_ref:   [block_k, head_dim]
      v_ref:   [block_k, head_dim]
      o_ref:   [block_q * group, head_dim]
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale
    k = k_ref[...].astype(jnp.float32)
    s = q @ k.T                                            # [bq*g, bk]

    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
    q_pos = qi * block_q + rows + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < length_ref[0]
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    s = jnp.where(jnp.isnan(s), NEG_INF, s)  # OOB grid padding (NaN fill)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    l_new = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    # zero padded value rows: 0 * NaN would poison the accumulator
    col_valid = (ki * block_k +
                 jax.lax.broadcasted_iota(jnp.int32, (k.shape[0], 1), 0)
                 ) < length_ref[0]
    vv = jnp.where(col_valid, v_ref[...].astype(jnp.float32), 0.0)
    acc_scr[...] = acc_scr[...] * alpha + p @ vv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    sm_scale: Optional[float] = None,
                    kv_length: Optional[jnp.ndarray] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [b, tq, h, d]; k/v: [b, tk, kv, d] -> [b, tq, h, d].

    ``kv_length``: scalar int32, number of valid kv slots (default tk).
    """
    b, tq, h, d = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    n_qb = pl.cdiv(tq, block_q)
    n_kb = pl.cdiv(tk, block_k)
    if kv_length is None:
        kv_length = jnp.array(tk, jnp.int32)
    length = jnp.asarray(kv_length, jnp.int32).reshape(1)

    # layout: fold (kv_head, group) into rows: q -> [b*kvh, tq*g, d]
    qr = (q.transpose(0, 2, 1, 3)
           .reshape(b, kvh, g, tq, d)
           .transpose(0, 1, 3, 2, 4)
           .reshape(b * kvh, tq * g, d))
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, tk, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, tk, d)

    grid = (b * kvh, n_qb, n_kb)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
            q_offset=q_offset, block_q=block_q, block_k=block_k,
            n_kv_blocks=n_kb, group=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # kv length scalar
            pl.BlockSpec((None, block_q * g, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q * g, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, tq * g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q * g, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q * g, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(length, qr, kr, vr)

    out = (out.reshape(b, kvh, tq, g, d)
              .transpose(0, 2, 1, 3, 4)
              .reshape(b, tq, h, d))
    return out
