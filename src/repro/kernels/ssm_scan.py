"""Mamba-1 selective scan as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §4): the recurrence is sequential in time but fully
parallel over channels, so the grid tiles (batch x d_inner blocks) and each
kernel instance walks the time axis with the state ``h [d_block, n]`` resident
in VMEM scratch (never touching HBM between steps). Channel blocks are
lane-aligned (multiples of 128); the time loop is a ``fori_loop`` over rows of
the VMEM-resident x/dt/B/C tiles. For long sequences the wrapper chunks time
and threads the state between chunks (grid-major time, state carried in the
scratch across grid steps).

Oracle: ``ref.ssm_scan_reference``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, h0_ref,
                y_ref, hT_ref, h_scr, *, n_t_chunks: int):
    """Grid: (batch, n_d_blocks, n_t_chunks); time chunks innermost.

    x_ref/dt_ref: [t_chunk, d_block]; A_ref: [d_block, n];
    B_ref/C_ref: [t_chunk, n]; h0_ref/hT_ref: [d_block, n];
    y_ref: [t_chunk, d_block]; h_scr: VMEM [d_block, n].
    """
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    t_chunk = x_ref.shape[0]
    A = A_ref[...].astype(jnp.float32)                     # [d, n]

    def _clean(a):
        # OOB grid padding is NaN-filled; treat padded steps as no-ops
        return jnp.where(jnp.isnan(a), 0.0, a)

    def step(t, _):
        xt = _clean(x_ref[t, :].astype(jnp.float32))       # [d]
        dtt = _clean(dt_ref[t, :].astype(jnp.float32))     # [d]
        Bt = _clean(B_ref[t, :].astype(jnp.float32))       # [n]
        Ct = _clean(C_ref[t, :].astype(jnp.float32))       # [n]
        h = h_scr[...]
        dA = jnp.exp(dtt[:, None] * A)                     # [d, n]
        h = h * dA + (dtt * xt)[:, None] * Bt[None, :]
        h_scr[...] = h
        y_ref[t, :] = (h @ Ct).astype(y_ref.dtype)         # [d]
        return 0

    jax.lax.fori_loop(0, t_chunk, step, 0)

    @pl.when(ti == n_t_chunks - 1)
    def _emit_state():
        hT_ref[...] = h_scr[...].astype(hT_ref.dtype)


def ssm_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
             h0: Optional[jnp.ndarray] = None, *,
             block_d: int = 256, t_chunk: int = 256,
             interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x, dt: [b, t, d]; A: [d, n]; B, C: [b, t, n]; D: [d].

    Returns (y [b, t, d], h_T [b, d, n] float32).
    """
    b, t, d = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)
    block_d = min(block_d, d)
    t_chunk = min(t_chunk, t)
    n_db = pl.cdiv(d, block_d)
    n_tc = pl.cdiv(t, t_chunk)

    y, hT = pl.pallas_call(
        functools.partial(_ssm_kernel, n_t_chunks=n_tc),
        grid=(b, n_db, n_tc),
        in_specs=[
            pl.BlockSpec((None, t_chunk, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((None, t_chunk, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((block_d, n), lambda bi, di, ti: (di, 0)),
            pl.BlockSpec((None, t_chunk, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((None, t_chunk, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((None, block_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, t_chunk, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((None, block_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, d), x.dtype),
            jax.ShapeDtypeStruct((b, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, h0)
    y = y + (x.astype(jnp.float32) * D.astype(jnp.float32)[None, None]).astype(y.dtype)
    return y, hT
