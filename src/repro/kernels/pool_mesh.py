"""Active pool-mesh registry for sharded paged-decode kernel dispatch.

The in-model paged hot loop calls :func:`repro.kernels.ops.paged_decode_attention`
from deep inside a jitted ``decode_step`` — there is no argument slot to
thread a :class:`jax.sharding.Mesh` through without touching every layer
signature. Instead the engine installs a :class:`PoolMeshSpec` here (a
thread-local, active only around its own jit dispatches so concurrently
constructed single-device engines never see it), and the kernel dispatcher
reads it **at trace time**: the traced program bakes in the ``shard_map``
routing exactly like the ``REPRO_KERNEL_IMPL`` choice bakes in the backend.

The spec records the axis decisions made once at engine construction by
:func:`repro.launch.sharding.paged_pool_mesh_spec`:

* ``kv_axis``   — pool planes sharded on the kv-head axis (the clean case:
  every shard computes its own query-head group end-to-end, no collective),
* ``slot_axis`` — MQA/GQA-small fallback: planes sharded on the in-block
  slot axis; per-shard partial softmax merged with an all-reduce,
* ``lane_axis`` — batch lanes sharded over the ``data`` axis when the
  engine's ``max_batch`` divides it.

This module is deliberately import-light (no repro imports) so both
``repro.kernels.ops`` and ``repro.launch.sharding`` can depend on it
without a cycle.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional


@dataclasses.dataclass(frozen=True)
class PoolMeshSpec:
    """One engine's sharded-pool routing decision (see module docstring).

    ``mesh`` is the :class:`jax.sharding.Mesh`; exactly one of
    ``kv_axis`` / ``slot_axis`` is set when the model axis is wider than 1
    (both ``None`` means every axis extent is 1 — a degenerate mesh the
    dispatcher treats as single-device).
    """

    mesh: object
    kv_axis: Optional[str] = None     # planes sharded on kv-heads
    slot_axis: Optional[str] = None   # planes sharded on in-block slots
    lane_axis: Optional[str] = None   # lanes sharded over "data"

    @property
    def sharded(self) -> bool:
        return self.kv_axis is not None or self.slot_axis is not None


_tls = threading.local()


def current_pool_mesh() -> Optional[PoolMeshSpec]:
    """The PoolMeshSpec installed by the innermost :func:`use_pool_mesh`,
    or ``None`` (single-device dispatch)."""
    return getattr(_tls, "spec", None)


@contextlib.contextmanager
def use_pool_mesh(spec: Optional[PoolMeshSpec]):
    """Install ``spec`` for the duration of a jit dispatch (trace time is
    what matters — cached executions re-enter for free).

    Publication happens *inside* the ``try`` so the registry can never be
    left armed: whatever raises after entry — including mid-dispatch
    trace errors in the ``with`` body — unwinds through the ``finally``
    and restores the previous value, so the next (possibly unsharded)
    engine on this thread never inherits a stale mesh.
    """
    prev = getattr(_tls, "spec", None)
    try:
        _tls.spec = spec
        yield
    finally:
        _tls.spec = prev
