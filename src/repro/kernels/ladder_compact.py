"""Fused KV-slot gather-compaction (Pallas TPU kernel) — LaCache's Sec. 3.3
iterative compaction realized as a stable-partition gather.

The survivor permutation (an argsort of the ladder keep mask, computed outside
the kernel — O(B) and tiny) drives a slot-axis gather of the K/V buffers.
On TPU the feature dim (kv_heads*head_dim, flattened) is tiled into
lane-aligned VMEM blocks; each grid step loads the full slot extent of one
feature tile plus the SMEM permutation, emits rows in permuted order, and
zeroes slots past ``new_length``. This keeps the gather entirely HBM->VMEM->HBM
with unit-stride lanes (vs. the HF python-list surgery the paper's artifact
uses — DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compact_kernel(perm_ref, newlen_ref, x_ref, o_ref):
    """Grid: (batch, n_feature_blocks).

    perm_ref: SMEM [s]; newlen_ref: SMEM [1];
    x_ref/o_ref: VMEM [s, f_block] (full slot extent of one feature tile).
    """
    s = x_ref.shape[0]
    perm = perm_ref[...]                                   # [s] int32
    x = x_ref[...]
    g = jnp.take(x, perm, axis=0)
    live = jax.lax.broadcasted_iota(jnp.int32, g.shape, 0) < newlen_ref[0]
    o_ref[...] = jnp.where(live, g, jnp.zeros((), x.dtype))


def gather_compact(x: jnp.ndarray, perm: jnp.ndarray, new_length: jnp.ndarray,
                   *, block_f: int = 512, interpret: bool = True) -> jnp.ndarray:
    """x: [b, s, ...feature...]; perm: [s]; new_length: scalar -> like x."""
    b, s = x.shape[:2]
    feat_shape = x.shape[2:]
    f = 1
    for d in feat_shape:
        f *= d
    xr = x.reshape(b, s, f)
    block_f = min(block_f, f)
    n_fb = pl.cdiv(f, block_f)
    perm = jnp.asarray(perm, jnp.int32)
    newlen = jnp.asarray(new_length, jnp.int32).reshape(1)

    out = pl.pallas_call(
        _compact_kernel,
        grid=(b, n_fb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, s, block_f), lambda bi, fi: (bi, 0, fi)),
        ],
        out_specs=pl.BlockSpec((None, s, block_f), lambda bi, fi: (bi, 0, fi)),
        out_shape=jax.ShapeDtypeStruct((b, s, f), x.dtype),
        interpret=interpret,
    )(perm, newlen, xr)
    return out.reshape(x.shape)
