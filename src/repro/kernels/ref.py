"""Pure-jnp oracles for every Pallas kernel (small-scale exact references).

These are the semantics contract: each kernel in this package must match its
oracle to float tolerance across shape/dtype sweeps (tests/test_kernels_*).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def mha_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  q_offset: int = 0, sm_scale: Optional[float] = None,
                  kv_valid: Optional[jnp.ndarray] = None,
                  return_probs: bool = False):
    """Plain softmax attention with GQA broadcast.

    q: [b, tq, h, d]; k/v: [b, tk, kv, d]. ``q_offset``: absolute position of
    q[0] relative to k[0] (for chunked prefill). ``window`` > 0 restricts each
    query to keys within the last ``window`` positions (sliding window).
    ``kv_valid``: bool[b, tk] or [tk] slot-validity mask.
    """
    b, tq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * sm_scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # broadcast kv heads to q heads
    kf = jnp.repeat(kf, g, axis=2)
    vf = jnp.repeat(vf, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    qpos = jnp.arange(tq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((tq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    if kv_valid is not None:
        kvm = kv_valid if kv_valid.ndim == 2 else kv_valid[None, :]
        mask = mask[None, None] & kvm[:, None, None, :]
    else:
        mask = mask[None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (shouldn't happen with causal) -> zeros
    p = jnp.where(jnp.any(mask, axis=-1, keepdims=True), p, 0.0)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    if return_probs:
        return o.astype(q.dtype), p
    return o.astype(q.dtype)


def decode_attention_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                               length: jnp.ndarray, *,
                               sm_scale: Optional[float] = None,
                               return_probs: bool = False):
    """Single-token decode attention over a budgeted slot cache.

    q: [b, h, d]; k/v: [b, s, kv, d]; length: scalar int32 (valid prefix).
    """
    valid = jnp.arange(k.shape[1]) < length
    out = mha_reference(q[:, None], k, v, causal=False, kv_valid=valid,
                        sm_scale=sm_scale, return_probs=return_probs)
    if return_probs:
        o, p = out
        return o[:, 0], p
    return out[:, 0]


def paged_logical_view(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                       block_tables: jnp.ndarray, lengths: jnp.ndarray,
                       n_slots: Optional[int] = None):
    """Gather the logical (k, v, valid) view through a block table.

    k_pool/v_pool: [n_blocks, block_size, kv, d]; block_tables:
    [b, max_blocks] int32 (-1 = unmapped); lengths: [b] int32. Returns
    k/v [b, S, kv, d] and a bool validity mask [b, S] (occupied AND
    mapped), with ``S = n_slots`` when given (cropping the padding rows of
    the last logical block) else ``max_blocks * block_size``. The single
    source of truth for paged-view semantics — both the XLA decode path
    (:func:`repro.kernels.ops.paged_decode_attention`) and the oracle
    below consume it, so they can never drift apart.
    """
    b = block_tables.shape[0]
    block_size = k_pool.shape[1]
    mb = block_tables.shape[1]
    ids = jnp.clip(block_tables, 0)                       # [b, mb]
    k = k_pool[ids].reshape(b, mb * block_size, *k_pool.shape[2:])
    v = v_pool[ids].reshape(b, mb * block_size, *v_pool.shape[2:])
    slot = jnp.arange(mb * block_size)
    mapped = jnp.repeat(block_tables >= 0, block_size, axis=1)
    valid = (slot[None, :] < lengths[:, None]) & mapped    # [b, mb*bs]
    if n_slots is not None:
        k, v, valid = k[:, :n_slots], v[:, :n_slots], valid[:, :n_slots]
    return k, v, valid


def paged_decode_attention_reference(q: jnp.ndarray, k_pool: jnp.ndarray,
                                     v_pool: jnp.ndarray,
                                     block_tables: jnp.ndarray,
                                     lengths: jnp.ndarray, *,
                                     sm_scale: Optional[float] = None,
                                     n_slots: Optional[int] = None,
                                     return_probs: bool = False):
    """Single-token decode attention through a paged block table.

    q: [b, h, d]; k_pool/v_pool: [n_blocks, block_size, kv, d];
    block_tables: [b, max_blocks] int32 (-1 = unmapped); lengths: [b] int32.
    Gathers the logical view per sequence, then runs the dense reference with
    a per-batch validity mask — the semantics contract for the Pallas paged
    kernel (which never materializes the gather).

    ``n_slots`` crops the padded view to the layer's slot-buffer size;
    ``return_probs`` additionally returns [b, h, 1, n_slots] attention
    probabilities (H2O/TOVA score accumulation — identical math to
    :func:`decode_attention_reference` with ``return_probs=True``).
    """
    k, v, valid = paged_logical_view(k_pool, v_pool, block_tables, lengths,
                                     n_slots)
    out = mha_reference(q[:, None], k, v, causal=False, kv_valid=valid,
                        sm_scale=sm_scale, return_probs=return_probs)
    if return_probs:
        o, p = out
        return o[:, 0], p
    return out[:, 0]


def paged_verify_attention_reference(q: jnp.ndarray, k_pool: jnp.ndarray,
                                     v_pool: jnp.ndarray,
                                     block_tables: jnp.ndarray,
                                     lengths: jnp.ndarray,
                                     q_offsets: jnp.ndarray, *,
                                     sm_scale: Optional[float] = None,
                                     n_slots: Optional[int] = None,
                                     return_probs: bool = False):
    """Multi-token causal decode attention through a paged block table.

    The verify step of draft/verify speculative decoding (and the chunked
    streaming-prefill step): ``T`` query tokens per lane attend causally over
    the lane's slot buffer, whose tail holds those same ``T`` freshly
    appended tokens.

    q: [b, T, h, d]; k_pool/v_pool: [n_blocks, block_size, kv, d];
    block_tables: [b, max_blocks] int32 (-1 = unmapped); lengths: [b] int32
    (occupied prefix *including* the appended chunk); q_offsets: [b] int32
    (slot of each lane's first query token — ``lengths - T`` when nothing
    clamped). Each lane runs :func:`mha_reference` causally at its own
    offset, so query ``i`` sees ``[whole compacted past || chunk[:i+1]]`` —
    bit-for-bit the dense chunk computation, per lane.

    ``return_probs`` additionally returns [b, h, T, S] attention
    probabilities (the same contract single-token ``return_probs`` carries
    for score-accumulating policies). This is the semantics contract for
    :func:`repro.kernels.ops.paged_verify_attention`.
    """
    k, v, valid = paged_logical_view(k_pool, v_pool, block_tables, lengths,
                                     n_slots)

    def one(qi, ki, vi, offi, vldi):
        return mha_reference(qi[None], ki[None], vi[None], causal=True,
                             q_offset=offi, kv_valid=vldi[None],
                             sm_scale=sm_scale, return_probs=return_probs)

    if return_probs:
        o, p = jax.vmap(one)(q, k, v, q_offsets, valid)
        return o[:, 0], p[:, 0]
    return jax.vmap(one)(q, k, v, q_offsets, valid)[:, 0]


def ring_valid_mask(ring_pos: jnp.ndarray, next_pos: jnp.ndarray,
                    window: int) -> jnp.ndarray:
    """Slot-validity mask of a sliding-window ring cache: occupied, inside
    the window, not from the future. ``ring_pos``: [..., w] per-slot
    absolute positions (-1 empty); ``next_pos``: [...] the position of the
    next token. THE single definition of ring validity — the dense decode
    path (:func:`repro.models.layers.attention_decode_ring`) and the paged
    oracle below both consume it, so the two backends' masks can never
    drift apart."""
    return (ring_pos >= 0) \
        & (ring_pos > (next_pos - 1 - window)[..., None]) \
        & (ring_pos <= (next_pos - 1)[..., None])


def paged_ring_attention_reference(q: jnp.ndarray, k_pool: jnp.ndarray,
                                   v_pool: jnp.ndarray,
                                   block_tables: jnp.ndarray,
                                   ring_pos: jnp.ndarray,
                                   next_pos: jnp.ndarray, *, window: int,
                                   sm_scale: Optional[float] = None):
    """Single-token sliding-window decode through a residue-class block table.

    q: [b, h, d]; k_pool/v_pool: [n_blocks, block_size, kv, d];
    block_tables: [b, max_blocks] int32 (-1 unmapped); ring_pos: [b, window]
    per-slot absolute positions (-1 empty, ring invariant slot == pos % w);
    next_pos: [b] the position of the *next* token (one past the appended
    query). Slot validity comes from the positions — occupied, inside the
    window, not from the future — the identical mask the dense ring decode
    path (:func:`repro.models.layers.attention_decode_ring`) applies, and
    the computation bottoms out in the same :func:`mha_reference`, so the
    paged and dense ring backends agree bit-for-bit. This is the semantics
    contract for the windowed Pallas dispatch
    (:func:`repro.kernels.ops.paged_ring_decode_attention`).
    """
    w = ring_pos.shape[1]
    k, v, mapped = paged_logical_view(k_pool, v_pool, block_tables,
                                      jnp.minimum(next_pos, w), w)
    valid = mapped & ring_valid_mask(ring_pos, next_pos, window)
    return mha_reference(q[:, None], k, v, causal=False, kv_valid=valid,
                         sm_scale=sm_scale)[:, 0]


def gather_compact_reference(x: jnp.ndarray, perm: jnp.ndarray,
                             new_length: jnp.ndarray) -> jnp.ndarray:
    """Permute slots (axis 1) by ``perm`` and zero slots >= new_length.

    x: [b, s, ...]; perm: [s] int32; new_length: scalar.
    """
    g = jnp.take(x, perm, axis=1)
    live = jnp.arange(x.shape[1]) < new_length
    shape = (1, -1) + (1,) * (x.ndim - 2)
    return jnp.where(live.reshape(shape), g, jnp.zeros((), x.dtype))


def ssm_scan_reference(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                       B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
                       h0: Optional[jnp.ndarray] = None):
    """Mamba-1 selective scan oracle.

    x, dt: [b, t, d]; A: [d, n]; B, C: [b, t, n]; D: [d];
    h0: [b, d, n] initial state. Returns (y [b, t, d], h_T [b, d, n]).
    Recurrence: h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t; y = C_t.h + D*x.
    """
    b, t, d = x.shape
    n = A.shape[1]
    xf, dtf, Bf, Cf = (a.astype(jnp.float32) for a in (x, dt, B, C))
    Af = A.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp          # [b,d], [b,d], [b,n], [b,n]
        dA = jnp.exp(dtt[:, :, None] * Af[None])          # [b,d,n]
        dBx = dtt[:, :, None] * Bt[:, None, :] * xt[:, :, None]
        h = h * dA + dBx
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
         Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + xf * D.astype(jnp.float32)[None, None]
    return y.astype(x.dtype), hT
