"""Flash-decode attention over a budgeted slot cache (Pallas TPU kernel).

One new query token attends to a fixed-size KV slot buffer with a valid
prefix of ``length`` slots (LaCache's compacted cache). GQA groups are folded
into query rows so one (kv_head x slot_block) K/V tile in VMEM serves the
whole group on the MXU. Online softmax over the slot-block grid dimension.

This is the kernel that realizes the paper's "attention-score-free eviction
composes with FlashAttention" claim on TPU: the policy only needs the slot
validity prefix, never attention probabilities.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _decode_kernel(length_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   sm_scale: float, block_s: int, n_s_blocks: int):
    """Grid: (batch * kv_heads, n_slot_blocks).

    q_ref: [group, d]; k_ref/v_ref: [block_s, d]; o_ref: [group, d].
    """
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32) * sm_scale          # [g, d]
    k = k_ref[...].astype(jnp.float32)                     # [bs, d]
    s = q @ k.T                                            # [g, bs]
    slot = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = slot < length_ref[0]
    s = jnp.where(mask, s, NEG_INF)

    s = jnp.where(jnp.isnan(s), NEG_INF, s)  # OOB grid padding (NaN fill)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    col_valid = (si * block_s +
                 jax.lax.broadcasted_iota(jnp.int32, (k.shape[0], 1), 0)
                 ) < length_ref[0]
    vv = jnp.where(col_valid, v_ref[...].astype(jnp.float32), 0.0)
    acc_scr[...] = acc_scr[...] * alpha + p @ vv
    m_scr[...] = m_new

    @pl.when(si == n_s_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     length: jnp.ndarray, *,
                     sm_scale: Optional[float] = None,
                     block_s: int = 256, interpret: bool = True) -> jnp.ndarray:
    """q: [b, h, d]; k/v: [b, s, kv, d]; length: scalar -> [b, h, d]."""
    b, h, d = q.shape
    s_slots, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    block_s = min(block_s, s_slots)
    n_sb = pl.cdiv(s_slots, block_s)
    length = jnp.asarray(length, jnp.int32).reshape(1)

    qr = q.reshape(b, kvh, g, d).reshape(b * kvh, g, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, s_slots, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, s_slots, d)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, sm_scale=sm_scale,
                          block_s=block_s, n_s_blocks=n_sb),
        grid=(b * kvh, n_sb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, g, d), lambda bh, si: (bh, 0, 0)),
            pl.BlockSpec((None, block_s, d), lambda bh, si: (bh, si, 0)),
            pl.BlockSpec((None, block_s, d), lambda bh, si: (bh, si, 0)),
        ],
        out_specs=pl.BlockSpec((None, g, d), lambda bh, si: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(length, qr, kr, vr)
    return out.reshape(b, kvh, g, d).reshape(b, h, d)
