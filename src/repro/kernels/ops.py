"""Public kernel ops with implementation dispatch.

``impl="pallas"`` -> the Pallas TPU kernels (interpret=True on CPU);
``impl="xla"``    -> SPMD-partitionable pure-JAX implementations (memory-safe
                     for long sequences: kv-block-scanned online softmax).

The distributed jit paths (dry-run, train) use the XLA implementations so
GSPMD can partition them; on-device execution flips to Pallas inside
shard_map (DESIGN.md §7). Semantics of both paths are identical and
cross-checked in tests/test_kernels_*.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

NEG_INF = -1.0e30


def default_impl() -> str:
    # deliberate trace-time static choice, baked in per process
    return os.environ.get("REPRO_KERNEL_IMPL", "xla")  # analysis: allow(TRC002)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------- #
# Flash attention (prefill / train)
# --------------------------------------------------------------------------- #
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    sm_scale=None, kv_length=None, impl: Optional[str] = None,
                    block_q: int = 128, block_k: int = 128):
    impl = impl or default_impl()
    if impl == "pallas":
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            sm_scale=sm_scale, kv_length=kv_length,
            block_q=block_q, block_k=block_k, interpret=_interpret())
    return _xla_flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        sm_scale=sm_scale, kv_length=kv_length, block_k=max(block_k, 512))


def _xla_flash_attention(q, k, v, *, causal, window, q_offset, sm_scale,
                         kv_length, block_k: int):
    """kv-block-scanned online softmax; O(tq * block_k) live memory."""
    b, tq, h, d = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    if kv_length is None:
        kv_length = jnp.array(tk, jnp.int32)
    if tk <= block_k:
        valid = jnp.arange(tk) < kv_length
        return _ref.mha_reference(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, sm_scale=sm_scale,
                                  kv_valid=valid)
    n_blocks = tk // block_k
    rem = tk - n_blocks * block_k
    qf = q.astype(jnp.float32) * sm_scale
    qf = qf.reshape(b, tq, kvh, g, d)
    qpos = jnp.arange(tq) + q_offset

    def block(carry, inp):
        m, l, acc = carry
        kb, vb, k0 = inp                     # [b, bk, kvh, d], [b, bk, kvh, d], scalar
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb.astype(jnp.float32))
        kpos = k0 + jnp.arange(kb.shape[1])
        mask = kpos[None, :] < kv_length
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window and window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    kb = k[:, :n_blocks * block_k].reshape(b, n_blocks, block_k, kvh, d)
    vb = v[:, :n_blocks * block_k].reshape(b, n_blocks, block_k, kvh, d)
    offs = jnp.arange(n_blocks) * block_k
    init = (jnp.full((b, kvh, g, tq), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, tq), jnp.float32),
            jnp.zeros((b, kvh, g, tq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        block, init, (kb.swapaxes(0, 1), vb.swapaxes(0, 1), offs))
    if rem:
        (m, l, acc), _ = block((m, l, acc),
                               (k[:, -rem:], v[:, -rem:],
                                jnp.array(n_blocks * block_k)))
    l = jnp.where(l == 0.0, 1.0, l)
    o = acc / l[..., None]                   # [b, kvh, g, tq, d]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, tq, h, d).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Decode attention (single-token, budgeted cache)
# --------------------------------------------------------------------------- #
def decode_attention(q, k, v, length, *, sm_scale=None,
                     impl: Optional[str] = None, return_probs: bool = False,
                     block_s: int = 256):
    if return_probs:  # H2O path: needs probabilities -> XLA only (paper's point)
        return _ref.decode_attention_reference(
            q, k, v, length, sm_scale=sm_scale, return_probs=True)
    impl = impl or default_impl()
    if impl == "pallas":
        from repro.kernels import decode_attention as da
        return da.decode_attention(q, k, v, length, sm_scale=sm_scale,
                                   block_s=block_s, interpret=_interpret())
    return _xla_decode_attention(q, k, v, length, sm_scale=sm_scale)


def _xla_decode_attention(q, k, v, length, *, sm_scale=None):
    """Grouped-GQA decode attention without materializing repeated KV heads.

    Keeping the kv-head axis intact (no jnp.repeat) lets GSPMD partition the
    slot-sharded cache with partial-softmax all-reduces instead of
    all-gathering the cache (§Perf iter 1c)."""
    valid = (jnp.arange(k.shape[1]) < length)[None, :]
    return _masked_decode_attention(q, k, v, valid, sm_scale=sm_scale)


def _masked_decode_attention_partial(q, k, v, valid, *, sm_scale=None):
    """Unmerged partial-softmax pieces of :func:`_masked_decode_attention`.

    Returns ``(acc, m, l)`` with ``acc = sum_s exp(s - m) * v`` ``[b, kv,
    g, d]``, the row max ``m`` and mass ``l`` ``[b, kv, g]`` — what a mesh
    shard contributes when the slot axis is sharded: the caller merges
    shards with ``o = psum(acc * exp(m - pmax(m))) / psum(l * exp(m -
    pmax(m)))`` (the partial-softmax all-reduce)."""
    b, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    q4 = (q.reshape(b, kvh, g, d).astype(jnp.float32)) * sm_scale
    scores = jnp.einsum("bkgd,bskd->bkgs", q4, k.astype(jnp.float32))
    vmask = valid[:, None, None, :]
    scores = jnp.where(vmask, scores, NEG_INF)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1))
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(vmask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return acc, m, l


def _masked_decode_attention(q, k, v, valid, *, sm_scale=None):
    """The shared decode-attention core over an explicit slot-validity mask.

    q: [b, h, d]; k/v: [b, s, kv, d]; valid: bool broadcastable to [b, s].
    Both the dense (scalar/vector ``length``) and the paged (gathered block
    view) decode paths reduce to this exact computation, which is what keeps
    the two backends token-for-token equal."""
    b, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    sm_scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    q4 = (q.reshape(b, kvh, g, d).astype(jnp.float32)) * sm_scale
    scores = jnp.einsum("bkgd,bskd->bkgs", q4, k.astype(jnp.float32))
    vmask = valid[:, None, None, :]
    scores = jnp.where(vmask, scores, NEG_INF)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.exp(scores - m)
    p = jnp.where(vmask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Paged decode attention (block-table cache; repro.core.paged)
# --------------------------------------------------------------------------- #
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           sm_scale=None, impl: Optional[str] = None,
                           n_slots: Optional[int] = None,
                           return_probs: bool = False):
    """Decode attention through a per-sequence block table over a global
    physical block pool. q: [b, h, d]; k_pool/v_pool: [n_blocks, bs, kv, d];
    block_tables: [b, max_blocks] (-1 unmapped); lengths: [b].

    ``n_slots`` crops the logical view to the layer's slot-buffer size
    (max_blocks * block_size rounds up), so the in-model paged decode path
    computes over exactly the same shapes as the dense path — the bitwise
    contract behind the paged-vs-dense differential harness.
    ``return_probs`` (H2O/TOVA) forces the XLA reference path, mirroring the
    dense kernel's FlashAttention-incompatibility argument.
    """
    if return_probs:
        return _ref.paged_decode_attention_reference(
            q, k_pool, v_pool, block_tables, lengths, sm_scale=sm_scale,
            n_slots=n_slots, return_probs=True)
    impl = impl or default_impl()
    pm = _pool_mesh_for_dispatch(impl)
    if pm is not None:
        return _sharded_paged_decode_attention(
            pm, q, k_pool, v_pool, block_tables, lengths,
            sm_scale=sm_scale, n_slots=n_slots)
    if impl == "pallas":
        from repro.kernels import paged_attention as pa
        return pa.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                         lengths, sm_scale=sm_scale,
                                         n_slots=n_slots,
                                         interpret=_interpret())
    return _xla_paged_decode_attention(q, k_pool, v_pool, block_tables,
                                       lengths, sm_scale=sm_scale,
                                       n_slots=n_slots)


def _pool_mesh_for_dispatch(impl: str):
    """The engine-installed pool-mesh spec, when the Pallas backend should
    route per shard. The XLA implementations stay mesh-free on purpose:
    they are GSPMD-partitionable (the masked core keeps the kv-head axis
    intact), so sharded placement alone partitions them — ``shard_map``
    exists to carry the Pallas kernel, whose scalar-prefetch index maps
    GSPMD cannot see through."""
    if impl != "pallas":
        return None
    from repro.kernels import pool_mesh as _pm
    spec = _pm.current_pool_mesh()
    return spec if spec is not None and spec.sharded else None


def _sharded_paged_decode_attention(pm, q, k_pool, v_pool, block_tables,
                                    lengths, *, sm_scale=None, n_slots=None):
    """Per-shard paged decode over a mesh-sharded pool (DESIGN.md §7).

    kv-head-sharded planes (``pm.kv_axis``): each shard owns a kv-head
    slice of the pool and the matching query-head group, so the existing
    scalar-prefetch Pallas kernel runs unchanged per shard with no
    collective — bitwise equal to the single-device kernel.

    slot-sharded planes (``pm.slot_axis`` — the MQA/GQA-small case of
    ``launch/sharding``'s KV rule): Pallas inside ``shard_map`` has no
    global-slot offset plumbing, so each shard falls back to the XLA
    reference core over its in-block slot slice and the shards merge with
    a partial-softmax all-reduce (``psum`` over rescaled ``acc``/``l``).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    b, h, d = q.shape
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    lane = pm.lane_axis
    tables = jnp.asarray(block_tables, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(b)
    if pm.kv_axis is not None:
        def kv_body(qq, kp, vp, tb, ln):
            from repro.kernels import paged_attention as pa
            return pa.paged_decode_attention(
                qq, kp, vp, tb, ln, sm_scale=scale, n_slots=n_slots,
                interpret=_interpret())
        fn = shard_map(
            kv_body, mesh=pm.mesh,
            in_specs=(P(lane, pm.kv_axis, None),
                      P(None, None, pm.kv_axis, None),
                      P(None, None, pm.kv_axis, None),
                      P(lane, None), P(lane)),
            out_specs=P(lane, pm.kv_axis, None), check_rep=False)
        return fn(q, k_pool, v_pool, tables, lengths)

    axis = pm.slot_axis
    bs_global = k_pool.shape[1]
    ns = n_slots if n_slots is not None else tables.shape[1] * bs_global

    def slot_body(qq, kp, vp, tb, ln):
        # local gathered view: shard p holds in-block rows
        # [p*bs_loc, (p+1)*bs_loc) of every pool block, so local slot j
        # is GLOBAL slot (j // bs_loc) * bs_global + p*bs_loc + j % bs_loc
        bs_loc = kp.shape[1]
        p_idx = jax.lax.axis_index(axis)
        jloc = jnp.arange(tb.shape[1] * bs_loc)
        blk = jnp.take(tb, jloc // bs_loc, axis=-1)            # [b, S_loc]
        row = jnp.clip(blk, 0) * bs_loc + jloc % bs_loc
        k = kp.reshape((-1,) + kp.shape[2:])[row]
        v = vp.reshape((-1,) + vp.shape[2:])[row]
        gslot = ((jloc // bs_loc) * bs_global + p_idx * bs_loc
                 + jloc % bs_loc)
        valid = (blk >= 0) & (gslot[None, :]
                              < jnp.minimum(ln[:, None], ns))
        acc, m, l = _masked_decode_attention_partial(qq, k, v, valid,
                                                     sm_scale=scale)
        m_all = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_all)
        l_all = jax.lax.psum(l * corr, axis)
        acc_all = jax.lax.psum(acc * corr[..., None], axis)
        o = acc_all / jnp.where(l_all == 0.0, 1.0, l_all)[..., None]
        return o.reshape(qq.shape).astype(qq.dtype)

    fn = shard_map(
        slot_body, mesh=pm.mesh,
        in_specs=(P(lane, None, None), P(None, axis, None, None),
                  P(None, axis, None, None), P(lane, None), P(lane)),
        out_specs=P(lane, None, None), check_rep=False)
    return fn(q, k_pool, v_pool, tables, lengths)


def _xla_paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                                sm_scale=None, n_slots=None):
    """XLA paged decode: gather the logical view through the table (fused by
    XLA — the Pallas kernel streams blocks instead), then run the *same*
    masked decode core as the dense path so logits agree bit-for-bit. The
    view semantics live in one place (:func:`ref.paged_logical_view`)."""
    k, v, valid = _ref.paged_logical_view(k_pool, v_pool, block_tables,
                                          lengths, n_slots)
    return _masked_decode_attention(q, k, v, valid, sm_scale=sm_scale)


def paged_verify_attention(q, k_pool, v_pool, block_tables, lengths,
                           q_offsets, *, sm_scale=None,
                           impl: Optional[str] = None,
                           n_slots: Optional[int] = None,
                           return_probs: bool = False):
    """Multi-token (verify / chunk) decode attention through a block table.

    The batched-verify twin of :func:`paged_decode_attention`: ``T`` query
    tokens per lane — a speculative draft window being verified in one
    dispatch, or a streaming-prefill chunk — attend causally over the lane's
    slot buffer whose tail holds those same freshly appended tokens.
    q: [b, T, h, d]; k_pool/v_pool: [n_blocks, bs, kv, d]; block_tables:
    [b, max_blocks] (-1 unmapped); lengths: [b] (occupied prefix including
    the chunk); q_offsets: [b] (per-lane slot of the first query token).

    ``return_probs`` forces the reference path (the same contract the
    single-token kernels carry for score-accumulating policies). The Pallas
    block-streaming kernel is single-query; multi-query dispatches run the
    gathered XLA path under every impl until a multi-query kernel lands —
    the verify step is compute-bound over ``T`` queries, so the gather it
    shares with :func:`_xla_paged_decode_attention` is not the bottleneck.
    The semantics contract is
    :func:`repro.kernels.ref.paged_verify_attention_reference`, and the
    dispatch *is* that computation, so kernel and oracle cannot drift.
    """
    if return_probs:
        return _ref.paged_verify_attention_reference(
            q, k_pool, v_pool, block_tables, lengths, q_offsets,
            sm_scale=sm_scale, n_slots=n_slots, return_probs=True)
    return _ref.paged_verify_attention_reference(
        q, k_pool, v_pool, block_tables, lengths, q_offsets,
        sm_scale=sm_scale, n_slots=n_slots)


def paged_ring_decode_attention(q, k_pool, v_pool, block_tables, ring_pos,
                                next_pos, *, window: int, sm_scale=None,
                                impl: Optional[str] = None):
    """Sliding-window (ring) decode through a residue-class block table.

    The windowed twin of :func:`paged_decode_attention` for in-model paged
    ring layers: logical ring slot j lives at pool row
    ``tables[j // bs] * bs + j % bs`` and validity comes from the per-slot
    positions (``ring_pos``/``next_pos``) instead of an occupied-prefix
    length. Contract: callers invoke this *after* the in-step ring append,
    at which point the ring invariant makes the valid slots exactly the
    occupied prefix ``[0, min(next_pos, window))``.

    The XLA path runs the pure reference — the dense ring decode path runs
    ``mha_reference`` directly, and bitwise parity between the two backends
    is the differential harness's contract. ``impl="pallas"`` exploits the
    prefix-occupancy fact to reuse the block-streaming Pallas paged-decode
    kernel unchanged with ``lengths = min(next_pos, window)``.
    """
    impl = impl or default_impl()
    if impl == "pallas":
        w = ring_pos.shape[-1]
        lengths = jnp.minimum(next_pos, w)
        pm = _pool_mesh_for_dispatch(impl)
        if pm is not None:
            # the prefix-occupancy fact holds per shard too, so the ring
            # reuses the sharded paged dispatch exactly as it reuses the
            # single-device kernel
            return _sharded_paged_decode_attention(
                pm, q, k_pool, v_pool, block_tables, lengths,
                sm_scale=sm_scale, n_slots=w)
        from repro.kernels import paged_attention as pa
        return pa.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                         lengths, sm_scale=sm_scale,
                                         n_slots=w, interpret=_interpret())
    return _ref.paged_ring_attention_reference(
        q, k_pool, v_pool, block_tables, ring_pos, next_pos,
        window=window, sm_scale=sm_scale)


# --------------------------------------------------------------------------- #
# Gather-compaction (LaCache iterative compaction)
# --------------------------------------------------------------------------- #
def gather_compact(x, perm, new_length, *, impl: Optional[str] = None):
    impl = impl or default_impl()
    if impl == "pallas":
        from repro.kernels import ladder_compact as lc
        return lc.gather_compact(x, perm, new_length, interpret=_interpret())
    return _ref.gather_compact_reference(x, perm, new_length)


# --------------------------------------------------------------------------- #
# Selective scan (Mamba)
# --------------------------------------------------------------------------- #
def ssm_scan(x, dt, A, B, C, D, h0=None, *, impl: Optional[str] = None):
    impl = impl or default_impl()
    if impl == "pallas":
        from repro.kernels import ssm_scan as ss
        return ss.ssm_scan(x, dt, A, B, C, D, h0, interpret=_interpret())
    return _ref.ssm_scan_reference(x, dt, A, B, C, D, h0)
