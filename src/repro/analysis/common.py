"""Shared plumbing for the lint passes: findings, suppression, discovery.

Pure stdlib (``ast`` + ``re``) — the lint CLI must stay import-light so CI
can run it before anything heavyweight (jax) is even importable.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

#: ``# analysis: allow(TRC002)`` / ``# analysis: allow(TRC001, DON001)``
_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\(\s*([A-Za-z0-9_*,\s]+?)\s*\)")

RULES = {
    "TRC001": "eager pool operation reachable from a traced region",
    "TRC002": "host-side compute (np.*) or environment read under trace",
    "TRC003": "mutation of host-side object state under trace",
    "DON001": "use of a donated argument after the donating dispatch",
    "DON002": "donation of a value held elsewhere by reference",
    "PYT001": "unregistered dataclass constructed under trace",
    "PYT002": "pytree aux/meta data contains array fields",
}


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One lint finding, ``path:line: RULE: message`` when rendered."""

    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


def parse_allows(source: str) -> Dict[int, Set[str]]:
    """Map line number -> rule IDs suppressed there.

    An ``# analysis: allow(RULE)`` comment suppresses matching findings on
    its own line (trailing style) and on the line below (comment-above
    style). ``allow(*)`` suppresses every rule on those lines.
    """
    allows: Dict[int, Set[str]] = {}
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        for target in (lineno, lineno + 1):
            allows.setdefault(target, set()).update(rules)
    return allows


def is_allowed(allows: Dict[int, Set[str]], rule: str, line: int) -> bool:
    granted = allows.get(line, ())
    return rule in granted or "*" in granted


#: directory names that terminate the package walk (import roots)
_STOP_DIRS = {"src", "tests", "test", "site-packages"}


def module_name_for(path: Path) -> str:
    """Dotted import name for ``path``, walking up to the import root
    (``src/repro/core/paged.py`` -> ``repro.core.paged``). The repo uses
    namespace packages (no ``__init__.py`` at the top level), so the walk
    stops at ``src``/``tests``, a repo root (``.git``/``pyproject.toml``),
    or a non-identifier directory — not at a missing ``__init__.py``."""
    parts = [path.stem]
    parent = path.parent
    while True:
        name = parent.name
        if (not name.isidentifier() or name in _STOP_DIRS
                or (parent / ".git").exists()
                or (parent / "pyproject.toml").exists()
                or parent == parent.parent):
            break
        parts.append(name)
        parent = parent.parent
    if parts[0] == "__init__":
        parts = parts[1:] or [path.parent.name]
    return ".".join(reversed(parts))


def discover_files(paths: Sequence[str]) -> List[Path]:
    """Expand files/directories into a sorted, de-duplicated .py file list."""
    seen: Set[Path] = set()
    out: List[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            candidates: Iterable[Path] = sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            candidates = [p]
        else:
            candidates = []
        for c in candidates:
            c = c.resolve()
            if c not in seen:
                seen.add(c)
                out.append(c)
    return out


def run_paths(paths: Sequence[str],
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every lint pass over ``paths`` (files or directories).

    Returns findings sorted by (path, line, rule), with ``# analysis:
    allow(...)`` suppressions already applied. ``rules`` optionally
    restricts to a subset of rule IDs (prefix match, so ``["TRC"]`` means
    all trace-purity rules).
    """
    from repro.analysis import donation, pytree, trace_purity
    from repro.analysis.callgraph import Index

    files = discover_files(paths)
    index = Index.build(files)
    findings: List[Finding] = []
    findings += trace_purity.run(index)
    findings += donation.run(index)
    findings += pytree.run(index)
    if rules is not None:
        keep = tuple(rules)
        findings = [f for f in findings if f.rule.startswith(keep)]
    out = []
    for f in findings:
        mi = index.by_path.get(f.path)
        if mi is not None and is_allowed(mi.allows, f.rule, f.line):
            continue
        out.append(f)
    return sorted(set(out))


def parse_file(path: Path) -> Optional[ast.Module]:
    try:
        return ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return None
