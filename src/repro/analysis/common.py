"""Shared plumbing for the lint passes: findings, suppression, discovery.

Pure stdlib (``ast`` + ``re``) — the lint CLI must stay import-light so CI
can run it before anything heavyweight (jax) is even importable.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

#: ``# analysis: allow(TRC002)`` / ``# analysis: allow(TRC001, DON001)``
_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\(\s*([A-Za-z0-9_*,\s]+?)\s*\)")

RULES = {
    "TRC001": "eager pool operation reachable from a traced region",
    "TRC002": "host-side compute (np.*) or environment read under trace",
    "TRC003": "mutation of host-side object state under trace",
    "DON001": "use of a donated argument after the donating dispatch",
    "DON002": "donation of a value held elsewhere by reference",
    "PYT001": "unregistered dataclass constructed under trace",
    "PYT002": "pytree aux/meta data contains array fields",
    "SHD001": "collective outside shard_map scope or on an undeclared "
              "mesh axis",
    "SHD002": "thread-local registry published without a guaranteed "
              "scoped reset",
    "SHD003": "NamedSharding/pool_plane_spec axis name absent from the "
              "mesh",
    "CMP001": "jit dispatch fed a per-call-varying Python scalar/shape "
              "without static_argnums",
    "CMP002": "unstable dict/kwarg expansion reaching a traced "
              "signature",
    "CMP003": "data-dependent shape construction / concretization under "
              "trace",
    "OBS001": "MetricsRegistry/Tracer call reachable from a traced "
              "region",
    "OBS002": "unbalanced keyed tracer begin/end span pair",
}


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One lint finding, ``path:line: RULE: message`` when rendered."""

    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


def parse_allows(source: str) -> Dict[int, Set[str]]:
    """Map line number -> rule IDs suppressed there.

    An ``# analysis: allow(RULE)`` comment suppresses matching findings on
    its own line (trailing style) and on the line below (comment-above
    style). ``allow(*)`` suppresses every rule on those lines.
    """
    allows: Dict[int, Set[str]] = {}
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        for target in (lineno, lineno + 1):
            allows.setdefault(target, set()).update(rules)
    return allows


def is_allowed(allows: Dict[int, Set[str]], rule: str, line: int) -> bool:
    granted = allows.get(line, ())
    return rule in granted or "*" in granted


#: directory names that terminate the package walk (import roots)
_STOP_DIRS = {"src", "tests", "test", "site-packages"}


def module_name_for(path: Path) -> str:
    """Dotted import name for ``path``, walking up to the import root
    (``src/repro/core/paged.py`` -> ``repro.core.paged``). The repo uses
    namespace packages (no ``__init__.py`` at the top level), so the walk
    stops at ``src``/``tests``, a repo root (``.git``/``pyproject.toml``),
    or a non-identifier directory — not at a missing ``__init__.py``."""
    parts = [path.stem]
    parent = path.parent
    while True:
        name = parent.name
        if (not name.isidentifier() or name in _STOP_DIRS
                or (parent / ".git").exists()
                or (parent / "pyproject.toml").exists()
                or parent == parent.parent):
            break
        parts.append(name)
        parent = parent.parent
    if parts[0] == "__init__":
        parts = parts[1:] or [path.parent.name]
    return ".".join(reversed(parts))


def discover_files(paths: Sequence[str]) -> List[Path]:
    """Expand files/directories into a sorted, de-duplicated .py file list."""
    seen: Set[Path] = set()
    out: List[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            candidates: Iterable[Path] = sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            candidates = [p]
        else:
            candidates = []
        for c in candidates:
            c = c.resolve()
            if c not in seen:
                seen.add(c)
                out.append(c)
    return out


def run_paths(paths: Sequence[str],
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every lint pass over ``paths`` (files or directories).

    Builds the shared analysis IR (:mod:`repro.analysis.ir`) once —
    parse, symbol tables, call graph, traced regions, dataflow facts —
    and runs each pass as a visitor over it. Returns findings sorted by
    (path, line, rule), with ``# analysis: allow(...)`` suppressions
    already applied. ``rules`` optionally restricts to a subset of rule
    IDs (prefix match, so ``["TRC"]`` means all trace-purity rules).
    """
    from repro.analysis import (donation, obs_purity, pytree, recompile,
                                sharding_discipline, trace_purity)
    from repro.analysis.ir import IR

    files = discover_files(paths)
    an_ir = IR.build(files)
    findings: List[Finding] = []
    findings += trace_purity.run(an_ir)
    findings += donation.run(an_ir)
    findings += pytree.run(an_ir)
    findings += sharding_discipline.run(an_ir)
    findings += recompile.run(an_ir)
    findings += obs_purity.run(an_ir)
    if rules is not None:
        keep = tuple(rules)
        findings = [f for f in findings if f.rule.startswith(keep)]
    out = []
    for f in findings:
        mi = an_ir.index.by_path.get(f.path)
        if mi is not None and is_allowed(mi.allows, f.rule, f.line):
            continue
        out.append(f)
    return sorted(set(out))


def family_counts(findings: Sequence[Finding]) -> Dict[str, int]:
    """Findings per rule family (``{"TRC": 3, "CMP": 1}``), sorted by
    family name — the summary-line / ``--list-rules`` breakdown."""
    out: Dict[str, int] = {}
    for f in findings:
        fam = f.rule[:3]
        out[fam] = out.get(fam, 0) + 1
    return dict(sorted(out.items()))


# --------------------------------------------------------------------------- #
# baseline file: reviewed pre-existing findings the gate tolerates
# --------------------------------------------------------------------------- #
def finding_fingerprint(f: Finding, root: Optional[Path] = None) -> str:
    """Stable fingerprint for baselining: rule + repo-relative path +
    hash of the *stripped source line text*, so reflowing unrelated code
    (line drift) does not invalidate the baseline while editing the
    flagged line itself does."""
    try:
        text = Path(f.path).read_text().splitlines()[f.line - 1].strip()
    except (OSError, IndexError):
        text = ""
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    return f"{f.rule}:{rel_path(f.path, root)}:{digest}"


def rel_path(path: str, root: Optional[Path] = None) -> str:
    """Path relative to ``root`` (default cwd) with ``/`` separators, or
    the absolute path when outside the root."""
    p = Path(path)
    base = root if root is not None else Path.cwd()
    try:
        return p.resolve().relative_to(base.resolve()).as_posix()
    except ValueError:
        return p.as_posix()


def load_baseline(path: Path) -> Set[str]:
    """Fingerprint set from a baseline file written by
    :func:`write_baseline`."""
    data = json.loads(path.read_text())
    return set(data.get("fingerprints", []))


def write_baseline(path: Path, findings: Sequence[Finding],
                   root: Optional[Path] = None) -> None:
    """Persist the current finding set as the reviewed baseline. Each
    entry keeps a human-readable ``note`` beside the fingerprint so the
    file reviews like a findings list, but only ``fingerprints`` is
    load-bearing."""
    root = root if root is not None else path.resolve().parent
    entries = sorted(
        {finding_fingerprint(f, root): f"{rel_path(f.path, root)}:"
                                       f"{f.line}: {f.rule}"
         for f in findings}.items())
    path.write_text(json.dumps({
        "schema_version": 1,
        "tool": "repro.analysis",
        "fingerprints": [fp for fp, _ in entries],
        "notes": {fp: note for fp, note in entries},
    }, indent=1) + "\n")


def apply_baseline(findings: Sequence[Finding], fingerprints: Set[str],
                   root: Optional[Path] = None) -> List[Finding]:
    """Drop findings whose fingerprint the reviewed baseline covers."""
    return [f for f in findings
            if finding_fingerprint(f, root) not in fingerprints]


def parse_file(path: Path) -> Optional[ast.Module]:
    try:
        return ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return None
