"""Donation-discipline pass: a donated pytree is dead after the dispatch.

Rules
-----
DON001
    Use-after-donate: an argument donated to a ``jax.jit(...,
    donate_argnums=/donate_argnames=)`` dispatch is read again in the
    enclosing function before being rebound. The donated buffers may have
    been aliased into the outputs — reading them is undefined (and
    silently wrong on TPU). A donating call inside a loop whose donated
    value is never rebound before the next iteration is the same bug one
    iteration later.
DON002
    Donating a must-not-donate value: anything handed out *by reference*
    from a shared store — ``prefix_cache.restore(...)`` results (dense
    entries alias the cache's own pytree; the engine.py dense-state
    caveat) and ``kv_store.get(...)`` gathers that a snapshot still
    references. Donating one corrupts every other holder.

The pass is intra-procedural and runs over the shared analysis IR:
dispatch handles come from :meth:`repro.analysis.ir.IR.handles` (which
collects every jit binding — ``self._name`` attrs, module globals,
``@partial(jax.jit, ...)`` defs — with their donate/static declarations),
ordered loads/stores from :meth:`repro.analysis.ir.IR.facts`. Local
aliases of handles resolve too, including conditional aliases — an alias
donates if *any* branch donates.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis import callgraph as cg
from repro.analysis import ir
from repro.analysis.common import Finding

#: attribute tails whose call results are held by reference elsewhere and
#: must never be donated
_NO_DONATE_SOURCES: Tuple[Tuple[str, str], ...] = (
    ("prefix_cache", "restore"),
    ("kv_store", "get"),
)

Path = Tuple[str, ...]


def _expr_path(node: ast.AST) -> Optional[Path]:
    chain = cg.attr_chain(node)
    return tuple(chain) if chain else None


def _extends(used: Path, donated: Path) -> bool:
    return used[:len(donated)] == donated


def run(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    for mi in an_ir.modules.values():
        table = an_ir.handles(mi)
        if not any(s.donates for s in [*table.attr.values(),
                                       *table.name.values(),
                                       *table.func.values()]):
            continue
        for fi in mi.functions.values():
            if isinstance(fi.node, cg.FunctionNode):
                findings += _check_function(an_ir, mi, fi, table)
    return findings


def _check_function(an_ir: "ir.IR", mi: cg.ModuleInfo, fi: cg.FuncInfo,
                    table: "ir.HandleTable") -> List[Finding]:
    findings: List[Finding] = []
    facts = an_ir.facts(fi)
    loads = facts.loads
    stores = facts.stores

    # local aliases of dispatch handles + tainted (no-donate) locals
    local_aliases: Dict[str, "ir.JitSpec"] = {}
    tainted: Dict[str, int] = {}        # name -> taint line
    for stmt in facts.assignments:
        if isinstance(stmt, ast.AugAssign):
            continue
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else ([stmt.target] if stmt.value is not None else [])
        value = stmt.value
        if value is None:
            continue
        names = []
        for t in targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                if isinstance(el, ast.Name):
                    names.append(el.id)
        if not names:
            continue
        spec = table.alias_spec(value, fi, local_aliases)
        for n in names:
            if spec is not None:
                local_aliases[n] = spec
            else:
                local_aliases.pop(n, None)
        taints = _is_no_donate_source(value)
        for n in names:
            if taints:
                tainted[n] = stmt.lineno
            elif not _value_reads(value, tainted):
                tainted.pop(n, None)

    # donation call sites
    for call in facts.calls:
        spec = table.resolve(fi, call.func, local_aliases)
        if spec is None or not spec.donates:
            continue
        donated = _donated_paths(call, spec)
        for path, arg_node in donated:
            if len(path) == 1 and path[0] in tainted \
                    and tainted[path[0]] < call.lineno:
                findings.append(Finding(
                    mi.path, call.lineno, "DON002",
                    f"donating '{'.'.join(path)}', which was obtained "
                    "from a by-reference store "
                    "(prefix_cache.restore / kv_store.get at line "
                    f"{tainted[path[0]]}): the store still holds these "
                    "buffers; donation corrupts every other reader"))
            f = _use_after_donate(fi, call, path, loads, stores)
            if f is not None:
                findings.append(Finding(mi.path, f[0], "DON001", f[1]))
    return findings


def _value_reads(value: ast.AST, tainted: Dict[str, int]) -> bool:
    """Does the assigned value read a tainted name (taint propagates)?"""
    for n in ast.walk(value):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                and n.id in tainted:
            return True
    return False


def _is_no_donate_source(value: ast.AST) -> bool:
    for n in ast.walk(value):
        if not isinstance(n, ast.Call):
            continue
        chain = cg.attr_chain(n.func)
        if chain is None or len(chain) < 2:
            continue
        tail = (chain[-2], chain[-1])
        if tail in _NO_DONATE_SOURCES:
            return True
    return False


def _donated_paths(call: ast.Call,
                   spec: "ir.JitSpec") -> List[Tuple[Path, ast.AST]]:
    out: List[Tuple[Path, ast.AST]] = []
    for i in spec.donate_argnums:
        if i < len(call.args):
            p = _expr_path(call.args[i])
            if p is not None:
                out.append((p, call.args[i]))
    if spec.donate_argnames:
        for kw in call.keywords:
            if kw.arg in spec.donate_argnames:
                p = _expr_path(kw.value)
                if p is not None:
                    out.append((p, kw.value))
        if spec.params:
            for pos, pname in enumerate(spec.params):
                if pname in spec.donate_argnames and pos < len(call.args):
                    p = _expr_path(call.args[pos])
                    if p is not None:
                        out.append((p, call.args[pos]))
    return out


def _enclosing_loop(fi: cg.FuncInfo, call: ast.Call):
    best = None
    for n in ast.walk(fi.node):
        if isinstance(n, (ast.For, ast.While)) \
                and n.lineno <= call.lineno <= (n.end_lineno or n.lineno):
            if best is None or n.lineno > best.lineno:
                best = n
    return best


def _use_after_donate(fi: cg.FuncInfo, call: ast.Call, path: Path,
                      loads, stores) -> Optional[Tuple[int, str]]:
    """First read of ``path`` after the donating call and before a
    rebind; loop-carried reuse when the call sits in a loop whose body
    never rebinds the path."""
    end = (call.end_lineno or call.lineno, 10 ** 6)

    def rebinds(pos_lo, pos_hi) -> bool:
        return any(pos_lo <= (ln, col) <= pos_hi
                   and path[:len(p)] == p          # prefix rebind kills
                   for ln, col, p in stores)

    # the donating statement itself may rebind (x = f(x)) — treat stores
    # on the call's own lines as an immediate kill
    call_span_lo = (call.lineno, -1)
    if rebinds(call_span_lo, end):
        killed_at_call = True
    else:
        killed_at_call = False

    if not killed_at_call:
        for ln, col, p in sorted(loads):
            if (ln, col) <= end:
                continue
            if _extends(p, path):
                if rebinds(end, (ln, col - 1)):
                    break
                return (ln, f"'{'.'.join(p)}' read at line {ln} after "
                            f"being donated at line {call.lineno}: "
                            "donated buffers may alias the dispatch "
                            "outputs; rebind before reading")
            # a full rebind before any use ends the hazard
            if (ln, col) > end and rebinds(end, (ln, col)):
                break
        loop = _enclosing_loop(fi, call)
        if loop is not None:
            span_lo = (loop.lineno, -1)
            span_hi = (loop.end_lineno or loop.lineno, 10 ** 6)
            if not rebinds(span_lo, span_hi):
                return (call.lineno,
                        f"'{'.'.join(path)}' is donated at line "
                        f"{call.lineno} inside a loop but never rebound "
                        "in the loop body: the next iteration reads a "
                        "donated buffer")
    return None
