"""Donation-discipline pass: a donated pytree is dead after the dispatch.

Rules
-----
DON001
    Use-after-donate: an argument donated to a ``jax.jit(...,
    donate_argnums=/donate_argnames=)`` dispatch is read again in the
    enclosing function before being rebound. The donated buffers may have
    been aliased into the outputs — reading them is undefined (and
    silently wrong on TPU). A donating call inside a loop whose donated
    value is never rebound before the next iteration is the same bug one
    iteration later.
DON002
    Donating a must-not-donate value: anything handed out *by reference*
    from a shared store — ``prefix_cache.restore(...)`` results (dense
    entries alias the cache's own pytree; the engine.py dense-state
    caveat) and ``kv_store.get(...)`` gathers that a snapshot still
    references. Donating one corrupts every other holder.

The pass is intra-procedural: donation sites are jit dispatches bound to
``self._name`` / module globals / ``@partial(jax.jit, ...)`` defs, plus
local aliases of those (including conditional aliases — an alias donates
if *any* branch donates).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import callgraph as cg
from repro.analysis.common import Finding

#: attribute tails whose call results are held by reference elsewhere and
#: must never be donated
_NO_DONATE_SOURCES: Tuple[Tuple[str, str], ...] = (
    ("prefix_cache", "restore"),
    ("kv_store", "get"),
)

Path = Tuple[str, ...]


@dataclasses.dataclass
class DonSpec:
    """What one donating jit donates."""

    argnums: Set[int] = dataclasses.field(default_factory=set)
    argnames: Set[str] = dataclasses.field(default_factory=set)
    #: positional parameter names of the wrapped callable (partial-bound
    #: keywords removed), for positional matching of donate_argnames
    params: Optional[List[str]] = None
    site_line: int = 0

    def merged(self, other: "DonSpec") -> "DonSpec":
        return DonSpec(self.argnums | other.argnums,
                       self.argnames | other.argnames,
                       self.params or other.params, self.site_line)


def _literal_ints(node: ast.AST) -> Set[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant)
                and isinstance(e.value, int)}
    return set()


def _literal_strs(node: ast.AST) -> Set[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant)
                and isinstance(e.value, str)}
    return set()


def _jit_donation(index: cg.Index, mi: cg.ModuleInfo,
                  cls: Optional[str], call: ast.Call) -> Optional[DonSpec]:
    """DonSpec if ``call`` is ``jax.jit(..., donate_*)``, else None."""
    hit = index.jax_wrapper(mi, call)
    if hit is None or hit[0] != "jit":
        return None
    spec = DonSpec(site_line=call.lineno)
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            spec.argnums |= _literal_ints(kw.value)
        elif kw.arg == "donate_argnames":
            spec.argnames |= _literal_strs(kw.value)
    if not spec.argnums and not spec.argnames:
        return None
    spec.params = _wrapped_params(index, mi, cls, call.args[0]) \
        if call.args else None
    return spec


def _wrapped_params(index: cg.Index, mi: cg.ModuleInfo,
                    cls: Optional[str],
                    expr: ast.AST) -> Optional[List[str]]:
    """Positional parameter names of the jitted callable, unwrapping
    ``functools.partial`` keyword bindings."""
    bound_kw: Set[str] = set()
    while isinstance(expr, ast.Call) \
            and cg.terminal_name(expr.func) == "partial" and expr.args:
        bound_kw |= {kw.arg for kw in expr.keywords if kw.arg}
        expr = expr.args[0]
    fi = index.resolve_ref(mi, cls, expr)
    if fi is None or not isinstance(fi.node, cg.FunctionNode):
        return None
    args = fi.node.args
    names = [a.arg for a in args.posonlyargs + args.args]
    if fi.cls is not None and names and names[0] == "self":
        names = names[1:]
    return [n for n in names if n not in bound_kw]


def _collect_donors(index: cg.Index, mi: cg.ModuleInfo):
    """Find donating dispatch handles in a module.

    Returns ``(attr_donors, name_donors, func_donors)``:
    ``{(class, attr): spec}`` for ``self._x = jax.jit(...)``,
    ``{name: spec}`` for module-level ``x = jax.jit(...)``,
    ``{qualname: spec}`` for ``@partial(jax.jit, donate_*)`` defs.
    """
    attr_donors: Dict[Tuple[str, str], DonSpec] = {}
    name_donors: Dict[str, DonSpec] = {}
    func_donors: Dict[str, DonSpec] = {}
    for fi in mi.functions.values():
        if not isinstance(fi.node, cg.FunctionNode):
            continue
        for dec in fi.node.decorator_list:
            if isinstance(dec, ast.Call) \
                    and cg.terminal_name(dec.func) == "partial" \
                    and dec.args:
                inner = ast.Call(func=dec.args[0], args=[],
                                 keywords=dec.keywords)
                inner.lineno = dec.lineno
                spec = _jit_donation(index, mi, fi.cls, inner)
                if spec is not None:
                    spec.params = _wrapped_params(
                        index, mi, fi.cls,
                        ast.Name(id=fi.name, ctx=ast.Load()))
                    args = fi.node.args
                    names = [a.arg for a in args.posonlyargs + args.args]
                    if fi.cls is not None and names \
                            and names[0] == "self":
                        names = names[1:]
                    bound = {kw.arg for kw in dec.keywords if kw.arg
                             and not kw.arg.startswith("donate")
                             and not kw.arg.startswith("static")}
                    spec.params = [n for n in names if n not in bound]
                    func_donors[fi.qualname] = spec
        for stmt in ast.walk(fi.node):
            if not isinstance(stmt, ast.Assign) \
                    or not isinstance(stmt.value, ast.Call):
                continue
            spec = _jit_donation(index, mi, fi.cls, stmt.value)
            if spec is None:
                continue
            for t in stmt.targets:
                chain = cg.attr_chain(t)
                if chain and chain[0] == "self" and len(chain) == 2 \
                        and fi.cls is not None:
                    attr_donors[(fi.cls, chain[1])] = spec
                elif chain and len(chain) == 1:
                    name_donors[chain[0]] = spec
    for stmt in mi.tree.body:
        if isinstance(stmt, ast.Assign) \
                and isinstance(stmt.value, ast.Call):
            spec = _jit_donation(index, mi, None, stmt.value)
            if spec is not None:
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        name_donors[t.id] = spec
    return attr_donors, name_donors, func_donors


def _expr_path(node: ast.AST) -> Optional[Path]:
    chain = cg.attr_chain(node)
    return tuple(chain) if chain else None


def _extends(used: Path, donated: Path) -> bool:
    return used[:len(donated)] == donated


class _FnScan(ast.NodeVisitor):
    """Ordered loads/stores of name/attribute paths in one function."""

    def __init__(self):
        self.loads: List[Tuple[int, int, Path]] = []
        self.stores: List[Tuple[int, int, Path]] = []

    def visit_Name(self, node: ast.Name):
        self._record(node)

    def visit_Attribute(self, node: ast.Attribute):
        p = _expr_path(node)
        if p is None:
            self.generic_visit(node)
            return
        self._record(node, p)

    def _record(self, node, path: Optional[Path] = None):
        path = path or (node.id,)
        entry = (node.lineno, node.col_offset, path)
        if isinstance(node.ctx, ast.Store):
            self.stores.append(entry)
        else:
            self.loads.append(entry)


def run(index: cg.Index) -> List[Finding]:
    findings: List[Finding] = []
    for mi in index.modules.values():
        attr_donors, name_donors, func_donors = _collect_donors(index, mi)
        if not (attr_donors or name_donors or func_donors):
            continue
        for fi in mi.functions.values():
            if isinstance(fi.node, cg.FunctionNode):
                findings += _check_function(mi, fi, attr_donors,
                                            name_donors, func_donors)
    return findings


def _donating_spec(mi: cg.ModuleInfo, fi: cg.FuncInfo, func: ast.AST,
                   attr_donors, name_donors, func_donors,
                   local_aliases: Dict[str, DonSpec]) -> Optional[DonSpec]:
    chain = cg.attr_chain(func)
    if chain is None:
        return None
    if len(chain) == 2 and chain[0] == "self" and fi.cls is not None:
        return attr_donors.get((fi.cls, chain[1]))
    if len(chain) == 1:
        name = chain[0]
        if name in local_aliases:
            return local_aliases[name]
        if name in name_donors:
            return name_donors[name]
        if name in func_donors:
            return func_donors[name]
    return None


def _alias_spec(expr: ast.AST, fi: cg.FuncInfo, attr_donors, name_donors,
                func_donors,
                local_aliases: Dict[str, DonSpec]) -> Optional[DonSpec]:
    """Spec for a local alias assignment: any referenced donating handle
    taints the alias (conditional expressions donate if either branch
    does)."""
    spec: Optional[DonSpec] = None
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            # a *call result* is a fresh value, not a dispatch handle
            return None
        cand = None
        chain = cg.attr_chain(node)
        if chain is None:
            continue
        if len(chain) == 2 and chain[0] == "self" and fi.cls is not None:
            cand = attr_donors.get((fi.cls, chain[1]))
        elif len(chain) == 1:
            cand = (local_aliases.get(chain[0])
                    or name_donors.get(chain[0])
                    or func_donors.get(chain[0]))
        if cand is not None:
            spec = cand if spec is None else spec.merged(cand)
    return spec


def _check_function(mi: cg.ModuleInfo, fi: cg.FuncInfo, attr_donors,
                    name_donors, func_donors) -> List[Finding]:
    findings: List[Finding] = []
    scan = _FnScan()
    scan.visit(fi.node)
    loads = sorted(scan.loads)
    stores = sorted(scan.stores)

    # local aliases of donating handles + tainted (no-donate) locals
    local_aliases: Dict[str, DonSpec] = {}
    tainted: Dict[str, int] = {}        # name -> taint line
    statements = [n for n in ast.walk(fi.node)
                  if isinstance(n, (ast.Assign, ast.AnnAssign))]
    statements.sort(key=lambda n: (n.lineno, n.col_offset))
    for stmt in statements:
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else ([stmt.target] if stmt.value is not None else [])
        value = stmt.value
        if value is None:
            continue
        names = []
        for t in targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                if isinstance(el, ast.Name):
                    names.append(el.id)
        if not names:
            continue
        spec = _alias_spec(value, fi, attr_donors, name_donors,
                           func_donors, local_aliases)
        for n in names:
            if spec is not None:
                local_aliases[n] = spec
            else:
                local_aliases.pop(n, None)
        taints = _is_no_donate_source(value)
        for n in names:
            if taints:
                tainted[n] = stmt.lineno
            elif not _value_reads(value, tainted):
                tainted.pop(n, None)

    # donation call sites
    for call in ast.walk(fi.node):
        if not isinstance(call, ast.Call):
            continue
        spec = _donating_spec(mi, fi, call.func, attr_donors, name_donors,
                              func_donors, local_aliases)
        if spec is None:
            continue
        donated = _donated_paths(call, spec)
        for path, arg_node in donated:
            if len(path) == 1 and path[0] in tainted \
                    and tainted[path[0]] < call.lineno:
                findings.append(Finding(
                    mi.path, call.lineno, "DON002",
                    f"donating '{'.'.join(path)}', which was obtained "
                    "from a by-reference store "
                    "(prefix_cache.restore / kv_store.get at line "
                    f"{tainted[path[0]]}): the store still holds these "
                    "buffers; donation corrupts every other reader"))
            f = _use_after_donate(fi, call, path, loads, stores)
            if f is not None:
                findings.append(Finding(mi.path, f[0], "DON001", f[1]))
    return findings


def _value_reads(value: ast.AST, tainted: Dict[str, int]) -> bool:
    """Does the assigned value read a tainted name (taint propagates)?"""
    for n in ast.walk(value):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                and n.id in tainted:
            return True
    return False


def _is_no_donate_source(value: ast.AST) -> bool:
    for n in ast.walk(value):
        if not isinstance(n, ast.Call):
            continue
        chain = cg.attr_chain(n.func)
        if chain is None or len(chain) < 2:
            continue
        tail = (chain[-2], chain[-1])
        if tail in _NO_DONATE_SOURCES:
            return True
    return False


def _donated_paths(call: ast.Call,
                   spec: DonSpec) -> List[Tuple[Path, ast.AST]]:
    out: List[Tuple[Path, ast.AST]] = []
    for i in spec.argnums:
        if i < len(call.args):
            p = _expr_path(call.args[i])
            if p is not None:
                out.append((p, call.args[i]))
    if spec.argnames:
        for kw in call.keywords:
            if kw.arg in spec.argnames:
                p = _expr_path(kw.value)
                if p is not None:
                    out.append((p, kw.value))
        if spec.params:
            for pos, pname in enumerate(spec.params):
                if pname in spec.argnames and pos < len(call.args):
                    p = _expr_path(call.args[pos])
                    if p is not None:
                        out.append((p, call.args[pos]))
    return out


def _enclosing_loop(fi: cg.FuncInfo, call: ast.Call):
    best = None
    for n in ast.walk(fi.node):
        if isinstance(n, (ast.For, ast.While)) \
                and n.lineno <= call.lineno <= (n.end_lineno or n.lineno):
            if best is None or n.lineno > best.lineno:
                best = n
    return best


def _use_after_donate(fi: cg.FuncInfo, call: ast.Call, path: Path,
                      loads, stores) -> Optional[Tuple[int, str]]:
    """First read of ``path`` after the donating call and before a
    rebind; loop-carried reuse when the call sits in a loop whose body
    never rebinds the path."""
    end = (call.end_lineno or call.lineno, 10 ** 6)

    def rebinds(pos_lo, pos_hi) -> bool:
        return any(pos_lo <= (ln, col) <= pos_hi
                   and path[:len(p)] == p          # prefix rebind kills
                   for ln, col, p in stores)

    # the donating statement itself may rebind (x = f(x)) — treat stores
    # on the call's own lines as an immediate kill
    call_span_lo = (call.lineno, -1)
    if rebinds(call_span_lo, end):
        killed_at_call = True
    else:
        killed_at_call = False

    if not killed_at_call:
        for ln, col, p in sorted(loads):
            if (ln, col) <= end:
                continue
            if _extends(p, path):
                if rebinds(end, (ln, col - 1)):
                    break
                return (ln, f"'{'.'.join(p)}' read at line {ln} after "
                            f"being donated at line {call.lineno}: "
                            "donated buffers may alias the dispatch "
                            "outputs; rebind before reading")
            # a full rebind before any use ends the hazard
            if (ln, col) > end and rebinds(end, (ln, col)):
                break
        loop = _enclosing_loop(fi, call)
        if loop is not None:
            span_lo = (loop.lineno, -1)
            span_hi = (loop.end_lineno or loop.lineno, 10 ** 6)
            if not rebinds(span_lo, span_hi):
                return (call.lineno,
                        f"'{'.'.join(path)}' is donated at line "
                        f"{call.lineno} inside a loop but never rebound "
                        "in the loop body: the next iteration reads a "
                        "donated buffer")
    return None
