"""Trace-purity pass: no eager pool ops / host compute under trace.

Rules
-----
TRC001
    An eager pool operation is reachable from a traced region: a call to
    an allocator primitive (``alloc_blocks`` / ``retain_blocks`` /
    ``release_blocks`` / ``detach_planes``), or to any function that can
    raise :class:`~repro.core.paged.PoolExhausted` (raising requires
    concrete values — under trace it either fails or silently never
    fires), or a direct ``raise PoolExhausted`` inside a traced function.
TRC002
    Host-side compute under trace: ``np.*`` calls (everything except
    trace-time-static helpers like ``np.prod`` / dtype constructors) or
    environment reads (``os.environ`` / ``os.getenv``). These run once at
    trace time with tracer inputs (crash) or bake a host value into the
    compiled program (stale on the next call).
TRC003
    Mutation of host object state (``self.x = ...``) inside a traced
    function: runs once at trace time, then never again on cached
    executions — the classic "works until the second call" bug.

Calls that cannot be resolved still match when their terminal attribute
name is a distinctive eager primitive, so aliasing cannot hide them.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import callgraph as cg
from repro.analysis import ir
from repro.analysis.common import Finding

EAGER_PRIMITIVES = {"alloc_blocks", "retain_blocks", "release_blocks",
                    "detach_planes"}

#: np helpers that are safe under trace: they compute static metadata
#: (shapes, dtypes, paddings) from concrete Python values at trace time.
NP_TRACE_SAFE = {
    "prod", "ceil", "floor", "log", "log2", "log10", "sqrt", "gcd", "lcm",
    "dtype", "iinfo", "finfo", "isscalar", "ndim", "shape", "size",
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "promote_types",
    "result_type",
}

#: exception names whose ``except`` clause swallows PoolExhausted
_CATCHING = {"PoolExhausted", "RuntimeError", "Exception", "BaseException"}


def _protected_spans(node: ast.AST) -> List[Tuple[int, int]]:
    """Line ranges of ``try:`` bodies guarded by a PoolExhausted-catching
    handler — calls inside do not propagate the raiser property."""
    spans = []
    for t in ast.walk(node):
        if not isinstance(t, ast.Try):
            continue
        for h in t.handlers:
            name = (cg.terminal_name(h.type)
                    if h.type is not None else None)
            if h.type is None or name in _CATCHING:
                first, last = t.body[0], t.body[-1]
                spans.append((first.lineno,
                              last.end_lineno or last.lineno))
                break
    return spans


def _raises_pool_exhausted_directly(node: ast.AST) -> Optional[int]:
    for r in ast.walk(node):
        if isinstance(r, ast.Raise) and r.exc is not None:
            exc = r.exc.func if isinstance(r.exc, ast.Call) else r.exc
            if cg.terminal_name(exc) == "PoolExhausted":
                return r.lineno
    return None


def compute_raisers(index: cg.Index) -> Set[cg.FuncInfo]:
    """Functions that can raise PoolExhausted (direct + fixpoint over
    resolvable calls, excluding calls inside a catching ``try``)."""
    raisers: Set[cg.FuncInfo] = set()
    for mi in index.modules.values():
        for fi in mi.functions.values():
            if _raises_pool_exhausted_directly(fi.node) is not None:
                raisers.add(fi)
    changed = True
    while changed:
        changed = False
        for mi in index.modules.values():
            for fi in mi.functions.values():
                if fi in raisers:
                    continue
                spans = _protected_spans(fi.node)
                for call in ast.walk(fi.node):
                    if not isinstance(call, ast.Call):
                        continue
                    if any(a <= call.lineno <= b for a, b in spans):
                        continue
                    callee = index.resolve_ref(mi, fi.cls, call.func)
                    if callee is not None and callee in raisers:
                        raisers.add(fi)
                        changed = True
                        break
    return raisers


def run(an_ir: "ir.IR") -> List[Finding]:
    index = an_ir.index
    raisers = compute_raisers(index)
    raiser_methods = {fi.name for fi in raisers if fi.cls is not None}
    regions = an_ir.regions
    findings: List[Finding] = []
    seen: Set[Tuple[str, str, int]] = set()

    def emit(rule: str, fi: cg.FuncInfo, line: int, msg: str,
             region: cg.Region) -> None:
        key = (rule, fi.module.path, line)
        if key in seen:
            return
        seen.add(key)
        chain = " -> ".join(region.members[fi])
        root = region.root
        findings.append(Finding(
            fi.module.path, line, rule,
            f"{msg} [traced via {root.wrapper} at "
            f"{root.func.module.name}:{root.site_line}, "
            f"call chain {chain}]"))

    for region in regions:
        for fi in region.members:
            _check_function(index, fi, region, raisers, raiser_methods,
                            emit)
    return findings


def _check_function(index: cg.Index, fi: cg.FuncInfo, region: cg.Region,
                    raisers: Set[cg.FuncInfo], raiser_methods: Set[str],
                    emit) -> None:
    mi = fi.module
    node = fi.node
    is_method = fi.cls is not None

    line = _raises_pool_exhausted_directly(node)
    if line is not None:
        emit("TRC001", fi, line,
             "raise PoolExhausted inside a traced function "
             "(pool exhaustion must be handled on the host, before "
             "dispatch)", region)

    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            _check_call(index, fi, n, region, raisers, raiser_methods,
                        emit)
        elif isinstance(n, ast.Attribute):
            chain = cg.attr_chain(n)
            if chain is not None and len(chain) == 2 \
                    and chain[0] == "os" and chain[1] == "environ" \
                    and mi.module_alias_target("os") == "os":
                emit("TRC002", fi, n.lineno,
                     "os.environ read under trace: the value is baked "
                     "in at trace time and stale afterwards; resolve it "
                     "eagerly and pass it in", region)
        elif isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            if not is_method:
                continue
            targets = (n.targets if isinstance(n, ast.Assign)
                       else [n.target])
            for t in targets:
                for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                           else [t]):
                    base = el.value if isinstance(el, ast.Subscript) \
                        else el
                    chain = cg.attr_chain(base)
                    if chain and chain[0] == "self" and len(chain) >= 2 \
                            and isinstance(base, ast.Attribute):
                        emit("TRC003", fi, el.lineno,
                             f"mutation of host state 'self."
                             f"{'.'.join(chain[1:])}' under trace: runs "
                             "once at trace time, never on cached "
                             "executions", region)


def _check_call(index: cg.Index, fi: cg.FuncInfo, call: ast.Call,
                region: cg.Region, raisers: Set[cg.FuncInfo],
                raiser_methods: Set[str], emit) -> None:
    mi = fi.module
    tname = cg.terminal_name(call.func)
    if tname in EAGER_PRIMITIVES:
        emit("TRC001", fi, call.lineno,
             f"eager pool operation '{tname}' reachable from a traced "
             "region: allocator calls mutate host refcounts and must "
             "happen before dispatch", region)
        return
    chain = cg.attr_chain(call.func)
    if chain is not None and len(chain) >= 2:
        head = mi.module_alias_target(chain[0])
        if head == "numpy" and chain[-1] not in NP_TRACE_SAFE:
            emit("TRC002", fi, call.lineno,
                 f"host numpy call '{'.'.join(chain)}' under trace: "
                 "np ops run on host values at trace time; use jnp or "
                 "hoist to the eager caller", region)
            return
        if head == "os" and chain[-1] == "getenv":
            emit("TRC002", fi, call.lineno,
                 "os.getenv under trace: the value is baked in at trace "
                 "time and stale afterwards", region)
            return
    callee = index.resolve_ref(mi, fi.cls, call.func)
    if callee is not None:
        if callee in raisers:
            emit("TRC001", fi, call.lineno,
                 f"call to '{callee.qualname}' which can raise "
                 "PoolExhausted: pool pressure must be handled eagerly, "
                 "outside the traced region", region)
        return
    if chain is not None and len(chain) >= 2 \
            and tname in raiser_methods \
            and tname not in cg.COMMON_METHOD_NAMES:
        emit("TRC001", fi, call.lineno,
             f"call to '{tname}' (matches a PoolExhausted-raising "
             "method) from a traced region", region)
