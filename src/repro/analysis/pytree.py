"""Pytree-registration pass: dataclasses under trace must be registered.

Rules
-----
PYT001
    A dataclass is constructed inside a traced region but never
    registered as a pytree (``jax.tree_util.register_dataclass`` /
    ``register_pytree_node[_class]`` / ``register_static``). jax treats
    an unregistered instance as a leaf: it escapes the trace as a static
    constant, silently freezing its array fields at their trace-time
    values. (NamedTuples are auto-registered — the repo's convention for
    jit-crossing containers — and are exempt.)
PYT002
    Registered aux/meta data contains arrays: ``register_dataclass(...,
    meta_fields=[...])`` naming an array-annotated field, or a
    ``tree_flatten`` whose aux tuple returns an array-annotated
    attribute. Aux data must be hashable static metadata — arrays in aux
    defeat tracing-cache keys and crash on hashing.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import callgraph as cg
from repro.analysis import ir
from repro.analysis.common import Finding

_REGISTER_FNS = {"register_dataclass", "register_pytree_node",
                 "register_pytree_node_class", "register_static",
                 "register_pytree_with_keys", "register_pytree_with_keys_class"}

#: annotation terminals that mean "this field is an array"
_ARRAY_ANNOTATIONS = {"ndarray", "Array", "ArrayLike", "DeviceArray"}


def _registered_classes(mi: cg.ModuleInfo) -> Set[str]:
    """Class names registered as pytrees anywhere in the module (call
    form ``register_*(Cls, ...)`` or decorator form)."""
    out: Set[str] = set()
    for node in ast.walk(mi.tree):
        if isinstance(node, ast.Call):
            if cg.terminal_name(node.func) in _REGISTER_FNS and node.args:
                name = cg.terminal_name(node.args[0])
                if name:
                    out.add(name)
    for ci in mi.classes.values():
        for dec in ci.node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if cg.terminal_name(target) in _REGISTER_FNS:
                out.add(ci.name)
    return out


def _dataclass_index(index: cg.Index) -> Dict[str, Tuple[cg.ClassInfo, bool]]:
    """All dataclasses across modules: name -> (info, registered?).
    Also keyed as "module:Class" for cross-module resolution."""
    out: Dict[str, Tuple[cg.ClassInfo, bool]] = {}
    for mi in index.modules.values():
        registered = _registered_classes(mi)
        for ci in mi.classes.values():
            if ci.is_dataclass:
                entry = (ci, ci.name in registered)
                out[f"{mi.name}:{ci.name}"] = entry
    return out


def _resolve_class(index: cg.Index, mi: cg.ModuleInfo,
                   func: ast.AST,
                   dcs: Dict[str, Tuple[cg.ClassInfo, bool]]
                   ) -> Optional[Tuple[cg.ClassInfo, bool]]:
    """Resolve a Call's callee to a known dataclass, through imports."""
    chain = cg.attr_chain(func)
    if chain is None:
        return None
    if len(chain) == 1:
        name = chain[0]
        if name in mi.classes:
            return dcs.get(f"{mi.name}:{name}")
        if name in mi.from_imports:
            mod, orig = mi.from_imports[name]
            return dcs.get(f"{mod}:{orig}")
        return None
    target = mi.module_alias_target(chain[0])
    if target is not None and len(chain) == 2:
        return dcs.get(f"{target}:{chain[1]}")
    return None


def run(an_ir: "ir.IR") -> List[Finding]:
    index = an_ir.index
    findings: List[Finding] = []
    dcs = _dataclass_index(index)
    seen: Set[Tuple[str, int]] = set()
    for region in an_ir.regions:
        for fi, chain in region.members.items():
            for call in ast.walk(fi.node):
                if not isinstance(call, ast.Call):
                    continue
                hit = _resolve_class(index, fi.module, call.func, dcs)
                if hit is None:
                    continue
                ci, registered = hit
                if registered:
                    continue
                key = (fi.module.path, call.lineno)
                if key in seen:
                    continue
                seen.add(key)
                findings.append(Finding(
                    fi.module.path, call.lineno, "PYT001",
                    f"dataclass '{ci.name}' constructed under trace "
                    f"(via {region.root.wrapper}, call chain "
                    f"{' -> '.join(chain)}) but never registered as a "
                    "pytree: jax will treat it as a static leaf and "
                    "freeze its fields at trace-time values; register "
                    "it (jax.tree_util.register_dataclass) or use a "
                    "NamedTuple"))
    findings += _check_aux_data(index)
    return findings


def _array_fields(ci: cg.ClassInfo) -> Set[str]:
    out: Set[str] = set()
    for stmt in ci.node.body:
        if isinstance(stmt, ast.AnnAssign) \
                and isinstance(stmt.target, ast.Name):
            for n in ast.walk(stmt.annotation):
                name = None
                if isinstance(n, ast.Name):
                    name = n.id
                elif isinstance(n, ast.Attribute):
                    name = n.attr
                elif isinstance(n, ast.Constant) \
                        and isinstance(n.value, str):
                    # string annotations: match on terminal token
                    name = n.value.rsplit(".", 1)[-1].strip("'\"[]")
                if name in _ARRAY_ANNOTATIONS:
                    out.add(stmt.target.id)
                    break
    return out


def _check_aux_data(index: cg.Index) -> List[Finding]:
    """PYT002: meta_fields / tree_flatten aux containing array fields."""
    findings: List[Finding] = []
    for mi in index.modules.values():
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            if cg.terminal_name(node.func) != "register_dataclass":
                continue
            cls_name = cg.terminal_name(node.args[0]) if node.args \
                else None
            ci = mi.classes.get(cls_name or "")
            if ci is None:
                continue
            arrays = _array_fields(ci)
            meta: Set[str] = set()
            meta_node: Optional[ast.AST] = None
            for kw in node.keywords:
                if kw.arg == "meta_fields":
                    meta_node = kw.value
            if meta_node is None and len(node.args) >= 3:
                meta_node = node.args[2]
            if meta_node is not None and isinstance(
                    meta_node, (ast.List, ast.Tuple)):
                meta = {e.value for e in meta_node.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
            bad = sorted(meta & arrays)
            if bad:
                findings.append(Finding(
                    mi.path, node.lineno, "PYT002",
                    f"register_dataclass({cls_name}, ...) places array "
                    f"field(s) {bad} in meta_fields: aux data is hashed "
                    "into the tracing cache key and must be static "
                    "metadata, not arrays"))
        # tree_flatten methods returning self.<array field> in aux
        for ci in mi.classes.values():
            fl = ci.methods.get("tree_flatten")
            if fl is None:
                continue
            arrays = _array_fields(ci)
            if not arrays:
                continue
            for ret in ast.walk(fl.node):
                if not isinstance(ret, ast.Return) \
                        or not isinstance(ret.value, ast.Tuple) \
                        or len(ret.value.elts) != 2:
                    continue
                aux = ret.value.elts[1]
                for n in ast.walk(aux):
                    chain = cg.attr_chain(n)
                    if chain and chain[0] == "self" and len(chain) == 2 \
                            and chain[1] in arrays \
                            and isinstance(n, ast.Attribute):
                        findings.append(Finding(
                            mi.path, ret.lineno, "PYT002",
                            f"tree_flatten of '{ci.name}' returns array "
                            f"field 'self.{chain[1]}' in its aux data: "
                            "aux must be hashable static metadata; move "
                            "it into the children tuple"))
                        break
    return findings
