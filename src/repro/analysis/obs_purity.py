"""Observability-purity pass: metrics/tracing stay on the host side.

Rules
-----
OBS001
    A MetricsRegistry / Tracer method call is reachable from a traced
    region. Instruments are host objects mutating Python floats and
    event buffers: under trace the call runs once at trace time and
    never again on cached executions — counters silently freeze, spans
    never close. The detector keys on the receiver path (a segment named
    ``metrics`` / ``tracer`` / ``_inst`` / ``_metrics`` / ``_tracer`` /
    ``tr``) plus an instrument-method terminal, so aliasing through
    ``self._inst.tokens.inc()`` or ``registry.counter("x").inc()`` still
    matches.
OBS002
    Unbalanced keyed span pair: a ``tracer.begin(key, ...)`` whose key
    fingerprint has no matching ``end``/``discard`` anywhere in the
    analyzed module set (or an ``end`` with no ``begin``). Keyed spans
    are cross-tick by design — begin at submit, end at retirement — so
    the pairing is checked globally, by the key's literal head (e.g.
    ``("running", req.request_id)`` pairs on ``"running"``), falling
    back to the normalized key expression when no literal is present.

Both checks run over the shared IR; the begin/end table is assembled in
one walk per module set.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import callgraph as cg
from repro.analysis import ir
from repro.analysis.common import Finding

#: receiver-path segments that mark an observability sink
_OBS_RECEIVERS = {"metrics", "tracer", "_metrics", "_tracer", "_inst",
                  "tr"}

#: instrument/tracer method terminals (MetricsRegistry + Tracer API)
_OBS_METHODS = {
    "inc", "dec", "observe", "set", "labels", "counter", "gauge",
    "histogram", "gauge_fn", "begin", "end", "discard", "span",
    "instant", "thread_name",
}

#: tracer span verbs for the OBS002 pairing table
_SPAN_VERBS = {"begin", "end", "discard"}


def _obs_call(call: ast.Call) -> Optional[Tuple[str, List[str]]]:
    """(method terminal, receiver chain) when ``call`` targets an
    observability sink."""
    chain = cg.attr_chain(call.func)
    if chain is None:
        # registry.counter("x").inc(): the receiver is a Call — match on
        # the inner call instead (walk finds it separately), but still
        # catch ``<obs call>.inc()`` one level deep
        if isinstance(call.func, ast.Attribute) \
                and isinstance(call.func.value, ast.Call):
            inner = _obs_call(call.func.value)
            if inner is not None and call.func.attr in _OBS_METHODS:
                return call.func.attr, inner[1]
        return None
    if chain[-1] not in _OBS_METHODS:
        return None
    if not any(seg in _OBS_RECEIVERS for seg in chain[:-1]):
        return None
    return chain[-1], chain[:-1]


def run(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    findings += _check_traced_obs(an_ir)
    findings += _check_span_balance(an_ir)
    return findings


# --------------------------------------------------------------------------- #
# OBS001
# --------------------------------------------------------------------------- #
def _check_traced_obs(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    seen: Set[Tuple[str, int]] = set()
    for fi, regions in an_ir.member_regions.items():
        mi = fi.module
        region = regions[0]
        root = region.root
        facts = an_ir.facts(fi)
        for call in facts.calls:
            hit = _obs_call(call)
            if hit is None or facts.in_nested(call.lineno):
                continue
            key = (mi.path, call.lineno)
            if key in seen:
                continue
            seen.add(key)
            method, recv = hit
            chain = " -> ".join(region.members[fi])
            findings.append(Finding(
                mi.path, call.lineno, "OBS001",
                f"observability call '{'.'.join(recv)}.{method}()' "
                f"reachable from a traced region [traced via "
                f"{root.wrapper} at {root.func.module.name}:"
                f"{root.site_line}, call chain {chain}]: instruments "
                "mutate host state — under trace this records once at "
                "trace time and never again; hoist it to the eager "
                "dispatch site"))
    return findings


# --------------------------------------------------------------------------- #
# OBS002
# --------------------------------------------------------------------------- #
def _span_fingerprint(call: ast.Call) -> Optional[str]:
    """Stable fingerprint of a keyed span: the key's literal string head
    when present (``("running", rid)`` -> ``running``), else the
    normalized key expression."""
    if not call.args:
        return None
    key = call.args[0]
    if isinstance(key, ast.Constant) and isinstance(key.value, str):
        return key.value
    if isinstance(key, (ast.Tuple, ast.List)) and key.elts:
        head = key.elts[0]
        if isinstance(head, ast.Constant) and isinstance(head.value, str):
            return head.value
    try:
        return ast.unparse(key)
    except Exception:                       # pragma: no cover - defensive
        return None


def _check_span_balance(an_ir: "ir.IR") -> List[Finding]:
    begins: Dict[str, List[Tuple[str, int]]] = {}
    closes: Set[str] = set()
    ends: Dict[str, List[Tuple[str, int]]] = {}
    opens: Set[str] = set()
    for mi in an_ir.modules.values():
        for node in ast.walk(mi.tree):
            if not isinstance(node, ast.Call):
                continue
            hit = _obs_call(node)
            if hit is None or hit[0] not in _SPAN_VERBS:
                continue
            fp = _span_fingerprint(node)
            if fp is None:
                continue
            if hit[0] == "begin":
                begins.setdefault(fp, []).append((mi.path, node.lineno))
                opens.add(fp)
            else:
                ends.setdefault(fp, []).append((mi.path, node.lineno))
                closes.add(fp)
    findings: List[Finding] = []
    for fp, sites in begins.items():
        if fp in closes:
            continue
        for path, line in sites:
            findings.append(Finding(
                path, line, "OBS002",
                f"keyed span '{fp}' is begun here but no matching "
                "end()/discard() exists on any analyzed engine code "
                "path: the span leaks and exports as unfinished; pair "
                "it (end at retirement, discard on abort)"))
    for fp, sites in ends.items():
        if fp in opens:
            continue
        for path, line in sites:
            findings.append(Finding(
                path, line, "OBS002",
                f"keyed span '{fp}' is ended/discarded here but never "
                "begun on any analyzed engine code path: the call is "
                "dead (or the begin was dropped in a refactor)"))
    return findings
