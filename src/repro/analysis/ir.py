"""Shared analysis IR: one parse, one symbol table, one call graph, one
set of dataflow facts — every lint pass is a visitor over this.

Before this module each pass re-derived what it needed from the raw
:class:`~repro.analysis.callgraph.Index` (trace-purity and pytree each
recomputed the traced regions; donation kept a private jit-handle
collector and load/store scanner). The IR computes each product once per
``run_paths`` invocation and hands passes read-only views:

* :attr:`IR.regions` — every traced region (cached
  :func:`callgraph.traced_regions` result), plus the derived
  :attr:`IR.member_regions` (function -> regions containing it) and
  :attr:`IR.shard_members` (functions inside a ``shard_map``-rooted
  region — the set the sharding pass treats as collective-legal);
* :meth:`IR.facts` — per-function linear dataflow facts
  (:class:`FunctionFacts`): ordered name/attribute loads and stores,
  ordered assignments, call sites, nested local defs, and the
  loop-varying name set the recompile pass keys on;
* :meth:`IR.handles` — every jit *dispatch handle* in a module
  (``self._step = jax.jit(...)``, module-level ``step = jax.jit(...)``,
  ``@jax.jit``/``@partial(jax.jit, ...)`` defs) as a :class:`JitSpec`
  carrying donate **and** static argument declarations — the donation
  pass filters for donating handles, the recompile pass uses them all.

Everything here stays pure stdlib ``ast`` — the CLI must keep running
before jax is importable.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import callgraph as cg

NamePath = Tuple[str, ...]


# --------------------------------------------------------------------------- #
# jit dispatch handles
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class JitSpec:
    """One ``jax.jit(...)`` dispatch handle: what it donates, what it
    declared static, the wrapped callable's positional params, and a
    human-readable display name for diagnostics."""

    site_line: int = 0
    donate_argnums: Set[int] = dataclasses.field(default_factory=set)
    donate_argnames: Set[str] = dataclasses.field(default_factory=set)
    static_argnums: Set[int] = dataclasses.field(default_factory=set)
    static_argnames: Set[str] = dataclasses.field(default_factory=set)
    #: positional parameter names of the wrapped callable (partial-bound
    #: keywords removed), for positional matching of *_argnames
    params: Optional[List[str]] = None
    display: str = "jit"

    @property
    def donates(self) -> bool:
        return bool(self.donate_argnums or self.donate_argnames)

    def merged(self, other: "JitSpec") -> "JitSpec":
        return JitSpec(self.site_line,
                       self.donate_argnums | other.donate_argnums,
                       self.donate_argnames | other.donate_argnames,
                       self.static_argnums | other.static_argnums,
                       self.static_argnames | other.static_argnames,
                       self.params or other.params,
                       self.display)


@dataclasses.dataclass
class HandleTable:
    """All jit dispatch handles of one module, by binding kind."""

    #: ``self._x = jax.jit(...)`` -> {(class, attr): spec}
    attr: Dict[Tuple[str, str], JitSpec] = dataclasses.field(
        default_factory=dict)
    #: module-level / function-local ``x = jax.jit(...)`` -> {name: spec}
    name: Dict[str, JitSpec] = dataclasses.field(default_factory=dict)
    #: ``@jax.jit`` / ``@partial(jax.jit, ...)`` defs -> {qualname: spec}
    func: Dict[str, JitSpec] = dataclasses.field(default_factory=dict)

    def __bool__(self) -> bool:
        return bool(self.attr or self.name or self.func)

    def resolve(self, fi: cg.FuncInfo, func_expr: ast.AST,
                local_aliases: Optional[Dict[str, JitSpec]] = None
                ) -> Optional[JitSpec]:
        """Spec for a dispatch call's callee expression, through local
        aliases (``chunk_fn = self._paged_chunk``)."""
        chain = cg.attr_chain(func_expr)
        if chain is None:
            return None
        if len(chain) == 2 and chain[0] == "self" and fi.cls is not None:
            return self.attr.get((fi.cls, chain[1]))
        if len(chain) == 1:
            name = chain[0]
            if local_aliases and name in local_aliases:
                return local_aliases[name]
            return self.name.get(name) or self.func.get(name)
        return None

    def alias_spec(self, expr: ast.AST, fi: cg.FuncInfo,
                   local_aliases: Dict[str, JitSpec]) -> Optional[JitSpec]:
        """Spec a local alias assignment carries: any referenced handle
        taints the alias (conditional expressions dispatch through either
        branch, so the specs merge)."""
        spec: Optional[JitSpec] = None
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                # a *call result* is a fresh value, not a dispatch handle
                return None
            chain = cg.attr_chain(node)
            if chain is None:
                continue
            cand = None
            if len(chain) == 2 and chain[0] == "self" \
                    and fi.cls is not None:
                cand = self.attr.get((fi.cls, chain[1]))
            elif len(chain) == 1:
                cand = (local_aliases.get(chain[0])
                        or self.name.get(chain[0])
                        or self.func.get(chain[0]))
            if cand is not None:
                spec = cand if spec is None else spec.merged(cand)
        return spec


def _literal_ints(node: ast.AST) -> Set[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)}
    return set()


def _literal_strs(node: ast.AST) -> Set[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)}
    return set()


def _jit_spec(index: cg.Index, mi: cg.ModuleInfo, cls: Optional[str],
              call: ast.Call) -> Optional[JitSpec]:
    """JitSpec if ``call`` is ``jax.jit(...)``, else None."""
    hit = index.jax_wrapper(mi, call)
    if hit is None or hit[0] != "jit":
        return None
    spec = JitSpec(site_line=call.lineno)
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            spec.donate_argnums |= _literal_ints(kw.value)
        elif kw.arg == "donate_argnames":
            spec.donate_argnames |= _literal_strs(kw.value)
        elif kw.arg == "static_argnums":
            spec.static_argnums |= _literal_ints(kw.value)
        elif kw.arg == "static_argnames":
            spec.static_argnames |= _literal_strs(kw.value)
    spec.params = _wrapped_params(index, mi, cls, call.args[0]) \
        if call.args else None
    return spec


def _wrapped_params(index: cg.Index, mi: cg.ModuleInfo,
                    cls: Optional[str],
                    expr: ast.AST) -> Optional[List[str]]:
    """Positional parameter names of the jitted callable, unwrapping
    ``functools.partial`` keyword bindings."""
    bound_kw: Set[str] = set()
    while isinstance(expr, ast.Call) \
            and cg.terminal_name(expr.func) == "partial" and expr.args:
        bound_kw |= {kw.arg for kw in expr.keywords if kw.arg}
        expr = expr.args[0]
    fi = index.resolve_ref(mi, cls, expr)
    if fi is None or not isinstance(fi.node, cg.FunctionNode):
        return None
    args = fi.node.args
    names = [a.arg for a in args.posonlyargs + args.args]
    if fi.cls is not None and names and names[0] == "self":
        names = names[1:]
    return [n for n in names if n not in bound_kw]


def _unwrap_jit_call(index: cg.Index, mi: cg.ModuleInfo,
                     call: ast.Call) -> Optional[ast.Call]:
    """The ``jax.jit(...)`` call inside ``call`` — itself, or one wrapped
    by a dispatcher (``self._mesh_dispatch(jax.jit(...))``): the binding
    still names a dispatch handle with the inner jit's declarations."""
    hit = index.jax_wrapper(mi, call)
    if hit is not None and hit[0] == "jit":
        return call
    for a in call.args:
        if isinstance(a, ast.Call):
            inner = _unwrap_jit_call(index, mi, a)
            if inner is not None:
                return inner
    return None


def _collect_handles(index: cg.Index, mi: cg.ModuleInfo) -> HandleTable:
    table = HandleTable()
    for fi in mi.functions.values():
        if not isinstance(fi.node, cg.FunctionNode):
            continue
        for dec in fi.node.decorator_list:
            spec = None
            if isinstance(dec, ast.Call) \
                    and cg.terminal_name(dec.func) == "partial" \
                    and dec.args:
                inner = ast.Call(func=dec.args[0], args=[],
                                 keywords=dec.keywords)
                inner.lineno = dec.lineno
                spec = _jit_spec(index, mi, fi.cls, inner)
                if spec is not None:
                    args = fi.node.args
                    names = [a.arg for a in args.posonlyargs + args.args]
                    if fi.cls is not None and names \
                            and names[0] == "self":
                        names = names[1:]
                    bound = {kw.arg for kw in dec.keywords if kw.arg
                             and not kw.arg.startswith("donate")
                             and not kw.arg.startswith("static")}
                    spec.params = [n for n in names if n not in bound]
            elif index._decorator_wrapper(mi, dec) == "jit":
                # bare ``@jax.jit`` / ``@jit`` (no donate/static kwargs)
                spec = JitSpec(site_line=fi.node.lineno)
                args = fi.node.args
                names = [a.arg for a in args.posonlyargs + args.args]
                if fi.cls is not None and names and names[0] == "self":
                    names = names[1:]
                spec.params = names
            if spec is not None:
                spec.display = fi.qualname
                table.func[fi.qualname] = spec
                if fi.cls is None:
                    table.func.setdefault(fi.name, spec)
        for stmt in ast.walk(fi.node):
            if not isinstance(stmt, ast.Assign) \
                    or not isinstance(stmt.value, ast.Call):
                continue
            jc = _unwrap_jit_call(index, mi, stmt.value)
            spec = _jit_spec(index, mi, fi.cls, jc) \
                if jc is not None else None
            if spec is None:
                continue
            for t in stmt.targets:
                chain = cg.attr_chain(t)
                if chain and chain[0] == "self" and len(chain) == 2 \
                        and fi.cls is not None:
                    s = dataclasses.replace(
                        spec, display=f"self.{chain[1]}",
                        donate_argnums=set(spec.donate_argnums),
                        donate_argnames=set(spec.donate_argnames),
                        static_argnums=set(spec.static_argnums),
                        static_argnames=set(spec.static_argnames))
                    table.attr[(fi.cls, chain[1])] = s
                elif chain and len(chain) == 1:
                    s = dataclasses.replace(
                        spec, display=chain[0],
                        donate_argnums=set(spec.donate_argnums),
                        donate_argnames=set(spec.donate_argnames),
                        static_argnums=set(spec.static_argnums),
                        static_argnames=set(spec.static_argnames))
                    table.name[chain[0]] = s
    for stmt in mi.tree.body:
        if isinstance(stmt, ast.Assign) \
                and isinstance(stmt.value, ast.Call):
            spec = _jit_spec(index, mi, None, stmt.value)
            if spec is not None:
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        spec.display = t.id
                        table.name[t.id] = spec
    return table


# --------------------------------------------------------------------------- #
# per-function linear dataflow facts
# --------------------------------------------------------------------------- #
class _FnScan(ast.NodeVisitor):
    """Ordered loads/stores of name/attribute paths in one function."""

    def __init__(self):
        self.loads: List[Tuple[int, int, NamePath]] = []
        self.stores: List[Tuple[int, int, NamePath]] = []

    def visit_Name(self, node: ast.Name):
        self._record(node)

    def visit_Attribute(self, node: ast.Attribute):
        chain = cg.attr_chain(node)
        if chain is None:
            self.generic_visit(node)
            return
        self._record(node, tuple(chain))

    def _record(self, node, path: Optional[NamePath] = None):
        path = path or (node.id,)
        entry = (node.lineno, node.col_offset, path)
        if isinstance(node.ctx, ast.Store):
            self.stores.append(entry)
        else:
            self.loads.append(entry)


@dataclasses.dataclass
class FunctionFacts:
    """Linear dataflow facts for one analyzed function."""

    fi: cg.FuncInfo
    #: ordered (line, col, dotted path) name/attribute reads
    loads: List[Tuple[int, int, NamePath]]
    #: ordered (line, col, dotted path) name/attribute writes
    stores: List[Tuple[int, int, NamePath]]
    #: every Assign/AnnAssign/AugAssign in source order
    assignments: List[ast.stmt]
    #: every Call node in the body (nested defs included)
    calls: List[ast.Call]
    #: nested local defs, name -> synthetic FuncInfo
    local_defs: Dict[str, cg.FuncInfo]
    #: (lineno, end_lineno) spans of every For/While in the body
    loop_spans: List[Tuple[int, int]]
    #: names whose value varies across loop iterations: ``for`` targets
    #: plus names stored inside a loop body
    loop_vars: Set[str]
    #: (lineno, end_lineno) spans of nested defs — code there belongs to
    #: the nested scope (which gets its own synthetic FuncInfo when it is
    #: a traced-region member), not to this function's linear flow
    nested_spans: List[Tuple[int, int]]

    def in_loop(self, lineno: int) -> bool:
        return any(a <= lineno <= b for a, b in self.loop_spans)

    def in_nested(self, lineno: int) -> bool:
        return any(a <= lineno <= b for a, b in self.nested_spans)


def _target_names(t: ast.AST) -> List[str]:
    out = []
    for el in (t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t]):
        if isinstance(el, ast.Name):
            out.append(el.id)
        elif isinstance(el, ast.Starred) \
                and isinstance(el.value, ast.Name):
            out.append(el.value.id)
    return out


def compute_facts(fi: cg.FuncInfo) -> FunctionFacts:
    node = fi.node
    scan = _FnScan()
    scan.visit(node)
    assignments = [n for n in ast.walk(node)
                   if isinstance(n, (ast.Assign, ast.AnnAssign,
                                     ast.AugAssign))]
    assignments.sort(key=lambda n: (n.lineno, n.col_offset))
    calls = [n for n in ast.walk(node) if isinstance(n, ast.Call)]
    local_defs: Dict[str, cg.FuncInfo] = {}
    if isinstance(node, cg.FunctionNode):
        local_defs = {
            n.name: cg.FuncInfo(fi.module,
                                f"{fi.qualname}.<locals>.{n.name}",
                                n, cls=fi.cls)
            for n in ast.walk(node)
            if isinstance(n, cg.FunctionNode) and n is not node}
    loop_spans = [(n.lineno, n.end_lineno or n.lineno)
                  for n in ast.walk(node)
                  if isinstance(n, (ast.For, ast.While))]
    nested_spans = [(n.lineno, n.end_lineno or n.lineno)
                    for n in ast.walk(node)
                    if isinstance(n, cg.FunctionNode) and n is not node]
    loop_vars: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.For):
            loop_vars.update(_target_names(n.target))
        elif isinstance(n, ast.comprehension):
            loop_vars.update(_target_names(n.target))
    for stmt in assignments:
        in_loop = any(a <= stmt.lineno <= b for a, b in loop_spans)
        if not in_loop:
            continue
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        for t in targets:
            loop_vars.update(_target_names(t))
    return FunctionFacts(fi, sorted(scan.loads), sorted(scan.stores),
                         assignments, calls, local_defs, loop_spans,
                         loop_vars, nested_spans)


# --------------------------------------------------------------------------- #
# the IR proper
# --------------------------------------------------------------------------- #
class IR:
    """One parse + symbol table + call graph + dataflow facts, shared by
    every pass of a single analysis run."""

    def __init__(self, index: cg.Index):
        self.index = index
        #: every traced region, computed once (previously each pass paid
        #: its own traced_regions() walk)
        self.regions: List[cg.Region] = cg.traced_regions(index)
        #: function -> regions containing it
        self.member_regions: Dict[cg.FuncInfo, List[cg.Region]] = {}
        for region in self.regions:
            for fi in region.members:
                self.member_regions.setdefault(fi, []).append(region)
        #: functions inside some shard_map-rooted region — where
        #: collectives are legal
        self.shard_members: Set[cg.FuncInfo] = set()
        self.shard_regions: List[cg.Region] = []
        for region in self.regions:
            if region.root.wrapper == "shard_map":
                self.shard_regions.append(region)
                self.shard_members.update(region.members)
        self._facts: Dict[cg.FuncInfo, FunctionFacts] = {}
        self._handles: Dict[str, HandleTable] = {}

    @classmethod
    def build(cls, files: Sequence[Path]) -> "IR":
        return cls(cg.Index.build(files))

    # convenience views ---------------------------------------------------
    @property
    def modules(self) -> Dict[str, cg.ModuleInfo]:
        return self.index.modules

    def facts(self, fi: cg.FuncInfo) -> FunctionFacts:
        f = self._facts.get(fi)
        if f is None:
            f = self._facts[fi] = compute_facts(fi)
        return f

    def handles(self, mi: cg.ModuleInfo) -> HandleTable:
        t = self._handles.get(mi.path)
        if t is None:
            t = self._handles[mi.path] = _collect_handles(self.index, mi)
        return t

    def region_of(self, fi: cg.FuncInfo) -> Optional[cg.Region]:
        """One representative traced region containing ``fi`` (for
        diagnostics), or None when the function is never traced."""
        regions = self.member_regions.get(fi)
        return regions[0] if regions else None
