"""Paged-pool sanitizer: a shadow allocator with per-block allocation sites.

Enabled by ``REPRO_SANITIZE=1`` (any value other than ``""``/``"0"``):
:class:`~repro.core.paged.PagedStateStore` calls :func:`attach_store` from
its ``__init__``, which wraps the store's eager allocator API at the
instance level. Every op is then validated against the pool *before* it
mutates refcounts, and the allocator invariants
(:func:`repro.core.paged.check_invariants`) are re-checked *after* — the
test-only helper promoted to a first-class runtime check. The sanitizer
records an allocation site (first engine/test frame) per live block, so a
leak or double-release reports *where the block came from*, not just its
id.

Detected at the op level
------------------------
* double-release: ``release_blocks``/``release`` dropping a block whose
  refcount is already 0 (or dropping more references than exist);
* retain-of-dead-block: ``retain_blocks`` on an unreferenced block (a
  stale table is being forked/spliced);
* negative refcounts / free-stack corruption / leaked blocks after every
  op, via ``check_invariants``.

Detected at the engine level (:func:`check_lanes`, called per step, and
``Engine.close()`` at shutdown)
-------------------------------
* CoW violations: a running lane whose table maps a block it neither owns
  (``blocks[i] == owned[i]``) nor holds a travelling reference for
  (``_lane_shared``), or a *writable* entry aliasing a shared block
  (ref > 1) — in-trace writes would corrupt every other holder;
* leaks at shutdown: pool references that survive lane retirement,
  parcel disposal and prefix-cache clearing, reported with their
  allocation sites.

The sanitizer is strict: violations raise :class:`SanitizerError`
immediately (tests assert on it; production never enables the flag).
"""
from __future__ import annotations

import os
import traceback
from typing import Dict, List, Optional

import numpy as np


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


class SanitizerError(AssertionError):
    """A pool invariant was violated at runtime."""


def _call_site(skip_substrings=("core/paged.py", "analysis/sanitizer.py",
                                "jax/", "numpy/")) -> str:
    """First stack frame outside the allocator/sanitizer — the caller the
    allocation should be attributed to."""
    for frame in reversed(traceback.extract_stack()[:-2]):
        fn = frame.filename.replace("\\", "/")
        if not any(s in fn for s in skip_substrings):
            return f"{fn}:{frame.lineno} in {frame.name}"
    return "<unknown>"


class PoolSanitizer:
    """Shadow allocator state for one :class:`PagedStateStore`."""

    def __init__(self, store):
        self.store = store
        #: block id -> allocation site (live blocks only)
        self.sites: Dict[int, str] = {}
        self.ops = 0

    # -- shadow bookkeeping ------------------------------------------------
    def _refs(self) -> np.ndarray:
        return np.asarray(self.store.pool.ref)

    def _sync_sites(self, site: str) -> None:
        """Adopt pool truth: record ``site`` for blocks that became live
        outside ``alloc_blocks`` (store ``put`` pages blocks in through
        ``from_dense``), drop sites of blocks that died."""
        ref = self._refs()
        live = set(np.nonzero(ref > 0)[0].tolist())
        for bid in live - self.sites.keys():
            self.sites[bid] = site
        for bid in list(self.sites):
            if bid not in live:
                del self.sites[bid]

    def _check_pool(self, op: str) -> None:
        from repro.core import paged
        try:
            paged.check_invariants(self.store.pool)
        except AssertionError as e:
            raise SanitizerError(
                f"pool invariant broken after {op}: {e}") from e
        self.ops += 1

    # -- op validation -----------------------------------------------------
    def before_release(self, ids: np.ndarray, op: str) -> None:
        # negative ids are the "unmapped" sentinel the device-side refcount
        # ops skip; numpy indexing would wrap them onto real blocks
        ids = np.asarray(ids, np.int64).reshape(-1)
        ids = ids[ids >= 0]
        if not ids.size:
            return
        ref = self._refs()
        uniq, counts = np.unique(ids, return_counts=True)
        for bid, n in zip(uniq.tolist(), counts.tolist()):
            have = int(ref[bid])
            if n > have:
                site = self.sites.get(bid, "<untracked>")
                raise SanitizerError(
                    f"double release: {op} drops {n} reference(s) of "
                    f"block {bid} but only {have} exist(s); "
                    f"block allocated at {site}, "
                    f"released from {_call_site()}")

    def before_retain(self, ids: np.ndarray) -> None:
        # same sentinel rule as before_release: -1 entries are skipped on
        # device, so they are not retains and must not index the ref array
        ids = np.asarray(ids, np.int64).reshape(-1)
        ids = ids[ids >= 0]
        if not ids.size:
            return
        ref = self._refs()
        dead = ids[ref[ids] <= 0]
        if dead.size:
            raise SanitizerError(
                f"retain of unreferenced block(s) {sorted(set(dead.tolist()))}: "
                "a stale table is being forked or spliced "
                f"(from {_call_site()})")

    def after_alloc(self, ids: np.ndarray) -> None:
        site = _call_site()
        ref = self._refs()
        for bid in np.asarray(ids, np.int64).reshape(-1).tolist():
            if int(ref[bid]) != 1:
                raise SanitizerError(
                    f"alloc_blocks returned block {bid} with refcount "
                    f"{int(ref[bid])} (expected 1)")
            self.sites[bid] = site

    def after_op(self, op: str) -> None:
        self._check_pool(op)
        self._sync_sites(f"{op} at {_call_site()}")

    # -- reporting ---------------------------------------------------------
    def live_report(self, ids) -> str:
        lines = [f"  block {bid}: allocated at "
                 f"{self.sites.get(bid, '<untracked>')}"
                 for bid in sorted(ids)]
        return "\n".join(lines)


def attach_store(store) -> PoolSanitizer:
    """Instance-level wrap of a store's eager allocator API."""
    san = PoolSanitizer(store)
    store._sanitizer = san

    alloc, retain, release_ids = (store.alloc_blocks, store.retain_blocks,
                                  store.release_blocks)
    put, release_snap = store.put, store.release

    def alloc_blocks(n):
        ids = alloc(n)
        san.after_alloc(ids)
        san.after_op("alloc_blocks")
        return ids

    def retain_blocks(ids):
        san.before_retain(ids)
        retain(ids)
        san.after_op("retain_blocks")

    def release_blocks(ids):
        san.before_release(ids, "release_blocks")
        release_ids(ids)
        san.after_op("release_blocks")

    def put_wrapped(tree, parent=None):
        out = put(tree, parent=parent)
        san.after_op("put")
        return out

    def release_wrapped(snap):
        if not getattr(snap, "released", False):
            from repro.core import paged
            if isinstance(snap, paged.TableSnapshot):
                san.before_release(snap.block_ids(), "release(snapshot)")
        release_snap(snap)
        san.after_op("release")

    store.alloc_blocks = alloc_blocks
    store.retain_blocks = retain_blocks
    store.release_blocks = release_blocks
    store.put = put_wrapped
    store.release = release_wrapped
    return san


# --------------------------------------------------------------------------- #
# Engine-level checks
# --------------------------------------------------------------------------- #
def _lane_leaf_tables(state, slot: int):
    """(section, key, blocks, owned) per paged layer of one lane of the
    batched decode state."""
    for section in ("blocks", "tail"):
        layers = getattr(state, section)
        for key in sorted(layers):
            leaf = layers[key]
            if not hasattr(leaf, "blocks") or not hasattr(leaf, "owned"):
                continue                     # SSM state: nothing paged
            # leaves are [..., lane, max_blocks] — an optional stacked-layer
            # axis rides in FRONT of the lane axis (the period scan), so the
            # lane is always axis -2
            yield (section, key,
                   np.asarray(leaf.blocks)[..., slot, :].reshape(-1),
                   np.asarray(leaf.owned)[..., slot, :].reshape(-1))


def check_lanes(engine) -> None:
    """Per-step CoW/refcount audit of every RUNNING lane's tables.

    Retired lanes keep stale tables until their next ``_lane_reset``, so
    only slots the scheduler reports as running are audited.
    """
    state = engine._slot_states
    if state is None:
        return
    ref = np.asarray(engine.kv_store.pool.ref)
    for slot in sorted(engine.scheduler.running):
        held = set(np.asarray(engine._lane_shared[slot]).tolist())
        for section, key, blocks, owned in _lane_leaf_tables(state, slot):
            mapped = blocks >= 0
            writable = mapped & (blocks == owned)
            foreign = blocks[mapped & ~writable].tolist()
            loose = [b for b in foreign if b not in held]
            if loose:
                raise SanitizerError(
                    f"CoW violation (lane {slot}, {section}/{key}): table "
                    f"maps block(s) {sorted(set(loose))} it neither owns "
                    "nor holds a reference for — an eviction elsewhere "
                    "can free them under the running lane")
            shared_writable = [int(b) for b in blocks[writable].tolist()
                               if ref[int(b)] > 1]
            if shared_writable:
                raise SanitizerError(
                    f"CoW violation (lane {slot}, {section}/{key}): "
                    f"writable table entr{'ies' if len(shared_writable) > 1 else 'y'} "
                    f"map shared block(s) {sorted(set(shared_writable))} "
                    "(refcount > 1): in-trace writes would corrupt every "
                    "other holder; the fork must swap the owned set first")
            dead = [int(b) for b in blocks[mapped].tolist()
                    if ref[int(b)] <= 0]
            if dead:
                raise SanitizerError(
                    f"use-after-free (lane {slot}, {section}/{key}): table "
                    f"maps unreferenced block(s) {sorted(set(dead))}")


def check_shutdown(engine) -> None:
    """Shutdown leak audit: after lanes retire, parcels drop and the
    prefix cache clears, the only live references left must be the lanes'
    permanent ``owned`` reservations."""
    store = engine.kv_store
    ref = np.asarray(store.pool.ref)
    live = set(np.nonzero(ref > 0)[0].tolist())
    expected = set()
    state = engine._slot_states
    if state is not None and engine._paged_in_model:
        n_slots = int(np.asarray(state.pos).shape[0])
        for slot in range(n_slots):
            for _, _, blocks, owned in _lane_leaf_tables(state, slot):
                expected.update(int(b) for b in owned.tolist() if b >= 0)
    leaked = live - expected
    if leaked:
        san = getattr(store, "_sanitizer", None)
        detail = f"\n{san.live_report(leaked)}" if san is not None else ""
        raise SanitizerError(
            f"{len(leaked)} block(s) leaked at engine shutdown "
            f"(live but not part of any lane's reserved set): "
            f"{sorted(leaked)[:16]}{detail}")
    missing = expected - live
    if missing:
        raise SanitizerError(
            f"lane-reserved block(s) lost their pool reference: "
            f"{sorted(missing)[:16]}")
