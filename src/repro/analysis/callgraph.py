"""Module index + call graph + traced-region discovery for the lint passes.

The passes need three capabilities:

* resolve a ``Call`` node to the function definition it invokes (through
  import aliases, ``from``-imports, ``self.method``, ``functools.partial``
  and nested local defs);
* find every *traced root*: the callable handed to ``jax.jit`` /
  ``vmap`` / ``grad`` / ``shard_map`` / ``lax.cond`` / ``lax.scan`` / ...
  whether as a call argument, a decorator, or a factory result;
* walk the *traced region* — the set of analyzed functions reachable from
  a root through resolvable calls — recording one example call chain per
  function for diagnostics.

Resolution is best-effort: an unresolvable callee simply ends a call-graph
edge. Passes that must not miss eager pool primitives therefore also match
on distinctive terminal attribute names (``alloc_blocks`` etc.), which
import aliasing cannot hide.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import parse_allows

# jax transforms whose callable argument is traced. Maps terminal name ->
# indices of callable arguments (-1 = "list of callables at index 1",
# used by lax.switch).
_JAX_WRAPPERS: Dict[str, Tuple[int, ...]] = {
    "jit": (0,), "vmap": (0,), "pmap": (0,), "grad": (0,),
    "value_and_grad": (0,), "checkpoint": (0,), "remat": (0,),
    "shard_map": (0,),
}
_LAX_WRAPPERS: Dict[str, Tuple[int, ...]] = {
    "cond": (1, 2), "scan": (0,), "while_loop": (0, 1), "fori_loop": (2,),
    "map": (0,), "associative_scan": (0,), "switch": (-1,),
}

FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef)


def attr_chain(node: ast.AST) -> Optional[List[str]]:
    """Flatten ``a.b.c`` into ``["a", "b", "c"]``; None if the head is not
    a plain Name (e.g. a call result or subscript)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return None


def terminal_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


@dataclasses.dataclass
class FuncInfo:
    """One analyzed function / method / lambda."""

    module: "ModuleInfo"
    qualname: str                       # "f", "Cls.f", or "<lambda:LINE>"
    node: ast.AST                       # FunctionDef | Lambda
    cls: Optional[str] = None           # enclosing class name, if a method

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    @property
    def line(self) -> int:
        return self.node.lineno

    def __hash__(self):
        return hash((self.module.path, self.qualname, self.node.lineno))

    def __eq__(self, other):
        return (isinstance(other, FuncInfo)
                and self.module.path == other.module.path
                and self.qualname == other.qualname
                and self.node.lineno == other.node.lineno)

    def __repr__(self):
        return f"<{self.module.name}.{self.qualname}>"


@dataclasses.dataclass
class ClassInfo:
    name: str
    node: ast.ClassDef
    module: "ModuleInfo"
    is_dataclass: bool = False
    methods: Dict[str, FuncInfo] = dataclasses.field(default_factory=dict)


class ModuleInfo:
    """Parsed file + symbol tables."""

    def __init__(self, path: Path, name: str, tree: ast.Module, source: str):
        self.path = str(path)
        self.name = name
        self.tree = tree
        self.source = source
        self.allows = parse_allows(source)
        #: local alias -> dotted module ("np" -> "numpy")
        self.import_alias: Dict[str, str] = {}
        #: local name -> (module, original name) for ``from m import n as x``
        self.from_imports: Dict[str, Tuple[str, str]] = {}
        self.functions: Dict[str, FuncInfo] = {}
        self.classes: Dict[str, ClassInfo] = {}
        self._collect()

    def _collect(self) -> None:
        # imports anywhere in the file (functions import numpy locally);
        # module-wide scoping over-approximates, which is the safe direction
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    local = a.asname or a.name.split(".")[0]
                    self.import_alias[local] = (a.name if a.asname
                                                else a.name.split(".")[0])
                    if a.asname is None and "." in a.name:
                        # ``import a.b.c`` binds "a"; remember the full
                        # path so a.b.c.f resolves by longest prefix
                        self.import_alias.setdefault(a.name.split(".")[0],
                                                     a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.from_imports[a.asname or a.name] = (node.module,
                                                             a.name)
        for node in self.tree.body:
            if isinstance(node, FunctionNode):
                self.functions[node.name] = FuncInfo(self, node.name, node)
            elif isinstance(node, ast.ClassDef):
                ci = ClassInfo(node.name, node, self,
                               is_dataclass=_has_dataclass_decorator(node))
                for item in node.body:
                    if isinstance(item, FunctionNode):
                        fi = FuncInfo(self, f"{node.name}.{item.name}",
                                      item, cls=node.name)
                        ci.methods[item.name] = fi
                        self.functions[fi.qualname] = fi
                self.classes[node.name] = ci

    def module_alias_target(self, name: str) -> Optional[str]:
        """Dotted module a local name refers to, if any."""
        if name in self.import_alias:
            return self.import_alias[name]
        if name in self.from_imports:
            mod, orig = self.from_imports[name]
            return f"{mod}.{orig}"
        return None


def _has_dataclass_decorator(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = terminal_name(target)
        if name == "dataclass":
            return True
    return False


class Index:
    """All analyzed modules + cross-module resolution."""

    def __init__(self, modules: List[ModuleInfo]):
        self.modules = {m.name: m for m in modules}
        self.by_path = {m.path: m for m in modules}
        #: method name -> every class method with that name (fallback
        #: resolution for receiver-typed calls like ``pol.keep_mask``)
        self.method_index: Dict[str, List[FuncInfo]] = {}
        for m in modules:
            for ci in m.classes.values():
                for name, fi in ci.methods.items():
                    self.method_index.setdefault(name, []).append(fi)

    @classmethod
    def build(cls, files: Sequence[Path]) -> "Index":
        from repro.analysis.common import module_name_for, parse_file
        mods = []
        for f in files:
            tree = parse_file(f)
            if tree is None:
                continue
            mods.append(ModuleInfo(f, module_name_for(f), tree,
                                   f.read_text()))
        return cls(mods)

    # -- resolution -------------------------------------------------------
    def resolve_dotted(self, dotted: List[str]) -> Optional[FuncInfo]:
        """Resolve ``["repro","core","paged","append"]`` by longest module
        prefix, the remainder naming a function or ``Class.method``."""
        for cut in range(len(dotted) - 1, 0, -1):
            mod = self.modules.get(".".join(dotted[:cut]))
            if mod is None:
                continue
            rest = dotted[cut:]
            if len(rest) == 1:
                return mod.functions.get(rest[0])
            if len(rest) == 2:
                return mod.functions.get(f"{rest[0]}.{rest[1]}")
            return None
        return None

    def resolve_ref(self, mi: ModuleInfo, cls: Optional[str],
                    node: ast.AST,
                    local_defs: Optional[Dict[str, FuncInfo]] = None
                    ) -> Optional[FuncInfo]:
        """Resolve a function *reference* expression (the callee of a Call,
        a decorator, or a callable argument) to its FuncInfo."""
        chain = attr_chain(node)
        if chain is None:
            return None
        if len(chain) == 1:
            name = chain[0]
            if local_defs and name in local_defs:
                return local_defs[name]
            if name in mi.from_imports:
                mod, orig = mi.from_imports[name]
                return self.resolve_dotted(mod.split(".") + [orig])
            return mi.functions.get(name)
        if chain[0] == "self" and cls is not None and len(chain) == 2:
            return mi.functions.get(f"{cls}.{chain[1]}")
        target = mi.module_alias_target(chain[0])
        if target is not None:
            return self.resolve_dotted(target.split(".") + chain[1:])
        return None

    def jax_wrapper(self, mi: ModuleInfo, call: ast.Call
                    ) -> Optional[Tuple[str, Tuple[int, ...]]]:
        """If ``call`` invokes a tracing jax transform, return
        ``(name, callable-arg indices)``."""
        func = call.func
        chain = attr_chain(func)
        if chain is None:
            return None
        name = chain[-1]
        if len(chain) == 1:
            src = mi.from_imports.get(name)
            if src is None:
                return None
            mod = src[0]
            if name == "shard_map" or (src[1] == "shard_map"):
                return ("shard_map", _JAX_WRAPPERS["shard_map"])
            if mod == "jax" and name in _JAX_WRAPPERS:
                return (name, _JAX_WRAPPERS[name])
            if mod.endswith("lax") and name in _LAX_WRAPPERS:
                return (name, _LAX_WRAPPERS[name])
            return None
        target = mi.module_alias_target(chain[0])
        if target is None:
            return None
        prefix = ".".join([target] + chain[1:-1])
        if name == "shard_map" and prefix.startswith("jax"):
            return ("shard_map", _JAX_WRAPPERS["shard_map"])
        if name in _JAX_WRAPPERS and prefix == "jax":
            return (name, _JAX_WRAPPERS[name])
        if name in _LAX_WRAPPERS and prefix.endswith("lax") \
                and prefix.startswith("jax"):
            return (name, _LAX_WRAPPERS[name])
        return None

    # -- traced roots -----------------------------------------------------
    def traced_roots(self, mi: ModuleInfo) -> List["TracedRoot"]:
        """Every traced root in ``mi``: decorated defs plus callables
        handed to jax transforms anywhere (module level or inside
        functions, with local nested defs resolvable)."""
        roots: List[TracedRoot] = []
        # decorator form: @jax.jit / @partial(jax.jit, ...)
        for fi in list(mi.functions.values()):
            node = fi.node
            if not isinstance(node, FunctionNode):
                continue
            for dec in node.decorator_list:
                wname = self._decorator_wrapper(mi, dec)
                if wname is not None:
                    roots.append(TracedRoot(fi, wname, node.lineno))
        # call form, scoped so local defs resolve
        for scope_fi, local_defs, calls in self._scoped_calls(mi):
            cls = scope_fi.cls if scope_fi else None
            for call in calls:
                hit = self.jax_wrapper(mi, call)
                if hit is None:
                    continue
                wname, arg_idx = hit
                for idx in arg_idx:
                    targets: List[ast.AST] = []
                    if idx == -1:       # lax.switch branch list
                        if len(call.args) > 1 and isinstance(
                                call.args[1], (ast.List, ast.Tuple)):
                            targets = list(call.args[1].elts)
                    elif idx < len(call.args):
                        targets = [call.args[idx]]
                    for t in targets:
                        for fi in self._callable_targets(
                                mi, cls, t, local_defs):
                            roots.append(TracedRoot(fi, wname, call.lineno))
        return roots

    def _decorator_wrapper(self, mi: ModuleInfo,
                           dec: ast.AST) -> Optional[str]:
        """``@jax.jit`` / ``@jit`` / ``@partial(jax.jit, ...)`` -> name."""
        if isinstance(dec, ast.Call):
            tname = terminal_name(dec.func)
            if tname == "partial" and dec.args:
                return self._decorator_wrapper(mi, dec.args[0])
            hit = self.jax_wrapper(mi, dec)
            if hit is not None:
                return hit[0]
            return None
        chain = attr_chain(dec)
        if chain is None:
            return None
        fake = ast.Call(func=dec, args=[], keywords=[])
        hit = self.jax_wrapper(mi, fake)
        return hit[0] if hit else None

    def _callable_targets(self, mi: ModuleInfo, cls: Optional[str],
                          expr: ast.AST,
                          local_defs: Dict[str, FuncInfo]
                          ) -> List[FuncInfo]:
        """Function(s) a callable expression refers to. Lambdas become
        synthetic FuncInfos; ``partial(f, ...)``, nested transforms and
        resolvable factory calls (``jit(make_step(cfg))`` -> walk
        ``make_step``, whose body contains the nested def) unwrap."""
        if isinstance(expr, ast.Lambda):
            return [FuncInfo(mi, f"<lambda:{expr.lineno}>", expr, cls=cls)]
        if isinstance(expr, ast.Call):
            tname = terminal_name(expr.func)
            if tname == "partial" and expr.args:
                return self._callable_targets(mi, cls, expr.args[0],
                                              local_defs)
            if self.jax_wrapper(mi, expr) is not None and expr.args:
                return self._callable_targets(mi, cls, expr.args[0],
                                              local_defs)
            factory = self.resolve_ref(mi, cls, expr.func, local_defs)
            return [factory] if factory is not None else []
        fi = self.resolve_ref(mi, cls, expr, local_defs)
        return [fi] if fi is not None else []

    def _scoped_calls(self, mi: ModuleInfo):
        """Yield (enclosing FuncInfo or None, local defs, Call nodes) per
        scope. Nested defs are attributed to their outermost function so
        ``jit(step)`` inside ``build_lowered`` resolves ``step``."""
        top_calls = []
        for node in mi.tree.body:
            if isinstance(node, FunctionNode) or \
                    isinstance(node, ast.ClassDef):
                continue
            top_calls += [n for n in ast.walk(node)
                          if isinstance(n, ast.Call)]
        yield None, {}, top_calls
        for fi in list(mi.functions.values()):
            if not isinstance(fi.node, FunctionNode):
                continue
            local_defs = {
                n.name: FuncInfo(mi, f"{fi.qualname}.<locals>.{n.name}",
                                 n, cls=fi.cls)
                for n in ast.walk(fi.node)
                if isinstance(n, FunctionNode) and n is not fi.node}
            calls = [n for n in ast.walk(fi.node)
                     if isinstance(n, ast.Call)]
            yield fi, local_defs, calls


@dataclasses.dataclass
class TracedRoot:
    func: FuncInfo
    wrapper: str            # "jit", "cond", ...
    site_line: int          # line of the jit/cond/... call


@dataclasses.dataclass
class Region:
    """Functions reachable under trace, with one example chain each."""

    root: TracedRoot
    #: FuncInfo -> call chain from the root ("a -> b -> c")
    members: Dict[FuncInfo, Tuple[str, ...]]


#: receiver-typed method names NOT followed / name-matched: too generic
#: (dict.get, list.append, set.add, queue.put ... would alias onto
#: analyzed classes and poison the region).
COMMON_METHOD_NAMES = {
    "get", "put", "append", "extend", "update", "pop", "popitem", "clear",
    "add", "remove", "insert", "read", "write", "close", "copy", "items",
    "keys", "values", "join", "split", "sum", "mean", "reshape", "astype",
    "at", "set", "replace", "index", "count",
}


def traced_regions(index: Index) -> List[Region]:
    """Compute the traced region of every root in every module."""
    regions: List[Region] = []
    for mi in index.modules.values():
        for root in index.traced_roots(mi):
            members: Dict[FuncInfo, Tuple[str, ...]] = {}
            queue: List[Tuple[FuncInfo, Tuple[str, ...]]] = [
                (root.func, (root.func.qualname,))]
            while queue:
                fi, chain = queue.pop()
                if fi in members or len(chain) > 12:
                    continue
                members[fi] = chain
                for callee in _callees(index, fi):
                    if callee not in members:
                        queue.append(
                            (callee, chain + (callee.qualname,)))
            regions.append(Region(root, members))
    return regions


def _callees(index: Index, fi: FuncInfo) -> Iterator[FuncInfo]:
    """Resolvable callees of a function, walking its whole body (nested
    defs included — inside a traced region everything is traced)."""
    mi = fi.module
    node = fi.node
    local_defs = {}
    if isinstance(node, FunctionNode):
        local_defs = {
            n.name: FuncInfo(mi, f"{fi.qualname}.<locals>.{n.name}",
                             n, cls=fi.cls)
            for n in ast.walk(node)
            if isinstance(n, FunctionNode) and n is not node}
    for call in ast.walk(node):
        if not isinstance(call, ast.Call):
            continue
        hit = index.jax_wrapper(mi, call)
        if hit is not None:
            # nested transform: its callable args are traced too
            _, arg_idx = hit
            for idx in arg_idx:
                if idx == -1:
                    if len(call.args) > 1 and isinstance(
                            call.args[1], (ast.List, ast.Tuple)):
                        for el in call.args[1].elts:
                            yield from index._callable_targets(
                                mi, fi.cls, el, local_defs)
                elif idx < len(call.args):
                    yield from index._callable_targets(
                        mi, fi.cls, call.args[idx], local_defs)
            continue
        callee = index.resolve_ref(mi, fi.cls, call.func, local_defs)
        if callee is not None:
            yield callee
            continue
        # receiver-typed fallback: follow ``pol.keep_mask(...)`` when
        # exactly one analyzed class defines the (distinctive) name
        tname = terminal_name(call.func)
        if tname and tname not in COMMON_METHOD_NAMES:
            candidates = index.method_index.get(tname, [])
            if len(candidates) == 1:
                yield candidates[0]
