"""CLI: ``python -m repro.analysis [--fail-on-warn] PATH...``.

Text mode prints one ``path:line: RULE: message`` per finding (stable
order) and a summary line with a per-family breakdown; exits 1 under
``--fail-on-warn`` when anything fired. ``--rules TRC`` restricts to
rule-ID prefixes (comma separated) — the filter applies to findings,
``--list-rules``, and the summary alike.

``--format json|sarif`` emits machine-readable output on stdout (the
summary moves to stderr so the document stays parseable); SARIF is
2.1.0, one run, with the (filtered) rule catalogue in
``tool.driver.rules`` — feed it to CI code-scanning upload.

``--baseline FILE`` drops findings whose fingerprint a reviewed
baseline covers (rule + path relative to the baseline file + hash of
the flagged line's text, so unrelated line drift doesn't invalidate
it); ``--write-baseline`` refreshes the file from the current finding
set instead of reporting.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.common import (RULES, apply_baseline, family_counts,
                                   load_baseline, rel_path, run_paths,
                                   write_baseline)

#: SARIF 2.1.0 document header
SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/"
                "sarif-spec/master/Schemata/sarif-schema-2.1.0.json")


def _summary(findings) -> str:
    n = len(findings)
    line = f"repro.analysis: {n} finding{'s' if n != 1 else ''}"
    fams = family_counts(findings)
    if fams:
        line += " (" + ", ".join(f"{fam} {c}" for fam, c in fams.items()) \
                + ")"
    return line


def to_sarif(findings, rule_ids, root=None) -> dict:
    """SARIF 2.1.0 document: one run, the rule catalogue restricted to
    ``rule_ids``, one result per finding with a file/line location."""
    return {
        "version": SARIF_VERSION,
        "$schema": SARIF_SCHEMA,
        "runs": [{
            "tool": {"driver": {
                "name": "repro.analysis",
                "informationUri":
                    "https://example.invalid/repro/docs/API.md",
                "rules": [{
                    "id": rid,
                    "shortDescription": {"text": RULES[rid]},
                    "defaultConfiguration": {"level": "warning"},
                } for rid in sorted(rule_ids)],
            }},
            "results": [{
                "ruleId": f.rule,
                "level": "warning",
                "message": {"text": f.message},
                "locations": [{
                    "physicalLocation": {
                        "artifactLocation": {
                            "uri": rel_path(f.path, root)},
                        "region": {"startLine": f.line},
                    },
                }],
            } for f in findings],
        }],
    }


def to_json(findings, root=None) -> dict:
    return {
        "tool": "repro.analysis",
        "schema_version": 1,
        "findings": [{
            "path": rel_path(f.path, root),
            "line": f.line,
            "rule": f.rule,
            "message": f.message,
        } for f in findings],
        "counts": family_counts(findings),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant lints for the repro serving stack "
                    "(trace purity, donation discipline, pytree "
                    "registration, sharding discipline, recompile "
                    "churn, observability purity).")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to analyze")
    ap.add_argument("--fail-on-warn", action="store_true",
                    help="exit 1 if any finding is reported")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule-ID prefixes to keep "
                         "(e.g. 'TRC001,DON')")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue (honors --rules) "
                         "and exit")
    ap.add_argument("--format", default="text",
                    choices=("text", "json", "sarif"),
                    help="output format (json/sarif print the document "
                         "on stdout, the summary on stderr)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="reviewed-baseline file: findings it "
                         "fingerprints are not reported")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite --baseline from the current findings "
                         "instead of reporting them")
    args = ap.parse_args(argv)

    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)

    if args.list_rules:
        keep = tuple(rules) if rules else None
        listed = {rid: desc for rid, desc in sorted(RULES.items())
                  if keep is None or rid.startswith(keep)}
        for rid, desc in listed.items():
            print(f"{rid}: {desc}")
        fams: dict = {}
        for rid in listed:
            fams[rid[:3]] = fams.get(rid[:3], 0) + 1
        print(f"{len(listed)} rule{'s' if len(listed) != 1 else ''}"
              + (" (" + ", ".join(f"{fam} {c}"
                                  for fam, c in sorted(fams.items()))
                 + ")" if fams else ""))
        return 0

    if not args.paths:
        ap.error("at least one PATH is required (or --list-rules)")
    if args.write_baseline and not args.baseline:
        ap.error("--write-baseline requires --baseline FILE")

    findings = run_paths(args.paths, rules=rules)

    baseline_path = Path(args.baseline) if args.baseline else None
    baseline_root = (baseline_path.resolve().parent
                     if baseline_path else None)
    if baseline_path and args.write_baseline:
        write_baseline(baseline_path, findings, root=baseline_root)
        print(f"repro.analysis: baseline written to {baseline_path} "
              f"({len(findings)} fingerprint"
              f"{'s' if len(findings) != 1 else ''})")
        return 0
    if baseline_path and baseline_path.exists():
        findings = apply_baseline(findings, load_baseline(baseline_path),
                                  root=baseline_root)

    keep = tuple(rules) if rules else None
    rule_ids = [rid for rid in RULES
                if keep is None or rid.startswith(keep)]
    if args.format == "sarif":
        print(json.dumps(to_sarif(findings, rule_ids), indent=1))
        print(_summary(findings), file=sys.stderr)
    elif args.format == "json":
        print(json.dumps(to_json(findings), indent=1))
        print(_summary(findings), file=sys.stderr)
    else:
        for f in findings:
            print(f.render())
        print(_summary(findings))
    return 1 if (findings and args.fail_on_warn) else 0


if __name__ == "__main__":
    sys.exit(main())
