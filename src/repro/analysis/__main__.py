"""CLI: ``python -m repro.analysis [--fail-on-warn] PATH...``.

Prints one ``path:line: RULE: message`` per finding (stable order), a
summary line, and exits 1 under ``--fail-on-warn`` when anything fired.
``--rules TRC`` restricts to rule-ID prefixes (comma separated).
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.common import RULES, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant lints for the repro serving stack "
                    "(trace purity, donation discipline, pytree "
                    "registration).")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to analyze")
    ap.add_argument("--fail-on-warn", action="store_true",
                    help="exit 1 if any finding is reported")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule-ID prefixes to keep "
                         "(e.g. 'TRC001,DON')")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}: {desc}")
        return 0

    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)
    findings = run_paths(args.paths, rules=rules)
    for f in findings:
        print(f.render())
    n = len(findings)
    print(f"repro.analysis: {n} finding{'s' if n != 1 else ''}")
    return 1 if (findings and args.fail_on_warn) else 0


if __name__ == "__main__":
    sys.exit(main())
