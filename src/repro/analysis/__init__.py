"""repro.analysis: invariant lints + runtime sanitizer for the serving stack.

Three AST/call-graph passes enforce contracts the paged serving stack
(PRs 3-5) relies on but no generic tool checks:

* trace-purity (TRC001/TRC002/TRC003): no eager pool operations, host
  ``np.*`` compute, environment reads, or host-state mutation reachable
  from inside a traced region (``jax.jit`` / ``shard_map`` / ``lax.cond``
  / ``lax.scan`` / ``vmap`` ...).
* donation-discipline (DON001/DON002): a pytree donated to a
  ``jax.jit(..., donate_argnums/donate_argnames)`` dispatch is dead after
  the call; values handed out by reference (prefix-cache hits, paged
  store gathers) must never be donated.
* pytree-registration (PYT001/PYT002): dataclasses constructed under
  trace must be registered pytrees, and registered aux/meta data must be
  hashable static metadata, never arrays.

Run ``python -m repro.analysis [--fail-on-warn] PATH...`` or call
:func:`run_paths` directly. Intentional eager/trace boundaries are
annotated in source with ``# analysis: allow(RULE)`` on the flagged line
or the line above.

The fourth component, :mod:`repro.analysis.sanitizer`, is a *runtime*
shadow allocator enabled by ``REPRO_SANITIZE=1`` (see its docstring); it
is imported lazily by ``repro.core.paged`` and never by the lint CLI.
"""
from repro.analysis.common import Finding, run_paths

__all__ = ["Finding", "run_paths"]
