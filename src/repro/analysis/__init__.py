"""repro.analysis: invariant lints + runtime sanitizer for the serving stack.

Six passes run as visitors over one shared analysis IR
(:mod:`repro.analysis.ir` — a single parse, symbol tables, the
jit/shard_map call graph with traced regions, per-function linear
dataflow facts) and enforce contracts the paged serving stack (PRs 3-8)
relies on but no generic tool checks:

* trace-purity (TRC001/TRC002/TRC003): no eager pool operations, host
  ``np.*`` compute, environment reads, or host-state mutation reachable
  from inside a traced region (``jax.jit`` / ``shard_map`` / ``lax.cond``
  / ``lax.scan`` / ``vmap`` ...).
* donation-discipline (DON001/DON002): a pytree donated to a
  ``jax.jit(..., donate_argnums/donate_argnames)`` dispatch is dead after
  the call; values handed out by reference (prefix-cache hits, paged
  store gathers) must never be donated.
* pytree-registration (PYT001/PYT002): dataclasses constructed under
  trace must be registered pytrees, and registered aux/meta data must be
  hashable static metadata, never arrays.
* sharding-discipline (SHD001/SHD002/SHD003): collectives only fire
  inside a ``shard_map``/``pmap`` whose mesh declares the named axis;
  thread-local mesh registries publish only with a guaranteed scoped
  reset; ``NamedSharding`` / ``pool_plane_spec`` axis names must exist
  on the mesh in scope.
* recompile-churn (CMP001/CMP002/CMP003): jit dispatches fed
  loop-varying shapes/static values (one executable per distinct
  value), dynamically built ``**kwargs`` reaching traced signatures,
  and data-dependent concretization (``.item()`` / ``int(computed)``)
  under trace.
* observability-purity (OBS001/OBS002): MetricsRegistry/Tracer calls
  must stay outside traced regions, and keyed tracer ``begin`` spans
  must pair with an ``end``/``discard`` somewhere on the analyzed
  engine paths.

Run ``python -m repro.analysis [--fail-on-warn] PATH...`` or call
:func:`run_paths` directly. ``--format json|sarif`` emits machine
output (SARIF 2.1.0 for CI annotation upload); ``--baseline FILE``
subtracts a reviewed baseline of line-hash fingerprints, letting the
gate extend over ``tests/`` and ``benchmarks/`` without freezing their
churn. Intentional boundaries are annotated in source with
``# analysis: allow(RULE)`` on the flagged line or the line above.

The runtime component, :mod:`repro.analysis.sanitizer`, is a *runtime*
shadow allocator enabled by ``REPRO_SANITIZE=1`` (see its docstring); it
is imported lazily by ``repro.core.paged`` and never by the lint CLI.
"""
from repro.analysis.common import Finding, run_paths

__all__ = ["Finding", "run_paths"]
