"""Recompile-churn pass: per-call-varying host values reaching jit.

Rules
-----
CMP001
    A jit dispatch handle is fed a Python scalar / shape that varies per
    call without a deliberate ``static_argnums`` story: a shape
    constructor (``jnp.zeros((1, w), ...)``) or a non-constant-width
    slice (``x[off:off + size]``) parameterized by a *loop-varying* name
    reaching a dispatch argument (directly or through a local
    assignment), or a declared-static argument fed a loop-varying value.
    Every distinct value traces a separate executable — the
    compile-inclusive cold-start soft spot. The message names the jit
    root and the churning argument; intentional warm ladders annotate
    with ``# analysis: allow(CMP001)``.
CMP002
    Dict/kwarg ordering instability reaching a traced signature:
    ``handle(**opts)`` where ``opts`` is not a dict display with literal
    keys. The traced signature (and therefore the executable cache key)
    then depends on a dynamically assembled key set — two call sites
    passing the "same" arguments through differently-built dicts compile
    twice, and a conditionally added key churns silently.
CMP003
    Data-dependent shape construction / concretization under trace:
    ``.item()`` / ``.tolist()``, or ``int(...)`` / ``float(...)`` over
    computed (non-shape) values inside a traced region. Under trace
    these either raise (``TracerError``) or bake a host value into the
    executable; when the value flows into a shape, every distinct value
    is a fresh compile. Shape-metadata reads (``jnp.shape`` / ``.shape``
    / ``np.prod``) are static at trace time and exempt.

All checks run over the shared IR: dispatch handles and their
static/donate declarations from :meth:`repro.analysis.ir.IR.handles`,
loop-varying names and assignment order from
:meth:`repro.analysis.ir.IR.facts`, traced membership from
:attr:`repro.analysis.ir.IR.member_regions`.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import callgraph as cg
from repro.analysis import ir
from repro.analysis.common import Finding
from repro.analysis.trace_purity import NP_TRACE_SAFE

#: constructors whose arguments are *shapes* — a varying scalar inside
#: means one executable per distinct value
SHAPE_CTORS = {"zeros", "ones", "full", "empty", "arange"}

#: call terminals that read static trace-time metadata (never churn)
_SHAPE_SAFE_CALLS = {"len", "shape", "ndim", "size"} | NP_TRACE_SAFE


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:                       # pragma: no cover - defensive
        return "<expr>"


def _loop_names_in(node: ast.AST, loop_vars: Set[str]) -> Set[str]:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
            and n.id in loop_vars}


def _bound_parts(node: Optional[ast.AST]) -> Tuple[str, int]:
    """Decompose a slice bound into (base expression, constant offset):
    ``i + 2`` -> ("i", 2), ``7`` -> ("", 7), anything else -> (text, 0).
    Two bounds with the same base have a constant width."""
    if node is None or (isinstance(node, ast.Constant)
                        and isinstance(node.value, int)):
        return "", getattr(node, "value", 0) if node is not None else 0
    if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                  (ast.Add, ast.Sub)) \
            and isinstance(node.right, ast.Constant) \
            and isinstance(node.right.value, int):
        off = node.right.value
        return _unparse(node.left), (off if isinstance(node.op, ast.Add)
                                     else -off)
    return _unparse(node), 0


def _slice_width_churn(sub: ast.Subscript,
                       loop_vars: Set[str]) -> Set[str]:
    """Loop-varying names the *width* of a slice depends on (constant
    widths like ``x[i:i + 1]`` / ``x[i + 1:i + 2]`` are churn-free even
    with varying ``i``)."""
    out: Set[str] = set()
    for sl in ast.walk(sub.slice):
        if not isinstance(sl, ast.Slice):
            continue
        lo, hi = sl.lower, sl.upper
        if hi is None:
            continue                        # open-ended: shape from base
        if _bound_parts(lo)[0] == _bound_parts(hi)[0]:
            continue                        # same base: constant width
        if lo is not None and isinstance(hi, ast.BinOp) \
                and isinstance(hi.op, ast.Add) \
                and _unparse(hi.left) == _unparse(lo):
            out |= _loop_names_in(hi.right, loop_vars)
            continue
        out |= _loop_names_in(lo, loop_vars) if lo is not None else set()
        out |= _loop_names_in(hi, loop_vars)
    return out
    # walking sub.slice (not sub) keeps base-expression names out


def _expr_churn(expr: ast.AST, loop_vars: Set[str],
                tainted: Dict[str, Set[str]]) -> Set[str]:
    """Loop-varying names whose value parameterizes a dispatch *shape*
    inside ``expr``: shape-constructor arguments, non-constant slice
    widths, and reads of locals already tainted by either."""
    out: Set[str] = set()
    for n in ast.walk(expr):
        if isinstance(n, ast.Call) \
                and cg.terminal_name(n.func) in SHAPE_CTORS:
            for a in list(n.args) + [kw.value for kw in n.keywords]:
                out |= _loop_names_in(a, loop_vars)
        elif isinstance(n, ast.Subscript):
            out |= _slice_width_churn(n, loop_vars)
        elif isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                and n.id in tainted:
            out |= tainted[n.id]
    return out


def run(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    findings += _check_dispatch_churn(an_ir)
    findings += _check_trace_concretization(an_ir)
    return findings


# --------------------------------------------------------------------------- #
# CMP001 + CMP002: per-handle call-site checks
# --------------------------------------------------------------------------- #
def _static_positions(spec: "ir.JitSpec") -> Tuple[Set[int], Set[str]]:
    nums = set(spec.static_argnums)
    names = set(spec.static_argnames)
    if spec.params:
        for pos, pname in enumerate(spec.params):
            if pname in names:
                nums.add(pos)
            if pos in spec.static_argnums:
                names.add(pname)
    return nums, names


def _check_dispatch_churn(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    for mi in an_ir.modules.values():
        table = an_ir.handles(mi)
        if not table:
            continue
        for fi in mi.functions.values():
            if not isinstance(fi.node, cg.FunctionNode):
                continue
            findings += _check_function(an_ir, mi, fi, table)
    return findings


def _check_function(an_ir: "ir.IR", mi: cg.ModuleInfo, fi: cg.FuncInfo,
                    table: "ir.HandleTable") -> List[Finding]:
    """Single ordered walk: taint state (locals carrying loop-varying
    shapes) evolves assignment by assignment, and each dispatch call is
    checked against the state *at its source position* — a taint acquired
    at line 40 never retro-flags a call on line 20."""
    facts = an_ir.facts(fi)
    loop_vars = facts.loop_vars
    local_aliases: Dict[str, "ir.JitSpec"] = {}
    tainted: Dict[str, Set[str]] = {}
    findings: List[Finding] = []
    checked: Set[int] = set()

    def check(call: ast.Call) -> None:
        if id(call) in checked or facts.in_nested(call.lineno):
            return
        checked.add(id(call))
        spec = table.resolve(fi, call.func, local_aliases)
        if spec is None:
            return
        static_nums, static_names = _static_positions(spec)
        root = (f"jit root '{spec.display}' "
                f"({mi.name}:{spec.site_line})")
        for pos, arg in enumerate(call.args):
            findings.extend(_check_arg(
                mi, call, arg, root, pos in static_nums,
                spec.params[pos] if spec.params
                and pos < len(spec.params) else f"arg {pos}",
                loop_vars, tainted))
        for kw in call.keywords:
            if kw.arg is None:
                findings.extend(_check_double_star(mi, call, kw.value,
                                                   root))
                continue
            findings.extend(_check_arg(mi, call, kw.value, root,
                                       kw.arg in static_names, kw.arg,
                                       loop_vars, tainted))

    spans = [(s.lineno, s.end_lineno or s.lineno)
             for s in facts.assignments]
    items: List[Tuple[int, int, str, ast.AST]] = [
        (s.lineno, s.col_offset, "assign", s) for s in facts.assignments]
    items += [(c.lineno, c.col_offset, "call", c) for c in facts.calls
              if not any(a <= c.lineno <= b for a, b in spans)]
    for _, _, kind, node in sorted(items, key=lambda it: it[:2]):
        if kind == "call":
            check(node)
            continue
        stmt = node
        # calls embedded in the assignment read the *pre*-store state
        for call in ast.walk(stmt):
            if isinstance(call, ast.Call):
                check(call)
        if isinstance(stmt, ast.AugAssign):
            continue
        value = stmt.value
        if value is None:
            continue
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        names = []
        for t in targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                if isinstance(el, ast.Name):
                    names.append(el.id)
        if not names:
            continue
        spec = table.alias_spec(value, fi, local_aliases)
        for n in names:
            if spec is not None:
                local_aliases[n] = spec
            else:
                local_aliases.pop(n, None)
        # a jit dispatch *result* has the executable's output shape — the
        # churning input is flagged at the call site itself, so the
        # result does not carry the taint forward
        if isinstance(value, ast.Call) \
                and table.resolve(fi, value.func, local_aliases) \
                is not None:
            churn: Set[str] = set()
        else:
            churn = _expr_churn(value, loop_vars, tainted)
        for n in names:
            if churn:
                tainted[n] = churn
            else:
                tainted.pop(n, None)
    return findings


def _check_arg(mi: cg.ModuleInfo, call: ast.Call, arg: ast.AST,
               root: str, is_static: bool, pname: str,
               loop_vars: Set[str],
               tainted: Dict[str, Set[str]]) -> List[Finding]:
    if is_static:
        churn = _loop_names_in(arg, loop_vars)
        if churn:
            return [Finding(
                mi.path, call.lineno, "CMP001",
                f"{root}: static argument '{pname}' is fed "
                f"loop-varying {sorted(churn)} — every distinct value "
                "recompiles; hoist the value or drop it from "
                "static_argnums")]
        return []
    churn = _expr_churn(arg, loop_vars, tainted)
    if churn:
        return [Finding(
            mi.path, call.lineno, "CMP001",
            f"{root}: argument '{pname}' carries a dispatch shape "
            f"built from loop-varying {sorted(churn)} — one executable "
            "per distinct extent; bucket the size, hoist it, or warm "
            "the ladder deliberately")]
    return []


def _check_double_star(mi: cg.ModuleInfo, call: ast.Call,
                       value: ast.AST, root: str) -> List[Finding]:
    if isinstance(value, ast.Dict) \
            and all(isinstance(k, ast.Constant)
                    and isinstance(k.value, str) for k in value.keys):
        return []                           # literal keys: stable order
    return [Finding(
        mi.path, call.lineno, "CMP002",
        f"{root}: '**{_unparse(value)}' expands a dynamically built "
        "dict into the traced signature — the executable cache keys on "
        "the keyword set, so a conditionally added or reordered key "
        "recompiles silently; pass explicit keywords or a dict display "
        "with literal keys")]


# --------------------------------------------------------------------------- #
# CMP003: concretization under trace
# --------------------------------------------------------------------------- #
def _has_unsafe_call(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            tname = cg.terminal_name(n.func)
            if tname not in _SHAPE_SAFE_CALLS:
                return True
    return False


def _check_trace_concretization(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    seen: Set[Tuple[str, int]] = set()
    for fi, regions in an_ir.member_regions.items():
        mi = fi.module
        region = regions[0]
        root = region.root
        where = (f"[traced via {root.wrapper} at "
                 f"{root.func.module.name}:{root.site_line}]")
        facts = an_ir.facts(fi)
        for call in facts.calls:
            key = (mi.path, call.lineno)
            if key in seen or facts.in_nested(call.lineno):
                continue
            tname = cg.terminal_name(call.func)
            if tname in ("item", "tolist") \
                    and isinstance(call.func, ast.Attribute):
                seen.add(key)
                findings.append(Finding(
                    mi.path, call.lineno, "CMP003",
                    f"'.{tname}()' concretizes "
                    f"'{_unparse(call.func.value)}' under trace "
                    f"{where}: it raises on tracers, and a host value "
                    "flowing into a shape recompiles per distinct "
                    "value; keep the value on-device or hoist the "
                    "read to the eager caller"))
            elif tname in ("int", "float") \
                    and isinstance(call.func, ast.Name) and call.args \
                    and _has_unsafe_call(call.args[0]):
                seen.add(key)
                findings.append(Finding(
                    mi.path, call.lineno, "CMP003",
                    f"'{tname}({_unparse(call.args[0])})' concretizes "
                    f"computed data under trace {where}: shape "
                    "construction from it is data-dependent — one "
                    "executable per value (or a TracerError); only "
                    "static metadata (jnp.shape / .shape / np.prod) "
                    "may be coerced at trace time"))
    return findings
