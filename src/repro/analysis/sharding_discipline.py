"""Sharding-discipline pass: collectives, mesh registries, axis names.

Rules
-----
SHD001
    A collective (``lax.psum`` / ``pmax`` / ``axis_index`` / ...) is
    reachable from a traced region but from no ``shard_map``-rooted one:
    outside ``shard_map`` (or ``pmap``) there is no named axis to reduce
    over, so the dispatch fails at trace time — or silently reduces over
    the wrong axis if an outer transform happens to bind the name. Also
    fires when the collective names a literal axis that the binding
    ``shard_map``'s mesh (resolvable literal ``Mesh(..., ("a", ...))``)
    does not declare.
SHD002
    A thread-local registry attribute (``X = threading.local()`` at
    module level; ``X.attr = ...`` anywhere) is published without a
    guaranteed scoped reset. The approved shape is a ``@contextmanager``
    whose ``try``/``finally`` restores the previous value — anything
    else leaves the registry armed for the next (possibly unsharded)
    engine in the process when a dispatch raises mid-flight
    (``kernels/pool_mesh.py`` is the canonical instance).
SHD003
    ``NamedSharding(mesh, P(...))`` / ``pool_plane_spec(mesh, ...,
    axis=...)`` constructed with a literal axis name absent from a mesh
    whose axis names are resolvable in the same function (a literal
    ``Mesh(devices, ("data", "model"))`` binding): GSPMD rejects the
    spec at placement time, far from the typo.

All three stay intra-procedural over the shared IR; unresolvable meshes
and non-literal axis names simply end the check (the safe direction).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import callgraph as cg
from repro.analysis import ir
from repro.analysis.common import Finding

#: named-axis collectives (jax.lax.*) that require a bound axis name
COLLECTIVES = {
    "psum", "pmax", "pmin", "pmean", "all_gather", "all_to_all",
    "ppermute", "pshuffle", "axis_index", "psum_scatter", "axis_size",
}

#: wrappers that bind named axes — membership in one of their regions
#: legalizes a collective
_AXIS_BINDING_WRAPPERS = {"shard_map", "pmap"}


def _is_collective(mi: cg.ModuleInfo, call: ast.Call) -> Optional[str]:
    """Collective name if ``call`` invokes a jax.lax collective."""
    chain = cg.attr_chain(call.func)
    if chain is None or chain[-1] not in COLLECTIVES:
        return None
    name = chain[-1]
    if len(chain) == 1:
        src = mi.from_imports.get(name)
        if src is not None and src[0].endswith("lax"):
            return name
        return None
    target = mi.module_alias_target(chain[0])
    prefix = ".".join(([target] if target else [chain[0]]) + chain[1:-1])
    if prefix.endswith("lax") and (prefix.startswith("jax")
                                   or prefix == "lax"):
        return name
    return None


def _collective_axes(call: ast.Call, name: str) -> Set[str]:
    """Literal axis names the collective references (empty when the axis
    expression is dynamic)."""
    nodes: List[ast.AST] = []
    if name == "axis_index":
        if call.args:
            nodes.append(call.args[0])
    elif len(call.args) >= 2:
        nodes.append(call.args[1])
    for kw in call.keywords:
        if kw.arg in ("axis_name", "axis"):
            nodes.append(kw.value)
    out: Set[str] = set()
    for n in nodes:
        for el in (n.elts if isinstance(n, (ast.Tuple, ast.List))
                   else [n]):
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.add(el.value)
    return out


def _mesh_axes_by_name(fi: cg.FuncInfo) -> Dict[str, Set[str]]:
    """Local ``name -> declared axis names`` for literal mesh bindings:
    ``m = Mesh(devs, ("data", "model"))`` / ``axis_names=(...)`` /
    ``jax.make_mesh((2, 4), ("data", "model"))``."""
    out: Dict[str, Set[str]] = {}
    for stmt in ast.walk(fi.node):
        if not isinstance(stmt, ast.Assign) \
                or not isinstance(stmt.value, ast.Call):
            continue
        call = stmt.value
        tname = cg.terminal_name(call.func)
        axes_node: Optional[ast.AST] = None
        if tname in ("Mesh", "make_mesh") and len(call.args) >= 2:
            axes_node = call.args[1]
        for kw in call.keywords:
            if kw.arg == "axis_names":
                axes_node = kw.value
        if axes_node is None or tname not in ("Mesh", "make_mesh"):
            continue
        axes: Set[str] = set()
        if isinstance(axes_node, ast.Constant) \
                and isinstance(axes_node.value, str):
            axes = {axes_node.value}
        elif isinstance(axes_node, (ast.Tuple, ast.List)):
            for el in axes_node.elts:
                if not (isinstance(el, ast.Constant)
                        and isinstance(el.value, str)):
                    axes = set()
                    break
                axes.add(el.value)
        if not axes:
            continue
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                out[t.id] = axes
    return out


def run(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    findings += _check_collectives(an_ir)
    findings += _check_tls_registries(an_ir)
    findings += _check_axis_names(an_ir)
    return findings


# --------------------------------------------------------------------------- #
# SHD001
# --------------------------------------------------------------------------- #
def _axis_binding_members(an_ir: "ir.IR") -> Set[cg.FuncInfo]:
    out: Set[cg.FuncInfo] = set()
    for region in an_ir.regions:
        if region.root.wrapper in _AXIS_BINDING_WRAPPERS:
            out.update(region.members)
    return out


def _declared_axes_for(an_ir: "ir.IR",
                       fi: cg.FuncInfo) -> Optional[Set[str]]:
    """Union of literal mesh axes over every shard_map site whose region
    contains ``fi``; None when any binding mesh is unresolvable."""
    axes: Set[str] = set()
    for region in an_ir.regions:
        if region.root.wrapper not in _AXIS_BINDING_WRAPPERS \
                or fi not in region.members:
            continue
        site = _shard_map_site(an_ir, region)
        if site is None:
            return None
        site_axes = _site_mesh_axes(an_ir, *site)
        if site_axes is None:
            return None
        axes |= site_axes
    return axes


def _shard_map_site(an_ir: "ir.IR", region: cg.Region
                    ) -> Optional[Tuple[cg.FuncInfo, ast.Call]]:
    """(enclosing function, shard_map Call) of a region's root site."""
    mi = region.root.func.module
    for fi in mi.functions.values():
        if not isinstance(fi.node, cg.FunctionNode):
            continue
        for call in an_ir.facts(fi).calls:
            hit = an_ir.index.jax_wrapper(mi, call)
            if hit is not None and hit[0] == "shard_map" \
                    and call.lineno == region.root.site_line:
                return fi, call
    return None


def _site_mesh_axes(an_ir: "ir.IR", fi: cg.FuncInfo,
                    call: ast.Call) -> Optional[Set[str]]:
    mesh_expr: Optional[ast.AST] = None
    for kw in call.keywords:
        if kw.arg == "mesh":
            mesh_expr = kw.value
    if mesh_expr is None and len(call.args) >= 2:
        mesh_expr = call.args[1]
    if not isinstance(mesh_expr, ast.Name):
        return None
    return _mesh_axes_by_name(fi).get(mesh_expr.id)


def _check_collectives(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    bound = _axis_binding_members(an_ir)
    seen: Set[Tuple[str, int]] = set()
    for fi, regions in an_ir.member_regions.items():
        mi = fi.module
        facts = an_ir.facts(fi)
        for call in facts.calls:
            name = _is_collective(mi, call)
            if name is None:
                continue
            if facts.in_nested(call.lineno):
                # the call belongs to a nested def (e.g. a shard_map
                # body) — that scope's own FuncInfo carries the check
                continue
            key = (mi.path, call.lineno)
            if key in seen:
                continue
            if fi not in bound:
                region = regions[0]
                seen.add(key)
                findings.append(Finding(
                    mi.path, call.lineno, "SHD001",
                    f"collective '{name}' reachable from a traced "
                    f"region (via {region.root.wrapper} at "
                    f"{region.root.func.module.name}:"
                    f"{region.root.site_line}) but from no shard_map: "
                    "there is no bound mesh axis to reduce over — move "
                    "the collective inside the shard_map body or route "
                    "this path through the sharded dispatcher"))
                continue
            declared = _declared_axes_for(an_ir, fi)
            if declared is None:
                continue                    # mesh not statically known
            missing = _collective_axes(call, name) - declared
            if missing:
                seen.add(key)
                findings.append(Finding(
                    mi.path, call.lineno, "SHD001",
                    f"collective '{name}' references axis "
                    f"{sorted(missing)} but the binding shard_map's "
                    f"mesh only declares {sorted(declared)}: the "
                    "dispatch fails at trace time (unbound axis name)"))
    return findings


# --------------------------------------------------------------------------- #
# SHD002
# --------------------------------------------------------------------------- #
def _tls_names(mi: cg.ModuleInfo) -> Set[str]:
    """Module-level names bound to ``threading.local()`` instances."""
    out: Set[str] = set()
    for stmt in mi.tree.body:
        if not isinstance(stmt, ast.Assign) \
                or not isinstance(stmt.value, ast.Call):
            continue
        chain = cg.attr_chain(stmt.value.func)
        if chain is None:
            continue
        is_local = (chain == ["threading", "local"]
                    and mi.module_alias_target("threading") == "threading")
        if not is_local and len(chain) == 1:
            src = mi.from_imports.get(chain[0])
            is_local = (src is not None and src == ("threading", "local"))
        if not is_local:
            continue
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                out.add(t.id)
    return out


def _is_contextmanager(fi: cg.FuncInfo) -> bool:
    if not isinstance(fi.node, cg.FunctionNode):
        return False
    for dec in fi.node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if cg.terminal_name(target) in ("contextmanager",
                                        "asynccontextmanager"):
            return True
    return False


def _line_spans(nodes: List[ast.stmt]) -> List[Tuple[int, int]]:
    return [(n.lineno, n.end_lineno or n.lineno) for n in nodes]


def _guarded_spans(fi: cg.FuncInfo, tls: str,
                   attr: str) -> List[Tuple[int, int]]:
    """Line spans in which a publication of ``tls.attr`` is reset-safe:
    ``finally`` (and ``except``) bodies, plus — inside a contextmanager —
    the whole function when some ``try`` holds the ``yield`` and its
    ``finally`` restores the same attribute."""
    spans: List[Tuple[int, int]] = []
    cm = _is_contextmanager(fi)
    for t in ast.walk(fi.node):
        if not isinstance(t, ast.Try):
            continue
        spans += _line_spans(t.finalbody)
        for h in t.handlers:
            spans += _line_spans(h.body)
        if not cm or not t.finalbody:
            continue
        has_yield = any(isinstance(n, (ast.Yield, ast.YieldFrom))
                        for b in t.body for n in ast.walk(b))
        restores = any(
            isinstance(n, ast.Assign)
            and any(cg.attr_chain(tg) == [tls, attr] for tg in n.targets)
            for b in t.finalbody for n in ast.walk(b))
        if has_yield and restores:
            spans.append((fi.node.lineno,
                          fi.node.end_lineno or fi.node.lineno))
    return spans


def _check_tls_registries(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    for mi in an_ir.modules.values():
        tls = _tls_names(mi)
        if not tls:
            continue
        for fi in mi.functions.values():
            if not isinstance(fi.node, cg.FunctionNode):
                continue
            for stmt in ast.walk(fi.node):
                if not isinstance(stmt, (ast.Assign, ast.AugAssign)):
                    continue
                targets = stmt.targets if isinstance(stmt, ast.Assign) \
                    else [stmt.target]
                for t in targets:
                    chain = cg.attr_chain(t)
                    if chain is None or len(chain) != 2 \
                            or chain[0] not in tls:
                        continue
                    guarded = _guarded_spans(fi, chain[0], chain[1])
                    if any(a <= stmt.lineno <= b for a, b in guarded):
                        continue
                    findings.append(Finding(
                        mi.path, stmt.lineno, "SHD002",
                        f"thread-local registry '{chain[0]}."
                        f"{chain[1]}' published without a guaranteed "
                        "scoped reset: a raise mid-dispatch leaves it "
                        "armed for the next (possibly unsharded) "
                        "engine in the process — publish through a "
                        "@contextmanager whose try/finally restores "
                        "the previous value"))
        # module-level publications are never scoped
        for stmt in mi.tree.body:
            if not isinstance(stmt, ast.Assign):
                continue
            for t in stmt.targets:
                chain = cg.attr_chain(t)
                if chain is not None and len(chain) == 2 \
                        and chain[0] in tls:
                    findings.append(Finding(
                        mi.path, stmt.lineno, "SHD002",
                        f"thread-local registry '{chain[0]}."
                        f"{chain[1]}' armed at import time: module-"
                        "level publication can never be reset by a "
                        "scope exit"))
    return findings


# --------------------------------------------------------------------------- #
# SHD003
# --------------------------------------------------------------------------- #
def _partition_spec_axes(call: ast.Call) -> Set[str]:
    """Literal axis names inside ``P(...)`` / ``PartitionSpec(...)``."""
    out: Set[str] = set()
    for a in call.args:
        for el in (a.elts if isinstance(a, (ast.Tuple, ast.List))
                   else [a]):
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.add(el.value)
    return out


def _check_axis_names(an_ir: "ir.IR") -> List[Finding]:
    findings: List[Finding] = []
    for mi in an_ir.modules.values():
        for fi in mi.functions.values():
            if not isinstance(fi.node, cg.FunctionNode):
                continue
            meshes = _mesh_axes_by_name(fi)
            if not meshes:
                continue
            for call in an_ir.facts(fi).calls:
                tname = cg.terminal_name(call.func)
                if tname == "NamedSharding" and len(call.args) >= 2 \
                        and isinstance(call.args[0], ast.Name):
                    declared = meshes.get(call.args[0].id)
                    spec = call.args[1]
                    if declared is None \
                            or not isinstance(spec, ast.Call) \
                            or cg.terminal_name(spec.func) not in (
                                "P", "PartitionSpec"):
                        continue
                    missing = _partition_spec_axes(spec) - declared
                    if missing:
                        findings.append(Finding(
                            mi.path, call.lineno, "SHD003",
                            f"NamedSharding over mesh "
                            f"'{call.args[0].id}' names axis "
                            f"{sorted(missing)} but the mesh only "
                            f"declares {sorted(declared)}: GSPMD "
                            "rejects the spec at placement time"))
                elif tname in ("pool_plane_spec", "paged_pool_mesh_spec") \
                        and call.args \
                        and isinstance(call.args[0], ast.Name):
                    declared = meshes.get(call.args[0].id)
                    if declared is None:
                        continue
                    for kw in call.keywords:
                        if kw.arg == "axis" \
                                and isinstance(kw.value, ast.Constant) \
                                and isinstance(kw.value.value, str) \
                                and kw.value.value not in declared:
                            findings.append(Finding(
                                mi.path, call.lineno, "SHD003",
                                f"{tname}(..., axis="
                                f"'{kw.value.value}') but mesh "
                                f"'{call.args[0].id}' only declares "
                                f"{sorted(declared)}: the plane spec "
                                "can never bind"))
    return findings
