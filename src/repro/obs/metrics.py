"""Low-overhead serving metrics: counters, gauges, fixed-bucket histograms.

The registry is the single publication point for the serving stack's
telemetry: the engine, scheduler, prefix cache, paged pool and speculative
decoder all resolve their instruments once (at construction) and then
increment plain Python floats on the hot path — no locks, no string
formatting, no allocation per event. Everything is host-side; this module
deliberately imports no jax/numpy so nothing here can ever end up under a
trace.

Three instrument kinds, mirroring the Prometheus data model:

* :class:`Counter` — monotone ``inc(v)``,
* :class:`Gauge` — ``set/inc/dec``, plus registry-level *callback* gauges
  (:meth:`MetricsRegistry.gauge_fn`) sampled lazily at snapshot time so
  expensive values (pool utilization scans) cost nothing per step,
* :class:`Histogram` — fixed upper-bound buckets (+Inf implicit),
  cumulative counts, ``sum``/``count``, and a bucket-interpolated
  :meth:`Histogram.percentile` estimate.

Instruments are grouped into *families* keyed by metric name; a family
with ``labels=(...)`` vends children via ``family.labels(v1, ...)``.
Label-less families proxy the instrument API directly, so
``registry.counter("x").inc()`` just works.

Exports: :meth:`MetricsRegistry.snapshot` (plain dict),
:meth:`MetricsRegistry.to_prometheus` (text exposition format) and
:meth:`MetricsRegistry.to_json`.

The default everywhere is :data:`NULL_REGISTRY` — a no-op registry whose
instruments swallow every call, so metrics-off serving pays only the
no-op method dispatch (and code can gate costlier sampling on
``registry.enabled``).
"""
from __future__ import annotations

import bisect
import json
from typing import Callable, Dict, List, Optional, Sequence, Tuple

#: default latency buckets (seconds): 1ms .. 60s, roughly log-spaced
DEFAULT_LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                           0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

#: buckets for signed slack values (seconds before/after a deadline)
DEFAULT_SLACK_BUCKETS = (-30.0, -5.0, -1.0, -0.1, 0.0, 0.1, 0.5, 1.0,
                         5.0, 30.0)


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counters only go up (inc by {v!r})")
        self.value += v


class Gauge:
    """Point-in-time value (set/inc/dec)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def dec(self, v: float = 1.0) -> None:
        self.value -= v


class Histogram:
    """Fixed-bucket histogram: cumulative bucket counts, sum and count.

    ``buckets`` are inclusive upper bounds in increasing order; an +Inf
    bucket is implicit. ``observe`` is O(log n_buckets) (bisect), no
    allocation.
    """

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(
                f"histogram buckets must be non-empty and strictly "
                f"increasing, got {buckets!r}")
        self.buckets = b
        self.counts = [0] * (len(b) + 1)    # last = +Inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def percentile(self, q: float) -> float:
        """Bucket-interpolated percentile estimate (``q`` in [0, 100]).

        Exact percentiles need the raw samples (callers that report SLO
        numbers keep those themselves); this is the cheap registry-side
        estimate: linear interpolation within the bucket containing the
        target rank, with the overflow bucket clamped to its lower bound.
        """
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile must be in [0, 100], got {q!r}")
        if self.count == 0:
            return 0.0
        rank = q / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= rank and c > 0:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                if i >= len(self.buckets):      # overflow bucket
                    return self.buckets[-1]
                hi = self.buckets[i]
                frac = (rank - seen) / c
                return lo + (hi - lo) * min(1.0, max(0.0, frac))
            seen += c
        return self.buckets[-1]


class _NullInstrument:
    """Shared no-op child: absorbs the whole instrument API."""

    __slots__ = ()

    def inc(self, v: float = 1.0) -> None:
        pass

    def dec(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def labels(self, *values: str) -> "_NullInstrument":
        return self

    def percentile(self, q: float) -> float:
        return 0.0


NULL_INSTRUMENT = _NullInstrument()


class Family:
    """One named metric: a set of children keyed by label values.

    Label-less families proxy the child API directly (the single child at
    the empty label tuple is created eagerly), so call sites never need to
    distinguish the two shapes.
    """

    __slots__ = ("name", "type", "help", "labelnames", "children",
                 "_solo", "_make")

    def __init__(self, name: str, kind: str, help: str,
                 labelnames: Tuple[str, ...], make: Callable):
        self.name = name
        self.type = kind
        self.help = help
        self.labelnames = labelnames
        self._make = make
        self.children: Dict[Tuple[str, ...], object] = {}
        self._solo = self.labels() if not labelnames else None

    def labels(self, *values: str):
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} takes {len(self.labelnames)} label "
                f"value(s) {self.labelnames}, got {values!r}")
        key = tuple(str(v) for v in values)
        child = self.children.get(key)
        if child is None:
            child = self.children[key] = self._make()
        return child

    # -- label-less proxying ------------------------------------------- #
    def _only(self):
        if self._solo is None:
            raise ValueError(
                f"metric {self.name!r} has labels {self.labelnames}; "
                "resolve a child with .labels(...) first")
        return self._solo

    def inc(self, v: float = 1.0) -> None:
        self._only().inc(v)

    def dec(self, v: float = 1.0) -> None:
        self._only().dec(v)

    def set(self, v: float) -> None:
        self._only().set(v)

    def observe(self, v: float) -> None:
        self._only().observe(v)

    def percentile(self, q: float) -> float:
        return self._only().percentile(q)

    @property
    def value(self) -> float:
        return self._only().value       # type: ignore[union-attr]

    @property
    def count(self) -> int:
        return self._only().count       # type: ignore[union-attr]

    @property
    def sum(self) -> float:
        return self._only().sum         # type: ignore[union-attr]


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


class MetricsRegistry:
    """Publication point and exporter for a set of metric families.

    ``counter/gauge/histogram`` are idempotent by name: the first call
    defines the family (type, help, labels); later calls return it (and
    raise on a conflicting redefinition), so independent components —
    engine, prefix cache, pool, speculative decoder — can resolve the
    same registry without coordination.
    """

    enabled = True

    def __init__(self):
        self._families: Dict[str, Family] = {}
        self._gauge_fns: Dict[str, Tuple[str, Callable[[], float]]] = {}

    # ------------------------------------------------------------------ #
    # Instrument definition
    # ------------------------------------------------------------------ #
    def _family(self, name: str, kind: str, help: str,
                labels: Sequence[str], make: Callable) -> Family:
        fam = self._families.get(name)
        if fam is not None:
            if fam.type != kind or fam.labelnames != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already registered as {fam.type} "
                    f"with labels {fam.labelnames}; cannot redefine as "
                    f"{kind} with labels {tuple(labels)}")
            return fam
        fam = Family(name, kind, help, tuple(labels), make)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._family(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._family(name, "gauge", help, labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
                  labels: Sequence[str] = ()) -> Family:
        b = tuple(buckets)
        return self._family(name, "histogram", help, labels,
                            lambda: Histogram(b))

    def gauge_fn(self, name: str, fn: Callable[[], float],
                 help: str = "") -> None:
        """Register a callback gauge sampled at snapshot time only — for
        values that are cheap to describe but costly to compute per step
        (pool utilization, queue depth). Re-registering a name replaces
        the callback (latest engine wins)."""
        self._gauge_fns[name] = (help, fn)

    # ------------------------------------------------------------------ #
    # Reads / export
    # ------------------------------------------------------------------ #
    def value(self, name: str, *labels: str) -> float:
        """Current value of a counter/gauge child (test/report helper)."""
        child = self._families[name].labels(*labels)
        return child.value          # type: ignore[union-attr]

    def get(self, name: str, *labels: str):
        """The raw instrument child (e.g. a Histogram for percentiles)."""
        return self._families[name].labels(*labels)

    def _sampled_gauges(self) -> List[Tuple[str, str, float]]:
        out = []
        for name, (help, fn) in sorted(self._gauge_fns.items()):
            try:
                out.append((name, help, float(fn())))
            except Exception:       # a dead provider must not kill export
                continue
        return out

    def snapshot(self) -> Dict[str, dict]:
        """Plain-dict view of every family (callback gauges sampled now)."""
        out: Dict[str, dict] = {}
        for name, fam in sorted(self._families.items()):
            vals = []
            for key, child in sorted(fam.children.items()):
                lab = dict(zip(fam.labelnames, key))
                if fam.type == "histogram":
                    h: Histogram = child       # type: ignore[assignment]
                    cum, acc = [], 0
                    for le, c in zip(h.buckets + (float("inf"),), h.counts):
                        acc += c
                        cum.append([le, acc])
                    vals.append({"labels": lab, "buckets": cum,
                                 "sum": h.sum, "count": h.count})
                else:
                    vals.append({"labels": lab,
                                 "value": child.value})  # type: ignore
            out[name] = {"type": fam.type, "help": fam.help,
                         "values": vals}
        for name, help, v in self._sampled_gauges():
            out[name] = {"type": "gauge", "help": help,
                         "values": [{"labels": {}, "value": v}]}
        return out

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines: List[str] = []
        for name, fam in sorted(self._families.items()):
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.type}")
            for key, child in sorted(fam.children.items()):
                base = ",".join(f'{ln}="{_escape(lv)}"'
                                for ln, lv in zip(fam.labelnames, key))
                if fam.type == "histogram":
                    h: Histogram = child       # type: ignore[assignment]
                    acc = 0
                    for le, c in zip(h.buckets + (float("inf"),), h.counts):
                        acc += c
                        le_s = "+Inf" if le == float("inf") else _fmt(le)
                        sep = "," if base else ""
                        lines.append(f'{name}_bucket{{{base}{sep}'
                                     f'le="{le_s}"}} {acc}')
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(f"{name}_sum{suffix} {_fmt(h.sum)}")
                    lines.append(f"{name}_count{suffix} {h.count}")
                else:
                    suffix = f"{{{base}}}" if base else ""
                    lines.append(
                        f"{name}{suffix} "
                        f"{_fmt(child.value)}")    # type: ignore[union-attr]
        for name, help, v in self._sampled_gauges():
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {_fmt(v)}")
        return "\n".join(lines) + ("\n" if lines else "")


class NullRegistry(MetricsRegistry):
    """No-op registry: every instrument is the shared null child, exports
    are empty. This is the engine default — metrics-off serving never
    builds a real instrument and call sites can skip costlier sampling by
    checking ``registry.enabled``."""

    enabled = False

    def counter(self, name, help="", labels=()):
        return NULL_INSTRUMENT

    def gauge(self, name, help="", labels=()):
        return NULL_INSTRUMENT

    def histogram(self, name, help="", buckets=DEFAULT_LATENCY_BUCKETS,
                  labels=()):
        return NULL_INSTRUMENT

    def gauge_fn(self, name, fn, help=""):
        pass

    def value(self, name, *labels):
        raise KeyError(f"null registry records nothing ({name!r})")

    def get(self, name, *labels):
        return NULL_INSTRUMENT


NULL_REGISTRY = NullRegistry()
