"""Engine observability: metrics registry + request-lifecycle tracing.

Everything in this package is strictly **host-side** (pure Python over
plain floats/dicts — no jax imports anywhere): instrumentation must never
leak into a traced region, and with the default no-op registry/tracer the
serving hot path pays nothing beyond a handful of no-op method calls.

* :mod:`repro.obs.metrics` — counters, gauges and fixed-bucket histograms
  with labels; snapshot-to-dict, Prometheus text exposition and JSON
  export. ``NULL_REGISTRY`` is the engine default.
* :mod:`repro.obs.trace` — request-lifecycle spans (submit -> admit ->
  prefill -> decode ticks -> retire, plus preempt/resume and speculative
  waves) exported as Chrome/Perfetto ``trace_event`` JSON.
"""
from repro.obs.metrics import (MetricsRegistry, NullRegistry,  # noqa: F401
                               NULL_REGISTRY)
from repro.obs.trace import Tracer, NullTracer, NULL_TRACER    # noqa: F401
