"""Request-lifecycle tracing as Chrome/Perfetto ``trace_event`` JSON.

The tracer records host-side spans over the serving engine's request
lifecycle — submit -> admit -> prefill -> decode ticks -> retire, plus
preempt/resume handoffs and speculative waves — and exports them in the
Chrome tracing format (the JSON ``traceEvents`` array), which both
``chrome://tracing`` and https://ui.perfetto.dev load directly.

Layout convention used by the engine:

* ``pid`` 0 is the whole engine process.
* ``tid`` 0 is the *engine* row: ``step``/``prefill``/``spec_wave``
  spans and scheduler instants live here.
* ``tid`` ``request_id + 1`` is one row per request: its ``queued`` span
  (submit -> admit), ``running`` span(s) (admit -> retire, split around
  preemptions), per-tick instants and the terminal status.

Spans that start and end in different engine calls use the *keyed* API —
``begin(key, name, tid)`` … ``end(key, **args)`` — so the engine never
holds timestamps itself; short same-frame sections can use the
:meth:`Tracer.span` context manager. All events carry microsecond
timestamps relative to the tracer's construction (or the injected
``clock``, which the simulated-clock load harness uses so traces line up
with its virtual time).

Like the metrics registry, this module is strictly host-side and imports
no jax; the engine default is :data:`NULL_TRACER`, whose methods are all
no-ops, so tracing-off serving pays nothing.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional, Tuple


class Tracer:
    """Bounded in-memory recorder of Chrome ``trace_event`` dicts.

    ``clock`` is any zero-arg callable returning seconds (monotonic or
    simulated); timestamps are stored in microseconds relative to the
    first reading. ``max_events`` bounds memory on long runs — once full,
    new events are counted in :attr:`dropped` instead of stored (begin/
    end bookkeeping still happens, so spans that *end* before the limit
    is hit are never truncated mid-flight).
    """

    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 max_events: int = 200_000):
        self._clock = clock if clock is not None else time.perf_counter
        self._t0 = self._clock()
        self._events: List[dict] = []
        self._open: Dict[object, Tuple[float, str, int, dict]] = {}
        self._names: Dict[int, str] = {}
        self.max_events = int(max_events)
        self.dropped = 0

    # ------------------------------------------------------------------ #
    def now(self) -> float:
        """Seconds since tracer start (same clock the events use)."""
        return self._clock() - self._t0

    def _emit(self, ev: dict) -> None:
        if len(self._events) >= self.max_events:
            self.dropped += 1
            return
        self._events.append(ev)

    def thread_name(self, tid: int, name: str) -> None:
        """Label a row (Perfetto shows this instead of the raw tid)."""
        if self._names.get(tid) == name:
            return
        self._names[tid] = name
        self._emit({"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                    "args": {"name": name}})

    # -- keyed spans (start/end in different engine calls) ------------- #
    def begin(self, key: object, name: str, tid: int = 0,
              **args: object) -> None:
        """Open a span under ``key``; a later :meth:`end` closes it.

        Re-beginning a live key silently replaces it (the half-open span
        is dropped) so engine restarts can't poison the table.
        """
        self._open[key] = (self.now(), name, tid, dict(args))

    def end(self, key: object, **args: object) -> None:
        """Close the span opened under ``key`` (no-op if absent)."""
        opened = self._open.pop(key, None)
        if opened is None:
            return
        t0, name, tid, a0 = opened
        if args:
            a0.update(args)
        dur = max(0.0, self.now() - t0)
        ev = {"ph": "X", "name": name, "pid": 0, "tid": tid,
              "ts": t0 * 1e6, "dur": dur * 1e6}
        if a0:
            ev["args"] = a0
        self._emit(ev)

    def discard(self, key: object) -> None:
        """Forget a half-open span without emitting it."""
        self._open.pop(key, None)

    # -- same-frame helpers -------------------------------------------- #
    def span(self, name: str, tid: int = 0, **args: object):
        """Context manager for a span contained in one engine call."""
        return _Span(self, name, tid, args)

    def instant(self, name: str, tid: int = 0, **args: object) -> None:
        ev = {"ph": "i", "name": name, "pid": 0, "tid": tid,
              "ts": self.now() * 1e6, "s": "t"}
        if args:
            ev["args"] = args
        self._emit(ev)

    # -- export --------------------------------------------------------- #
    def to_dict(self) -> dict:
        """Chrome tracing JSON object (half-open spans flushed as-is)."""
        tail = []
        now = self.now()
        for t0, name, tid, a0 in self._open.values():
            ev = {"ph": "X", "name": name, "pid": 0, "tid": tid,
                  "ts": t0 * 1e6, "dur": max(0.0, now - t0) * 1e6}
            a = dict(a0, unfinished=True)
            ev["args"] = a
            tail.append(ev)
        return {"traceEvents": self._events + tail,
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the trace JSON to ``path``; returns the event count."""
        d = self.to_dict()
        with open(path, "w") as f:
            json.dump(d, f)
        return len(d["traceEvents"])

    def __len__(self) -> int:
        return len(self._events)


class _Span:
    __slots__ = ("_tr", "_name", "_tid", "_args", "_t0")

    def __init__(self, tr: Tracer, name: str, tid: int, args: dict):
        self._tr = tr
        self._name = name
        self._tid = tid
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tr.now()
        return self

    def __exit__(self, *exc) -> None:
        dur = max(0.0, self._tr.now() - self._t0)
        ev = {"ph": "X", "name": self._name, "pid": 0, "tid": self._tid,
              "ts": self._t0 * 1e6, "dur": dur * 1e6}
        if self._args:
            ev["args"] = self._args
        self._tr._emit(ev)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL_SPAN = _NullSpan()


class NullTracer(Tracer):
    """No-op tracer: records nothing, exports an empty trace."""

    enabled = False

    def __init__(self):                     # no clock reads at all
        self._events = []
        self._open = {}
        self.max_events = 0
        self.dropped = 0

    def now(self) -> float:
        return 0.0

    def thread_name(self, tid, name):
        pass

    def begin(self, key, name, tid=0, **args):
        pass

    def end(self, key, **args):
        pass

    def discard(self, key):
        pass

    def span(self, name, tid=0, **args):
        return _NULL_SPAN

    def instant(self, name, tid=0, **args):
        pass

    def to_dict(self):
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_TRACER = NullTracer()
