"""Serve a long-context request mix under different eviction policies and
compare quality/memory/latency — the paper's serving story in one script.

Uses the request-level API: each client request has its own prompt length,
token budget and sampling params; the engine admits them into batch slots
continuously (Engine.submit / Engine.run) instead of lockstep batches.

  PYTHONPATH=src python examples/serve_longcontext.py [--ctx 600] [--budget 96]
"""
import argparse
import time

import numpy as np

from benchmarks.common import bench_model, corpus, with_policy
from repro.core.policy import get_policy, policy_names
from repro.serving.engine import Engine, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ctx", type=int, default=600)
    ap.add_argument("--budget", type=int, default=96)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg, params = bench_model()   # trains once, then cached
    co = corpus()
    toks = np.stack([co.stream(args.ctx, seed=100 + i)
                     for i in range(args.batch)])

    # 1) policy quality/memory sweep (streaming teacher-forced scoring)
    print(f"{'policy':12s}{'budget':>8s}{'ppl':>9s}{'cacheMB':>9s}{'s/100tok':>10s}")
    for policy in policy_names():
        budget = args.budget if get_policy(policy).evicts else args.ctx
        c = with_policy(cfg, policy, budget)
        eng = Engine(c, params, budget=budget)
        t0 = time.perf_counter()
        if get_policy(policy).needs_scores:
            # score-based policies need per-step attention probabilities
            # (observe); only the token-by-token decode path produces them
            nll = eng.score_stream(toks)
        else:
            nll = eng.score_stream_chunked(toks)
        dt = (time.perf_counter() - t0) / (args.ctx * args.batch) * 100
        ppl = float(np.exp(nll.mean()))
        mb = eng.cache_bytes(eng.new_state(args.batch)) / 1e6
        print(f"{policy:12s}{budget:>8d}{ppl:>9.3f}{mb:>9.2f}{dt:>10.3f}")

    # 2) mixed-length request serving under LaCache (continuous batching)
    c = with_policy(cfg, "lacache", args.budget)
    eng = Engine(c, params, budget=args.budget, max_batch=max(2, args.batch // 2))
    for i in range(args.batch):
        plen = args.ctx // (1 + i % 3)            # deliberately ragged
        eng.submit(co.stream(plen, seed=200 + i), args.max_new,
                   SamplingParams(temperature=0.0, seed=i))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.output_tokens) for r in done)
    print(f"\nrequest mode: {len(done)} requests "
          f"({eng.scheduler.n_slots} slots) -> {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile)")
    print("LaCache: near-full-cache quality at streaming-cache memory.")


if __name__ == "__main__":
    main()
