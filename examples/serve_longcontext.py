"""Serve a long-context request batch under different eviction policies and
compare quality/memory/latency — the paper's serving story in one script.

  PYTHONPATH=src python examples/serve_longcontext.py [--ctx 600] [--budget 96]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import bench_model, corpus, with_policy
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ctx", type=int, default=600)
    ap.add_argument("--budget", type=int, default=96)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg, params = bench_model()   # trains once, then cached
    co = corpus()
    toks = np.stack([co.stream(args.ctx, seed=100 + i)
                     for i in range(args.batch)])

    print(f"{'policy':12s}{'budget':>8s}{'ppl':>9s}{'cacheMB':>9s}{'s/100tok':>10s}")
    for policy in ("full", "streaming", "lacache", "h2o"):
        budget = args.ctx if policy == "full" else args.budget
        c = with_policy(cfg, policy, budget)
        eng = Engine(c, params, budget=budget)
        t0 = time.perf_counter()
        nll = eng.score_stream(toks)
        dt = (time.perf_counter() - t0) / (args.ctx * args.batch) * 100
        ppl = float(np.exp(nll.mean()))
        mb = eng.cache_bytes(eng.new_state(args.batch)) / 1e6
        print(f"{policy:12s}{budget:>8d}{ppl:>9.3f}{mb:>9.2f}{dt:>10.3f}")
    print("\nLaCache: near-full-cache quality at streaming-cache memory.")


if __name__ == "__main__":
    main()
